"""Fault-tolerance walkthrough: preemption, restart, elastic re-mesh.

1.  Train with periodic checkpoints and an injected node failure; the
    resumable runner restarts from the last committed step.
2.  Restore the same checkpoint onto a *different* mesh shape (elastic
    shrink), re-deriving shardings from the layout engine — the step
    counter and loss trajectory carry over bit-exactly (deterministic
    data pipeline).

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import shutil
import tempfile

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_smoke_config
from repro.data import pipeline
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.train import build
from repro.runtime import elastic
from repro.runtime.fault_tolerance import (
    FailureInjector,
    PreemptionError,
    run_resumable,
)

STEPS, CKPT_EVERY = 12, 4


def main() -> None:
    cfg = get_smoke_config("minitron-8b")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")
    ckpt = Checkpointer(ckpt_dir)
    mesh = make_host_mesh(data=len(jax.devices()))
    data_cfg = pipeline.DataConfig(seq_len=64, global_batch=4)
    injector = FailureInjector(fail_at_steps=(6,))

    state_box = {}

    def restore() -> int:
        state, jitted, _ = build(cfg, mesh, total_steps=STEPS)
        if ckpt.latest_step() is not None:
            state = elastic.remesh_restore(ckpt, state, cfg, mesh)
            print(f"[ft] restored step {int(state.step)}")
        state_box.update(state=state, jitted=jitted)
        return int(state.step)

    def run_step(step: int) -> None:
        injector.maybe_fail(step)          # simulated preemption
        batch = pipeline.make_batch(cfg, data_cfg, step)
        with shd.use_mesh(mesh):
            state, metrics = state_box["jitted"](state_box["state"],
                                                 batch)
        state_box["state"] = state
        print(f"[ft] step {step} loss {float(metrics['loss']):.4f}")
        if (step + 1) % CKPT_EVERY == 0:
            ckpt.save(step + 1, state)

    restarts = run_resumable(STEPS, run_step, restore)
    print(f"[ft] finished with {restarts} restart(s)")

    # elastic re-mesh: restore the final checkpoint on a 1-device mesh
    small = make_host_mesh(data=1)
    state, _, _ = build(cfg, small, total_steps=STEPS)
    ckpt.save(STEPS, state_box["state"])
    restored = elastic.remesh_restore(ckpt, state, cfg, small)
    print(f"[ft] elastic re-mesh restore ok at step {int(restored.step)}")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
