"""End-to-end serving driver: batched requests through the decode engine.

Prefills a batch of variable-intent prompts, decodes greedily with
per-sequence EOS masking, and reports tokens/s — the production
``repro.launch.serve`` path on a host mesh.  Exercises three model
families (dense GQA, sliding-window, SSM) to show the same engine serves
attention and attention-free caches alike.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.launch.serve import load_params
from repro.serve.engine import DecodeEngine

ARCHS = ("smollm-360m", "h2o-danube-3-4b", "mamba2-370m")
BATCH, PROMPT, STEPS = 4, 24, 12


def main() -> None:
    mesh = make_host_mesh(data=len(jax.devices()))
    rng = np.random.default_rng(0)
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        params = load_params(cfg, mesh)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab, (BATCH, PROMPT)), jnp.int32)
        with shd.use_mesh(mesh):
            engine = DecodeEngine(params, cfg, batch=BATCH,
                                  max_len=PROMPT + STEPS,
                                  eos_id=cfg.vocab - 1)
            t0 = time.time()
            res = engine.generate(prompts, STEPS)
            dt = time.time() - t0
        print(f"[{arch:20s}] {res.steps} steps x {BATCH} seqs "
              f"in {dt:5.2f}s -> {res.tokens[0][:8]}")


if __name__ == "__main__":
    main()
