"""Quickstart: the paper's technique as a library.

1.  Solve the reuse-maximizing tiling DSE for a GEMM (the paper's IP
    formulation on the TPU memory hierarchy) and inspect the ranked
    designs — the Table III/IV analogue.
2.  Run GEMMs through the declarative operator API: a ``GemmSpec``
    describes the problem, ``plan`` resolves strategy/tile/modeled
    bytes once (introspectable via ``plan.explain()``), ``execute``
    runs it (Pallas on TPU, bit-identical reference elsewhere) — or
    the one-shot ``ops.gemm`` that composes all three.
3.  Reproduce a slice of the paper's own analytical results (Versal
    Table III row 1 / Stratix Table IV row 1).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import ops
from repro.core import dse, paper_model as pm
from repro.core.tiling import GemmProblem


def main() -> None:
    # -- 1. tiling DSE ------------------------------------------------
    p = GemmProblem(m=8192, k=4096, n=4096, a_dtype="bfloat16")
    designs = dse.solve(p, top=3)
    print(f"GEMM {p.m}x{p.k}x{p.n} ({p.a_dtype}) — top designs:")
    for d in designs:
        t = d.tile
        print(f"  {t.strategy:3s} block {t.bm}x{t.bk}x{t.bn}  "
              f"VMEM {d.vmem_bytes/2**20:5.1f} MiB  "
              f"AI {d.traffic.arithmetic_intensity:6.0f}  "
              f"bound={d.traffic.bound}")

    # mixed precision is per-operand: a decode-shaped W8A16 GEMM bills
    # the int8 weight stream at one byte/element (+ scale vector)
    dec16 = GemmProblem(16, 4096, 4096, "bfloat16", "bfloat16")
    dec8 = GemmProblem(16, 4096, 4096, "bfloat16", "bfloat16",
                       "float32", b_dtype="int8")
    h16 = dse.solve(dec16, top=1)[0].traffic.hbm_bytes
    h8 = dse.solve(dec8, top=1)[0].traffic.hbm_bytes
    print(f"decode 16x4096x4096 modeled HBM: bf16 {h16/2**20:.1f} MiB "
          f"-> W8A16 {h8/2**20:.1f} MiB ({h8/h16:.0%})")

    # -- 2. the declarative operator API ------------------------------
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (512, 1024), jnp.bfloat16)
    b = jax.random.normal(key, (1024, 768), jnp.bfloat16)

    # spec -> plan -> execute, with the plan introspectable
    spec = ops.GemmSpec.for_operands(a, b)
    plan = ops.plan(spec, ops.gemm_shapes(a, b))
    c = ops.execute(plan, a, b)
    print(f"\n{plan.explain()}")
    print(f"execute: {a.shape} @ {b.shape} -> {c.shape} {c.dtype}")

    # the paper's int8 scheme as a spec: int8 operands, int32
    # accumulation, dequant scales applied outside
    aq, asc = ops.quantize_int8(a)
    bq, bsc = ops.quantize_int8(b, axis=0)
    acc = ops.gemm(aq, bq, out_dtype=jnp.int32)
    c8 = acc.astype(jnp.float32) * asc * bsc
    rel = float(jnp.linalg.norm(c8 - c.astype(jnp.float32))
                / jnp.linalg.norm(c.astype(jnp.float32)))
    print(f"int8 path rel err vs bf16: {rel:.3f}")

    # fused-epilogue + dual-B gated specs: a whole SwiGLU up-projection
    # in one call — act(A Wg) * (A Wu) with A streamed once, and the
    # down-projection absorbing the residual add on its flush
    wg = jax.random.normal(jax.random.PRNGKey(1), (1024, 768),
                           jnp.bfloat16)
    h = ops.gemm(a, wg, b2=b, activation="silu")
    y = ops.gemm(h, wg.T, residual=a)
    print(f"gated SwiGLU: {a.shape} -> {h.shape} -> {y.shape} "
          f"(gate/up intermediates stay in VMEM)")
    ratios = dse.mlp_traffic(16, 4096, 14336, fused=True, residual=True)
    unf = dse.mlp_traffic(16, 4096, 14336, fused=False, residual=True)
    print(f"decode SwiGLU modeled activation HBM: "
          f"{unf['activations']/2**20:.1f} -> "
          f"{ratios['activations']/2**20:.1f} MiB "
          f"({ratios['activations']/unf['activations']:.0%})")
    info = ops.plan_cache_info()
    print(f"plan cache: {info.entries} entries, {info.hits} hits, "
          f"{info.misses} misses (DSE ran once per unique spec+shape)")

    # -- 3. the paper's own numbers -----------------------------------
    sol = pm.MAXEVA_P1
    thr = pm.versal_throughput_ops(sol, 300e6) / 1e12
    print(f"\nVersal P1 13x4x6 @300MHz: {thr:.2f} TOPs "
          f"(paper Table III: 77.01)")
    lay = pm.TBLayout(18, 16, 4, 3)
    thr = pm.stratix_throughput_ops(lay, 349e6) / 1e12
    print(f"Stratix 18x16x4x3 @349MHz: {thr:.2f} TOPs "
          f"(paper Table IV: 68.00)")


if __name__ == "__main__":
    main()
