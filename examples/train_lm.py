"""End-to-end training driver: train a ~100M-param dense LM for a few
hundred steps on the host, with checkpointing and restart.

Uses the production ``repro.launch.train`` path (mesh -> layout-engine
shardings -> donated jitted step -> deterministic data pipeline -> async
checkpoints), not a separate toy loop.  Default config is a 12-layer
d=768 llama-style model (~103M params at vocab 32k, smollm family); CI
mode (--ci) shrinks it so the example finishes in ~a minute on one CPU
core.

    PYTHONPATH=src python examples/train_lm.py            # ~100M model
    PYTHONPATH=src python examples/train_lm.py --ci       # quick check
"""

import argparse
import shutil
import tempfile

from repro.configs.base import ModelConfig, register
from repro.launch import train as launch_train

EX100M = ModelConfig(
    name="example-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32000, head_dim=64,
    notes="~103M-param example model (train_lm.py)",
)

EX_CI = ModelConfig(
    name="example-ci", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=1024, head_dim=32, dtype="float32",
    notes="CI-sized example model",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = register(EX_CI if args.ci else EX100M)
    steps = args.steps or (30 if args.ci else 300)
    seq, batch = (128, 8) if args.ci else (512, 8)
    seq = args.seq_len or seq
    batch = args.global_batch or batch
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    print(f"[example] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{steps} steps @ seq={seq} batch={batch}")
    print(f"[example] checkpoints -> {ckpt_dir}")

    # phase 1: train the first 60% of the budget
    mid = max(steps * 3 // 5, 1)
    launch_train.train(cfg, steps=mid, seq_len=seq, global_batch=batch,
                       ckpt_dir=ckpt_dir, ckpt_every=max(mid // 2, 1))
    # phase 2: restart from the checkpoint and finish (proves the
    # checkpoint/restore path end-to-end; loss continues, not resets)
    out = launch_train.train(cfg, steps=steps, seq_len=seq,
                             global_batch=batch, ckpt_dir=ckpt_dir,
                             ckpt_every=max(steps // 3, 1))
    print(f"[example] final loss {out['loss']:.4f}")
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
