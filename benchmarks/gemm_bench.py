"""Wall-clock GEMM micro-benchmark (CPU host).

Times the public ``ops.gemm`` dispatch path (reference/XLA on this CPU
container) against raw ``jnp.dot`` to confirm the kernel layer adds no
dispatch overhead, plus the Pallas kernels in interpret mode on a small
shape for functional parity.  Real kernel throughput numbers come from
the roofline analysis (the container has no TPU).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.core import dse
from repro.core.bandwidth import estimate
from repro.core.hardware import TPU_V5E
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))         # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(report) -> None:
    key = jax.random.PRNGKey(0)
    m = k = n = 1024
    a = jax.random.normal(key, (m, k), jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(key, (k, n), jnp.float32).astype(jnp.bfloat16)

    gemm_jit = jax.jit(lambda a, b: ops.gemm(a, b))
    dot_jit = jax.jit(lambda a, b: jnp.dot(a, b))
    t_gemm = _time(gemm_jit, a, b)
    t_dot = _time(dot_jit, a, b)
    flops = 2.0 * m * k * n
    # identical lowering expected: within noise of each other
    ok = t_gemm < 3 * t_dot
    report.row("gemm", f"ops.gemm {m}x{k}x{n} bf16",
               us_per_call=f"{t_gemm*1e6:.0f}",
               gflops=f"{flops/t_gemm/1e9:.1f}",
               vs_xla=f"{t_gemm/t_dot:.2f}x", ok=ok)

    # Pallas kernels, interpret mode, small shape: parity + timing
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        tile = TileConfig(64, 128, 128, "aie")
        sa = a[:128, :256].astype(jnp.bfloat16)
        sb = b[:256, :128].astype(jnp.bfloat16)
        want = ref.gemm_ref(sa, sb, out_dtype=jnp.bfloat16)
        got = ops.gemm(sa, sb, tile=tile)
        err = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                    - got.astype(jnp.float32))))
        report.row("gemm", "pallas-aie 128x256x128 interpret",
                   max_abs_err=f"{err:.3e}", ok=err < 1e-1)
        got_tb = ops.gemm(sa, sb, tile=TileConfig(64, 128, 128, "tb"))
        err_tb = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                       - got_tb.astype(jnp.float32))))
        report.row("gemm", "pallas-tb  128x256x128 interpret",
                   max_abs_err=f"{err_tb:.3e}", ok=err_tb < 1e-1)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode

    # int8 quantized path (the paper's precision scheme)
    aq, ascale = ops.quantize_int8(a[:256, :256])          # (m,1) rows
    bq, bscale = ops.quantize_int8(b[:256, :256], axis=0)  # (1,n) cols
    got = ops.gemm_int8(jnp.asarray(aq), jnp.asarray(bq), ascale, bscale)
    want = jnp.dot(a[:256, :256].astype(jnp.float32),
                   b[:256, :256].astype(jnp.float32))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    report.row("gemm", "int8 quantized 256x256x256",
               rel_err=f"{rel:.3f}", ok=rel < 0.05)

    # W8A16: fused int8-weight kernels (interpret parity) + the modeled
    # HBM traffic the per-operand DSE claims vs bf16 weights for a
    # decode-shaped GEMM (m=16 batch, k=n=4096)
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        wq = quant.quantize_weight(b[:256, :128].astype(jnp.float32))
        x = a[:64, :256]
        want = ref.gemm_ref(x, quant.dequantize_weight(wq, jnp.bfloat16),
                            out_dtype=jnp.float32)
        for strat in ("aie", "tb"):
            got = ops.gemm(x, wq, strategy=strat,
                           tile=TileConfig(64, 128, 128, strat),
                           out_dtype=jnp.float32)
            rel = float(jnp.linalg.norm(got - want)
                        / jnp.linalg.norm(want))
            report.row("gemm", f"w8a16 fused-{strat} 64x256x128",
                       rel_err=f"{rel:.4f}", ok=rel < 5e-3)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode

    m_dec, k_dec, n_dec = 16, 4096, 4096
    p16 = GemmProblem(m_dec, k_dec, n_dec, "bfloat16", "bfloat16")
    p8 = GemmProblem(m_dec, k_dec, n_dec, "bfloat16", "bfloat16",
                     "float32", "int8")
    d16 = dse.solve(p16, top=1)[0]
    d8 = dse.solve(p8, top=1)[0]
    hbm16 = estimate(d16.tile, p16, TPU_V5E).hbm_bytes
    hbm8 = estimate(d8.tile, p8, TPU_V5E).hbm_bytes
    report.row("gemm", f"w8a16 modeled HBM {m_dec}x{k_dec}x{n_dec}",
               bf16_mib=f"{hbm16/2**20:.1f}",
               int8_mib=f"{hbm8/2**20:.1f}",
               ratio=f"{hbm8/hbm16:.2f}", ok=hbm8 <= 0.6 * hbm16)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
