"""Wall-clock GEMM micro-benchmark (CPU host).

Times the public ``ops.gemm`` dispatch path (reference/XLA on this CPU
container) against raw ``jnp.dot`` to confirm the kernel layer adds no
dispatch overhead, plus the Pallas kernels in interpret mode on a small
shape for functional parity.  Real kernel throughput numbers come from
the roofline analysis (the container has no TPU).
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tiling import TileConfig
from repro.kernels import ops, ref


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))         # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(report) -> None:
    key = jax.random.PRNGKey(0)
    m = k = n = 1024
    a = jax.random.normal(key, (m, k), jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(key, (k, n), jnp.float32).astype(jnp.bfloat16)

    gemm_jit = jax.jit(lambda a, b: ops.gemm(a, b))
    dot_jit = jax.jit(lambda a, b: jnp.dot(a, b))
    t_gemm = _time(gemm_jit, a, b)
    t_dot = _time(dot_jit, a, b)
    flops = 2.0 * m * k * n
    # identical lowering expected: within noise of each other
    ok = t_gemm < 3 * t_dot
    report.row("gemm", f"ops.gemm {m}x{k}x{n} bf16",
               us_per_call=f"{t_gemm*1e6:.0f}",
               gflops=f"{flops/t_gemm/1e9:.1f}",
               vs_xla=f"{t_gemm/t_dot:.2f}x", ok=ok)

    # Pallas kernels, interpret mode, small shape: parity + timing
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        tile = TileConfig(64, 128, 128, "aie")
        sa = a[:128, :256].astype(jnp.bfloat16)
        sb = b[:256, :128].astype(jnp.bfloat16)
        want = ref.gemm_ref(sa, sb, out_dtype=jnp.bfloat16)
        got = ops.gemm(sa, sb, tile=tile)
        err = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                    - got.astype(jnp.float32))))
        report.row("gemm", "pallas-aie 128x256x128 interpret",
                   max_abs_err=f"{err:.3e}", ok=err < 1e-1)
        got_tb = ops.gemm(sa, sb, tile=TileConfig(64, 128, 128, "tb"))
        err_tb = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                       - got_tb.astype(jnp.float32))))
        report.row("gemm", "pallas-tb  128x256x128 interpret",
                   max_abs_err=f"{err_tb:.3e}", ok=err_tb < 1e-1)
    finally:
        os.environ.pop("REPRO_KERNELS", None)

    # int8 quantized path (the paper's precision scheme)
    aq, ascale = ops.quantize_int8(a[:256, :256])          # (m,1) rows
    bq, bscale = ops.quantize_int8(b[:256, :256], axis=0)  # (1,n) cols
    got = ops.gemm_int8(jnp.asarray(aq), jnp.asarray(bq), ascale, bscale)
    want = jnp.dot(a[:256, :256].astype(jnp.float32),
                   b[:256, :256].astype(jnp.float32))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    report.row("gemm", "int8 quantized 256x256x256",
               rel_err=f"{rel:.3f}", ok=rel < 0.05)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
