"""Wall-clock GEMM micro-benchmark (CPU host).

Times the public planned ``ops.gemm`` dispatch path (reference/XLA on
this CPU container) against raw ``jnp.dot`` to confirm the spec/plan/
execute layer adds no dispatch overhead, plus the Pallas kernels in
interpret mode on a small shape for functional parity.  Real kernel
throughput numbers come from the roofline analysis (the container has
no TPU).

Also writes ``BENCH_gemm.json`` (rows + the fused-vs-unfused SwiGLU
modeled-HBM ratios + the grouped MoE block with its
grouped-vs-dense-capacity FLOPs ratio + the plan-cache counters proving
the DSE resolves once per unique spec+shape + modeled-vs-measured rows
for the planned attention path); the pallas-interpret CI job uploads it
as an artifact.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ops, quant, telemetry
from repro.core import dse
from repro.core.bandwidth import estimate
from repro.core.hardware import TPU_V5E
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import ref
from repro.telemetry import report as treport

BENCH_JSON = os.environ.get("REPRO_BENCH_JSON", "BENCH_gemm.json")


def _time(fn, *args, iters: int = 5) -> float:
    jax.block_until_ready(fn(*args))         # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(report) -> None:
    ops.plan_cache_clear()       # so the cache rows below are exact
    # per-section plan-cache accounting: each section ends with its own
    # hit/miss counts snapshotted and the cache cleared, so no section's
    # numbers are polluted by plans an earlier section resolved
    section_stats = {}

    def end_section(name: str) -> None:
        section_stats[name] = ops.plan_cache_info()._asdict()
        ops.plan_cache_clear()

    key = jax.random.PRNGKey(0)
    m = k = n = 1024
    a = jax.random.normal(key, (m, k), jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(key, (k, n), jnp.float32).astype(jnp.bfloat16)

    # dispatch-overhead row: the spec/plan/execute layer must lower to
    # the identical XLA dot, so pin the reference path — under an
    # interpret-mode env this row would time the interpreted kernel,
    # which measures the interpreter, not the dispatch layer
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "ref"
    try:
        gemm_jit = jax.jit(lambda a, b: ops.gemm(a, b))
        dot_jit = jax.jit(lambda a, b: jnp.dot(a, b))
        t_gemm = _time(gemm_jit, a, b)
        t_dot = _time(dot_jit, a, b)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode
    flops = 2.0 * m * k * n
    # identical lowering expected: within noise of each other
    ok = t_gemm < 3 * t_dot
    report.row("gemm", f"ops.gemm {m}x{k}x{n} bf16",
               us_per_call=f"{t_gemm*1e6:.0f}",
               gflops=f"{flops/t_gemm/1e9:.1f}",
               vs_xla=f"{t_gemm/t_dot:.2f}x", ok=ok)
    end_section("dispatch_overhead")

    # Pallas kernels, interpret mode, small shape: parity + timing
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        tile = TileConfig(64, 128, 128, "aie")
        sa = a[:128, :256].astype(jnp.bfloat16)
        sb = b[:256, :128].astype(jnp.bfloat16)
        want = ref.gemm_ref(sa, sb, out_dtype=jnp.bfloat16)
        got = ops.gemm(sa, sb, tile=tile)
        err = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                    - got.astype(jnp.float32))))
        report.row("gemm", "pallas-aie 128x256x128 interpret",
                   max_abs_err=f"{err:.3e}", ok=err < 1e-1)
        got_tb = ops.gemm(sa, sb, tile=TileConfig(64, 128, 128, "tb"))
        err_tb = float(jnp.max(jnp.abs(want.astype(jnp.float32)
                                       - got_tb.astype(jnp.float32))))
        report.row("gemm", "pallas-tb  128x256x128 interpret",
                   max_abs_err=f"{err_tb:.3e}", ok=err_tb < 1e-1)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode
    end_section("interpret_parity")

    # int8 path (the paper's precision scheme) through the planned API:
    # int8 x int8 spec, int32 accumulation, scales applied outside
    aq, ascale = ops.quantize_int8(a[:256, :256])          # (m,1) rows
    bq, bscale = ops.quantize_int8(b[:256, :256], axis=0)  # (1,n) cols
    acc = ops.gemm(jnp.asarray(aq), jnp.asarray(bq), out_dtype=jnp.int32)
    got = (acc.astype(jnp.float32) * ascale * bscale)
    want = jnp.dot(a[:256, :256].astype(jnp.float32),
                   b[:256, :256].astype(jnp.float32))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    report.row("gemm", "int8 quantized 256x256x256",
               rel_err=f"{rel:.3f}", ok=rel < 0.05)

    # W8A16: fused int8-weight kernels (interpret parity) + the modeled
    # HBM traffic the per-operand DSE claims vs bf16 weights for a
    # decode-shaped GEMM (m=16 batch, k=n=4096)
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        wq = quant.quantize_weight(b[:256, :128].astype(jnp.float32))
        x = a[:64, :256]
        want = ref.gemm_ref(x, quant.dequantize_weight(wq, jnp.bfloat16),
                            out_dtype=jnp.float32)
        for strat in ("aie", "tb"):
            got = ops.gemm(x, wq, strategy=strat,
                           tile=TileConfig(64, 128, 128, strat),
                           out_dtype=jnp.float32)
            rel = float(jnp.linalg.norm(got - want)
                        / jnp.linalg.norm(want))
            report.row("gemm", f"w8a16 fused-{strat} 64x256x128",
                       rel_err=f"{rel:.4f}", ok=rel < 5e-3)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode

    m_dec, k_dec, n_dec = 16, 4096, 4096
    p16 = GemmProblem(m_dec, k_dec, n_dec, "bfloat16", "bfloat16")
    p8 = GemmProblem(m_dec, k_dec, n_dec, "bfloat16", "bfloat16",
                     "float32", "int8")
    d16 = dse.solve(p16, top=1)[0]
    d8 = dse.solve(p8, top=1)[0]
    hbm16 = estimate(d16.tile, p16, TPU_V5E).hbm_bytes
    hbm8 = estimate(d8.tile, p8, TPU_V5E).hbm_bytes
    report.row("gemm", f"w8a16 modeled HBM {m_dec}x{k_dec}x{n_dec}",
               bf16_mib=f"{hbm16/2**20:.1f}",
               int8_mib=f"{hbm8/2**20:.1f}",
               ratio=f"{hbm8/hbm16:.2f}", ok=hbm8 <= 0.6 * hbm16)
    end_section("int8_w8a16")

    # ------------------------------------------------ fused-MLP rows
    # wall-clock: fused SwiGLU dispatch (gated + epilogue specs) vs the
    # unfused three-GEMM + XLA-silu composition, public ops path
    d_m, d_ff = 512, 1536
    x = jax.random.normal(key, (4, 64, d_m), jnp.float32)
    wg = jax.random.normal(jax.random.PRNGKey(2), (d_m, d_ff),
                           jnp.float32)
    wu = jax.random.normal(jax.random.PRNGKey(3), (d_m, d_ff),
                           jnp.float32)
    wd = jax.random.normal(jax.random.PRNGKey(4), (d_ff, d_m),
                           jnp.float32)

    def fused_mlp(x):
        h = ops.gemm(x, wg, b2=wu, activation="silu")
        return ops.gemm(h, wd, residual=x)

    def unfused_mlp(x):
        gate = ops.gemm(x, wg)
        up = ops.gemm(x, wu)
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
        return x + ops.gemm(h, wd)

    t_fused = _time(jax.jit(fused_mlp), x)
    t_unfused = _time(jax.jit(unfused_mlp), x)
    err = float(jnp.max(jnp.abs(fused_mlp(x) - unfused_mlp(x))))
    report.row("gemm", f"swiglu fused-mlp wall-clock b4s64 d{d_m}",
               fused_us=f"{t_fused*1e6:.0f}",
               unfused_us=f"{t_unfused*1e6:.0f}",
               max_abs_err=f"{err:.2e}",
               ok=err < 1e-3 and t_fused < 3 * t_unfused)

    # gated kernel interpret parity on a small shape
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        xs = x[0, :16].astype(jnp.bfloat16)
        got = ops.gemm(xs, wg[:, :256].astype(jnp.bfloat16),
                       b2=wu[:, :256].astype(jnp.bfloat16),
                       activation="silu",
                       tile=TileConfig(16, 128, 128, "aie"))
        zg = ref.gemm_ref(xs, wg[:, :256].astype(jnp.bfloat16),
                          out_dtype=jnp.float32)
        zu = ref.gemm_ref(xs, wu[:, :256].astype(jnp.bfloat16),
                          out_dtype=jnp.float32)
        want = jax.nn.silu(zg) * zu
        rel = float(jnp.linalg.norm(got.astype(jnp.float32) - want)
                    / jnp.linalg.norm(want))
        report.row("gemm", "gated pallas-aie 16x512x256 interpret",
                   rel_err=f"{rel:.4f}", ok=rel < 2e-2)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode

    # modeled HBM bytes/layer, fused vs unfused SwiGLU (the paper's
    # in-array cascade carried past the flush).  Decode shape: the
    # weight stream is an identical irreducible floor on both sides, so
    # the credit is reported on the activation/intermediate component;
    # at the train shape the (m, d_ff) intermediates dominate and the
    # drop shows on the layer total.
    ratios = {}
    for label, m_mlp, comp, thresh in (
            ("decode_16x4096xff14336", 16, "activations", 0.7),
            ("train_8192x4096xff14336", 8192, "total", 0.7)):
        fu = dse.mlp_traffic(m_mlp, 4096, 14336, fused=True,
                             residual=True)
        un = dse.mlp_traffic(m_mlp, 4096, 14336, fused=False,
                             residual=True)
        ratio = fu[comp] / un[comp]
        ratios[label] = {
            "component": comp, "ratio": round(ratio, 4),
            "fused_bytes": fu, "unfused_bytes": un,
        }
        report.row("gemm", f"swiglu modeled HBM {label}",
                   component=comp,
                   unfused_mib=f"{un[comp]/2**20:.1f}",
                   fused_mib=f"{fu[comp]/2**20:.1f}",
                   ratio=f"{ratio:.2f}", ok=ratio <= thresh)
    end_section("fused_mlp")

    # --------------------------------------------- plan-cache counters
    # The section above ended with a cache clear, so the counters here
    # are EXACT: three calls on one fresh decode shape must resolve the
    # DSE once (1 miss) and hit twice.
    xd = jax.random.normal(key, (16, 1024), jnp.bfloat16)
    wd16 = jax.random.normal(key, (1024, 1024), jnp.bfloat16)
    for _ in range(3):
        ops.gemm(xd, wd16)
    info = ops.plan_cache_info()
    ok = (info.entries == 1 and info.hits == 2 and info.misses == 1)
    report.row("gemm", "plan cache (DSE once per unique spec+shape)",
               entries=info.entries, hits=info.hits,
               misses=info.misses, ok=ok)
    end_section("plan_cache")

    # ------------------------------------------- grouped (MoE) section
    # The grouped ragged expert sweep on a deterministically imbalanced
    # routing sample (t=2048 tokens, top_k=2, E=8, capacity factor
    # 1.25): the plan's executed FLOPs (true routed rows + straddle
    # tiles) must undercut the padded dense-capacity einsum by at least
    # the capacity headroom — ratio <= 1/cf + 0.05 straddle slack —
    # plus interpret parity of the one-kernel sweep vs its XLA oracle.
    t_tok, top_k, e_moe, cf = 2048, 2, 8, 1.25
    from repro.models.moe import capacity as moe_capacity
    c_moe = moe_capacity(t_tok, e_moe, top_k, cf)
    dense_rows = e_moe * c_moe
    counts = [2048, 1024, 512, 256, 128, 64, 32, 32]   # skewed routing
    assert sum(counts) == t_tok * top_k
    sizes_moe = [min(cnt, c_moe) for cnt in counts]
    m_true = sum(sizes_moe)
    k_g, n_g = 512, 1024
    spec_g = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                          grouped=True)
    pl_g = ops.plan(spec_g, (m_true, k_g, n_g, e_moe, dense_rows))
    dense_flops = 2.0 * dense_rows * k_g * n_g
    flops_ratio = pl_g.flops / dense_flops
    limit = 1.0 / cf + 0.05
    report.row("gemm",
               f"grouped modeled FLOPs E{e_moe} cf{cf} imbalanced",
               true_rows=m_true, capacity_rows=dense_rows,
               tile=f"{pl_g.tile.strategy} {pl_g.tile.bm}x"
                    f"{pl_g.tile.bk}x{pl_g.tile.bn}",
               ratio=f"{flops_ratio:.3f}", limit=f"{limit:.3f}",
               ok=flops_ratio <= limit)
    assert flops_ratio <= limit, (
        f"grouped plan executes {flops_ratio:.3f} of dense-capacity "
        f"FLOPs on the imbalanced sample; want <= {limit:.3f}")

    # interpret parity: the planned grouped dispatch vs the jnp
    # reference on a ragged sample with an empty group
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        gs = jnp.asarray([100, 0, 37, 60], jnp.int32)
        ag = jax.random.normal(key, (197, 256), jnp.float32) \
            .astype(jnp.bfloat16)
        bg = (jax.random.normal(jax.random.PRNGKey(7), (4, 256, 256),
                                jnp.float32) * 0.1).astype(jnp.bfloat16)
        got = ops.gemm_grouped(ag, bg, gs)
        want = ref.gemm_grouped_ref(ag, bg, gs, out_dtype=got.dtype)
        err_g = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                      - want.astype(jnp.float32))))
        report.row("gemm", "grouped pallas 197x256x256 E4 interpret",
                   max_abs_err=f"{err_g:.3e}", ok=err_g < 1e-1)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode
    grouped_section = {
        "tokens": t_tok, "top_k": top_k, "n_experts": e_moe,
        "capacity_factor": cf, "capacity": c_moe,
        "group_sizes": sizes_moe,
        "true_rows": m_true, "capacity_rows": dense_rows,
        "executed_flops": pl_g.flops, "dense_capacity_flops": dense_flops,
        "flops_ratio": round(flops_ratio, 4),
        "flops_ratio_limit": round(limit, 4),
        "tile": f"{pl_g.tile.strategy} {pl_g.tile.bm}x{pl_g.tile.bk}x"
                f"{pl_g.tile.bn}",
        "modeled_hbm_bytes": pl_g.hbm_bytes,
        "interpret_max_abs_err": err_g,
        "explain": pl_g.explain(),
    }
    end_section("grouped")

    # ------------------------------------- model-vs-measured section
    # Representative decode-shaped specs, executed standalone and
    # joined with their modeled bytes/roofline time — the measurement
    # half of the paper's analytic story.  On this CPU host 'achieved'
    # only compares specs against each other (honesty note in the
    # report module); the check is that measurement itself works.
    mvm_plans = [
        ops.plan(ops.GemmSpec(), (16, 1024, 1024)),
        ops.plan(ops.GemmSpec(b_quant=True), (16, 1024, 1024)),
        ops.plan(ops.GemmSpec(gated=True,
                              epilogue=ops.Epilogue(activation="silu")),
                 (16, 512, 512)),
    ]
    mvm = treport.model_vs_measured(mvm_plans, iters=3)
    for r in mvm:
        report.row("gemm", f"model-vs-measured {r['spec']}",
                   shape=f"{r['m']}x{r['k']}x{r['n']}",
                   modeled_us=r["t_model_us"],
                   measured_us=r["t_measured_us"],
                   achieved=r["achieved"], mode=r["mode"],
                   ok=r["t_measured_us"] is not None
                   and r["t_measured_us"] > 0)
    end_section("model_vs_measured")

    # ------------------------------------------------ autotune section
    # Re-time the fused-vs-unfused SwiGLU wash with *measured* tiles:
    # autotuning on (small K — this is a CPU host), every GEMM the two
    # MLPs plan goes through the top-K sweep, winners persist to the
    # tuning cache, and the wall-clock is re-taken with the tuned plans.
    from repro import tune
    tune.enable(2)
    try:
        # fresh lambdas: jit caches traces per function object, and the
        # fused_mlp/unfused_mlp traces above predate autotuning — the
        # retrace is what routes every plan through the tuner
        t_fused_at = _time(jax.jit(lambda v: fused_mlp(v)), x)
        t_unfused_at = _time(jax.jit(lambda v: unfused_mlp(v)), x)
        tuned_plans = [{
            "spec": p.spec.key, "shape": f"{p.m}x{p.k}x{p.n}",
            "tile": f"{p.tile.strategy} {p.tile.bm}x{p.tile.bk}x"
                    f"{p.tile.bn}",
            "source": p.source,
            "t_measured_us": (round(p.tuned.t_measured_us, 2)
                              if p.tuned else None),
            "t_analytic_us": (round(p.tuned.t_analytic_us, 2)
                              if p.tuned and p.tuned.t_analytic_us
                              else None),
            "analytic_tile": p.tuned.analytic_tile if p.tuned else None,
            "from_cache": p.tuned.from_cache if p.tuned else None,
        } for p in ops.plans()]
        winner = "fused" if t_fused_at <= t_unfused_at else "unfused"
        delta = abs(t_fused_at - t_unfused_at) \
            / max(min(t_fused_at, t_unfused_at), 1e-12)
        n_tuned = sum(1 for p in tuned_plans if p["source"] == "tuned")
        autotune_section = {
            "k": 2,
            "fused_us": round(t_fused_at * 1e6, 1),
            "unfused_us": round(t_unfused_at * 1e6, 1),
            "fused_us_analytic_tiles": round(t_fused * 1e6, 1),
            "unfused_us_analytic_tiles": round(t_unfused * 1e6, 1),
            "winner": winner,
            "measured_delta": round(delta, 4),
            "plans": tuned_plans,
            "tuning_cache": tune.tuning_cache_info()._asdict(),
            "cache_path": tune.cache_path(),
        }
        report.row("gemm", "swiglu autotuned wall-clock",
                   fused_us=f"{t_fused_at*1e6:.0f}",
                   unfused_us=f"{t_unfused_at*1e6:.0f}",
                   winner=winner, delta=f"{delta:.2f}x",
                   tuned=f"{n_tuned}/{len(tuned_plans)}",
                   ok=n_tuned > 0)
    finally:
        tune.disable()
    end_section("autotune")

    # ---------------------------------------------- calibration section
    # Regress every measured sample the tuner just persisted against its
    # modeled HBM bytes + flops: effective per-mode bandwidth/compute
    # constants with R².  On this CPU host the constants describe the
    # host, not a TPU — that is exactly what makes them useful for
    # re-ranking tiles here and honest in the report.
    fits = tune.calibrate.fit()
    calibration_section = {mode: c.as_dict() for mode, c in fits.items()}
    for mode, c in fits.items():
        report.row("gemm", f"calibration fit [{mode}]",
                   n=c.n_samples,
                   eff_bw=("-" if c.hbm_bw is None
                           else f"{c.hbm_bw/1e9:.2f}GB/s"),
                   eff_flops=("-" if c.peak_flops is None
                              else f"{c.peak_flops/1e9:.1f}GF/s"),
                   t0_us=f"{c.t0_us:.1f}", r2=f"{c.r2:.4f}",
                   ok=c.n_samples >= 3)

    # ------------------------------------------------ attention section
    # Same treatment for the AttnSpec -> attn_plan -> attn_execute path:
    # representative prefill/decode/paged specs planned through the
    # attention DSE and executed standalone, the measured median joined
    # with the plan's modeled bytes/roofline time.  Ref dispatch pinned
    # for the timing rows (the GEMM model-vs-measured honesty note
    # applies), plus one interpret-parity row proving the planned
    # flash-decode body agrees with its XLA oracle through the same
    # plan/execute entrypoints the serve loop uses.
    from repro.tune import measure_attn_plan
    ops.attn_plan_cache_clear()
    attn_cases = [
        ("prefill mha causal b1s512 d64",
         ops.AttnSpec(mode="prefill"), (1, 512, 512, 8, 8, 64)),
        ("prefill gqa4 win256 b1s512 d64",
         ops.AttnSpec(mode="prefill", window=256, group=4),
         (1, 512, 512, 8, 2, 64)),
        ("decode gqa4 b4 skv2048 d64",
         ops.AttnSpec(mode="decode", group=4), (4, 2048, 8, 2, 64)),
        ("decode_paged gqa4 b2 32x64p d64",
         ops.AttnSpec(mode="decode_paged", group=4),
         (2, 32, 64, 8, 2, 64)),
    ]
    attn_rows = []
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "ref"
    try:
        rng = np.random.default_rng(0)
        for label, spec_a, shapes_a in attn_cases:
            pl_a = ops.attn_plan(spec_a, shapes_a)
            meas = measure_attn_plan(pl_a, iters=3, warmup=1, rng=rng)
            t_us = meas.median_s * 1e6
            t_model_us = pl_a.traffic.t_model * 1e6
            attn_rows.append({
                "spec": pl_a.spec.key, "shape": pl_a.shape_key,
                "kernel": pl_a.kernel,
                "blocks": (f"{pl_a.bq or '-'}x{pl_a.bkv or '-'}"
                           if pl_a.bq or pl_a.bkv else None),
                "source": pl_a.source,
                "hbm_mib": round(pl_a.hbm_bytes / 2**20, 3),
                "flops": pl_a.flops,
                "bound": pl_a.traffic.bound,
                "t_model_us": round(t_model_us, 2),
                "t_measured_us": round(t_us, 2),
                "spread": round(meas.spread, 4),
                "mode": "ref",
                "fallback_reason": pl_a.fallback_reason,
            })
            report.row("gemm", f"attn model-vs-measured {label}",
                       kernel=pl_a.kernel,
                       modeled_us=f"{t_model_us:.1f}",
                       measured_us=f"{t_us:.0f}",
                       hbm_mib=f"{pl_a.hbm_bytes/2**20:.1f}",
                       ok=t_us > 0)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode
    attn_cache = ops.attn_plan_cache_info()
    ok = (attn_cache.entries == len(attn_cases)
          and attn_cache.misses == len(attn_cases))
    report.row("gemm", "attn plan cache (one resolve per spec+shape)",
               entries=attn_cache.entries, hits=attn_cache.hits,
               misses=attn_cache.misses, ok=ok)

    # interpret parity: the planned flash-decode kernel body vs the XLA
    # decode oracle, ragged per-row positions included
    prev_mode = os.environ.get("REPRO_KERNELS")
    os.environ["REPRO_KERNELS"] = "interpret"
    try:
        from repro.kernels.attn_api import _decode_attention_xla
        qd = jax.random.normal(key, (2, 8, 64), jnp.float32) \
            .astype(jnp.bfloat16)
        kc = jax.random.normal(jax.random.PRNGKey(11), (2, 512, 4, 64),
                               jnp.float32).astype(jnp.bfloat16)
        vc = jax.random.normal(jax.random.PRNGKey(12), (2, 512, 4, 64),
                               jnp.float32).astype(jnp.bfloat16)
        pos = jnp.asarray([200, 511], jnp.int32)
        pl_fd = ops.attn_plan(ops.AttnSpec(mode="decode", group=2),
                              (2, 512, 8, 4, 64))
        got = ops.attn_execute(pl_fd, qd, kc, vc, pos=pos)
        want = _decode_attention_xla(qd, kc, vc, pos, window=0)
        err_a = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                      - want.astype(jnp.float32))))
        report.row("gemm", "attn flash-decode b2 skv512 interpret",
                   kernel=pl_fd.kernel, max_abs_err=f"{err_a:.3e}",
                   ok=pl_fd.kernel == "flash_decode" and err_a < 1e-1)
    finally:
        if prev_mode is None:
            os.environ.pop("REPRO_KERNELS", None)
        else:
            os.environ["REPRO_KERNELS"] = prev_mode
    attn_section = {
        "model_vs_measured": attn_rows,
        "plan_cache": attn_cache._asdict(),
        "interpret_flash_decode_max_abs_err": err_a,
    }
    ops.attn_plan_cache_clear()

    payload = {"rows": report.rows, "swiglu_fused_hbm": ratios,
               "attn": attn_section,
               "grouped": grouped_section,
               "autotune": autotune_section,
               "calibration": calibration_section,
               "w8a16_decode_hbm_ratio": round(hbm8 / hbm16, 4),
               "plan_cache": info._asdict(),
               "plan_cache_sections": section_stats,
               "model_vs_measured": mvm,
               "model_vs_measured_summary": treport.summarize(mvm)}
    if telemetry.enabled():
        payload["telemetry_snapshot"] = telemetry.snapshot()
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    report.row("gemm", "bench json", path=BENCH_JSON, ok=True)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
