"""Paper Fig. 7b / Fig. 8: throughput vs square matrix size.

The paper zero-pads square SxSxS GEMMs up to the design's compute-GEMM
multiple and measures effective throughput (useful ops / padded time).
We reproduce the scalability *shape*: the padding-utilization model

    eff(S) = native_peak * S^3 / (pad(S, Dm) * pad(S, Dk) * pad(S, Dn))

which recovers the paper's observations: the Versal 2x2x8 (P1) design
reaches ~native peak by S~2K; the Stratix 9x16x5x5 design (D_K'=2400)
scales worse than 9x8x10x5 (D_K'=640) despite higher native peak.
"""

from __future__ import annotations

from repro.core import paper_model as pm

SIZES = [512, 1024, 2048, 4096, 8192, 16384, 32768]


def _pad(s: int, d: int) -> int:
    return ((s + d - 1) // d) * d


def curve(compute_gemm, native_tops: float):
    dm, dk, dn = compute_gemm
    out = []
    for s in SIZES:
        util = s ** 3 / (_pad(s, dm) * _pad(s, dk) * _pad(s, dn))
        out.append((s, native_tops * util))
    return out


def run(report) -> None:
    # Versal best overall design 2x2x8 (P1) @ 290 MHz (Fig. 7b)
    sol = pm.MAXEVA_P1
    thr = pm.versal_throughput_ops(sol, 290e6) / 1e12
    versal = curve(sol.compute_gemm, thr)
    # paper: ~native peak for S >= ~2K
    ok_v = versal[-1][1] > 0.97 * thr and versal[2][1] > 0.9 * thr
    report.row("fig7b", "versal 2x2x8 (P1)",
               curve=" ".join(f"{s//1024}K:{t:.1f}" if s >= 1024
                              else f"{s}:{t:.1f}" for s, t in versal),
               native=f"{thr:.2f} TOPs", ok=ok_v)

    # Stratix Fig. 8a vs 8b: high-D_K' vs low-D_K' designs
    a = pm.TBLayout(9, 16, 5, 5)     # D_K' = 1280
    b = pm.TBLayout(9, 8, 10, 5)     # D_K' = 640
    thr_a = pm.stratix_throughput_ops(a, 350e6) / 1e12
    thr_b = pm.stratix_throughput_ops(b, 320e6) / 1e12
    ca = curve(a.compute_gemm, thr_a)
    cb = curve(b.compute_gemm, thr_b)
    # the lower-D_K' design must scale better at small sizes even though
    # its native peak is lower (paper SS V-C2)
    frac_a_small = ca[0][1] / thr_a
    frac_b_small = cb[0][1] / thr_b
    ok_s = thr_a > thr_b and frac_b_small > frac_a_small
    report.row("fig8", "stratix 9x16x5x5 vs 9x8x10x5",
               curve=f"@512: {ca[0][1]:.1f} vs {cb[0][1]:.1f} TOPs "
                     f"(native {thr_a:.1f} vs {thr_b:.1f})",
               scaling=f"util@512 {100*frac_a_small:.0f}% vs "
                       f"{100*frac_b_small:.0f}%",
               ok=ok_s)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
