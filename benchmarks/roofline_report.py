"""§Roofline report: the 40-cell (arch × shape) table from dry-run
artifacts.

Reads ``artifacts/dryrun/single/*.json`` (written by
``repro.launch.dryrun``) and emits, per cell: the three roofline terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and memory fit — the
exact §Roofline record the task sheet requires.  ``markdown_table()`` is
what EXPERIMENTS.md embeds.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs.base import ARCH_IDS
from repro.launch.shapes import SHAPES

ART_DIR = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")


def load(mesh: str = "single") -> Dict[str, dict]:
    out = {}
    for path in glob.glob(os.path.join(ART_DIR, mesh, "*.json")):
        rec = json.load(open(path))
        out[f"{rec['arch']}__{rec['shape']}"] = rec
    return out


def _fmt_t(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def cell_rows(mesh: str = "single") -> List[dict]:
    recs = load(mesh)
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            rec = recs.get(f"{arch}__{shape}")
            if rec is None:
                rows.append({"arch": arch, "shape": shape,
                             "status": "missing"})
                continue
            if rec.get("skipped"):
                rows.append({"arch": arch, "shape": shape,
                             "status": "skip",
                             "reason": rec["skip_reason"]})
                continue
            if not rec.get("ok"):
                rows.append({"arch": arch, "shape": shape,
                             "status": "FAIL",
                             "reason": rec.get("error", "?")[:200]})
                continue
            r = rec["roofline"]
            mem = rec.get("memory_analysis", {})
            peak = mem.get("peak_bytes_per_device")
            hbm = rec.get("hbm_per_device", 16 * 2**30)
            rows.append({
                "arch": arch, "shape": shape, "status": "ok",
                "t_compute": r["t_compute"], "t_memory": r["t_memory"],
                "t_collective": r["t_collective"],
                "dominant": r["dominant"],
                "roofline_fraction": r["roofline_fraction"],
                "useful_ratio": r["useful_flops_ratio"],
                "peak_bytes": peak,
                "fits": (peak is not None and peak <= hbm),
                "layout": rec.get("layout"),
            })
    return rows


def markdown_table(mesh: str = "single") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | "
        "bottleneck | roofline frac | 6ND/HLO | HBM/dev | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for row in cell_rows(mesh):
        if row["status"] == "skip":
            lines.append(f"| {row['arch']} | {row['shape']} | "
                         f"skip({row['reason'][:40]}…) | | | | | | | |")
        elif row["status"] in ("missing", "FAIL"):
            lines.append(f"| {row['arch']} | {row['shape']} | "
                         f"**{row['status']}** | | | | | | | |")
        else:
            pk = row["peak_bytes"]
            lines.append(
                f"| {row['arch']} | {row['shape']} | "
                f"{_fmt_t(row['t_compute'])} | {_fmt_t(row['t_memory'])} |"
                f" {_fmt_t(row['t_collective'])} | {row['dominant']} | "
                f"{row['roofline_fraction']:.3f} | "
                f"{(row['useful_ratio'] or 0):.2f} | "
                f"{pk/2**30:.1f}GiB | "
                f"{'yes' if row['fits'] else 'NO'} |")
    return "\n".join(lines)


def run(report) -> None:
    for mesh in ("single", "multi"):
        rows = cell_rows(mesh)
        done = [r for r in rows if r["status"] == "ok"]
        skip = [r for r in rows if r["status"] == "skip"]
        fail = [r for r in rows if r["status"] == "FAIL"]
        missing = [r for r in rows if r["status"] == "missing"]
        report.row(
            "roofline", f"dryrun[{mesh}] 40-cell sweep",
            compiled=len(done), skipped=len(skip), failed=len(fail),
            missing=len(missing),
            ok=(not fail and not missing and len(skip) == 7))
        if mesh == "single" and done:
            worst = min(done, key=lambda r: r["roofline_fraction"])
            coll = max(done, key=lambda r: r["t_collective"]
                       / max(r["t_compute"] + r["t_memory"], 1e-12))
            report.row(
                "roofline", "extremes",
                worst_fraction=f"{worst['arch']}/{worst['shape']} "
                               f"{worst['roofline_fraction']:.3f}",
                most_collective=f"{coll['arch']}/{coll['shape']}",
                ok=True)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
    print()
    print(markdown_table())
