"""Continuous-batching serve benchmark (smoke-sized, CPU host).

Runs the ragged acceptance trace — prompt lens 4/16/8/32, max_tokens
8/32/16/4 — through the continuous 2-slot engine, checks every request
is bit-identical to a solo batch-1 greedy run, and scores it against the
old lockstep engine with a traffic-style work model:

* one **slot-token unit** = one batch-slot occupying one sequence
  position of work (a decode step costs ``n_slots`` units whether or not
  every slot is live; a prefill costs its processed token positions,
  padding included) — the serving analogue of the paper's HBM-traffic
  scoring, where cost follows what is *streamed*, not what is useful;
* **lockstep** groups requests FIFO into static batches of ``n_slots``,
  pads prompts to the batch max, and decodes every member until the
  batch max_tokens finishes (the old engine's semantics);
* **continuous** admits per slot (exact prompts, no padding) and counts
  its real measured decode steps — idle-slot tail steps included.

Modeled tokens/sec is useful tokens per unit; the ratio is asserted
>= 1.5x and written to ``BENCH_serve.json`` (with measured wall-clock
numbers alongside) so the serving trajectory is machine-readable across
PRs; the pallas-interpret CI job uploads it as an artifact.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.serve.engine import (ACCEPTANCE_TRACE, DecodeEngine,
                                acceptance_requests, solo_greedy)

BENCH_JSON = os.environ.get("REPRO_SERVE_BENCH_JSON", "BENCH_serve.json")

PROMPT_LENS = tuple(p for p, _ in ACCEPTANCE_TRACE)
MAX_TOKENS = tuple(mt for _, mt in ACCEPTANCE_TRACE)
N_SLOTS = 2
SPEEDUP_FLOOR = 1.5


def lockstep_units(prompt_lens, max_tokens, n_slots) -> dict:
    """Work model of the old lockstep engine: FIFO static batches of
    ``n_slots``; prompts pad to the batch max; every member decodes
    until the batch's slowest request finishes."""
    prefill = decode_steps = 0
    for i in range(0, len(prompt_lens), n_slots):
        pls = prompt_lens[i:i + n_slots]
        mts = max_tokens[i:i + n_slots]
        prefill += max(pls) * len(pls)          # padded prompt tokens
        decode_steps += max(mts) - 1            # first token rides prefill
    return {"prefill_tokens": prefill, "decode_steps": decode_steps,
            "slot_token_units": prefill + decode_steps * n_slots}


def run(report) -> None:
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(p + mt for p, mt in zip(PROMPT_LENS, MAX_TOKENS)) + 1
    reqs = acceptance_requests(cfg.vocab)
    useful = sum(MAX_TOKENS)

    engine = DecodeEngine(params, cfg, batch=N_SLOTS, max_len=max_len)
    # warm-up: compile every prompt-length bucket + the step, so the
    # measured numbers exclude jit compilation
    engine.run(acceptance_requests(cfg.vocab, seed=1))
    engine.reset_metrics()

    t0 = time.perf_counter()
    results = {r.rid: r for r in engine.run(reqs)}
    wall = time.perf_counter() - t0

    # --- correctness: bit-identical to each request alone at batch 1
    exact = 0
    for req in reqs:
        want = solo_greedy(params, cfg, req.prompt, req.max_tokens,
                           max_len)
        if np.array_equal(results[req.rid].tokens, want):
            exact += 1
    report.row("serve", "ragged trace vs solo batch-1 (greedy)",
               bit_identical=f"{exact}/{len(reqs)}",
               ok=exact == len(reqs))

    # --- modeled work: slot-token units (see module docstring)
    m = engine.metrics
    cont_units = m["prefill_tokens"] + m["decode_steps"] * N_SLOTS
    lock = lockstep_units(PROMPT_LENS, MAX_TOKENS, N_SLOTS)
    cont_tps = useful / cont_units              # tokens per unit
    lock_tps = useful / lock["slot_token_units"]
    speedup = cont_tps / lock_tps
    occupancy = engine.occupancy()
    report.row("serve",
               f"continuous vs lockstep, {N_SLOTS} slots (modeled)",
               cont_units=cont_units,
               lockstep_units=lock["slot_token_units"],
               speedup=f"{speedup:.2f}x",
               occupancy=f"{occupancy:.2f}",
               ok=speedup >= SPEEDUP_FLOOR)
    report.row("serve", "measured wall-clock (smoke, CPU)",
               tok_s=f"{useful / wall:.1f}",
               decode_tok_s=f"{engine.tokens_per_sec():.1f}",
               steps=m["decode_steps"], ok=True)

    # --- per-request latency breakdown: TTFT + queue wait from the
    # engine's request lifecycle (every acceptance request arrives at
    # t=0, so queue wait here IS the scheduler's admission delay)
    ttfts = np.asarray([results[r.rid].ttft for r in reqs])
    qwaits = np.asarray([results[r.rid].queue_wait for r in reqs])
    per_request = [
        {"rid": r.rid, "prompt_len": int(r.prompt.shape[0]),
         "n_tokens": results[r.rid].n_tokens,
         "ttft_s": round(float(results[r.rid].ttft), 6),
         "queue_wait_s": round(float(results[r.rid].queue_wait), 6)}
        for r in reqs
    ]
    report.row("serve", "request latency breakdown",
               ttft_mean_ms=f"{ttfts.mean()*1e3:.1f}",
               ttft_p99_ms=f"{np.percentile(ttfts, 99)*1e3:.1f}",
               queue_wait_mean_ms=f"{qwaits.mean()*1e3:.1f}",
               ok=bool((ttfts > 0).all()))

    payload = {
        "trace": {"prompt_lens": PROMPT_LENS, "max_tokens": MAX_TOKENS,
                  "n_slots": N_SLOTS, "useful_tokens": useful},
        "continuous": {
            "prefill_tokens": m["prefill_tokens"],
            "decode_steps": m["decode_steps"],
            "slot_token_units": cont_units,
            "occupancy": occupancy,
            "modeled_tokens_per_unit": cont_tps,
            "measured_tok_s": useful / wall,
            "measured_decode_tok_s": engine.tokens_per_sec(),
        },
        "lockstep": dict(lock, modeled_tokens_per_unit=lock_tps),
        "modeled_speedup": speedup,
        "bit_identical": exact == len(reqs),
        "requests": per_request,
        "latency": {
            "ttft_mean_s": float(ttfts.mean()),
            "ttft_p99_s": float(np.percentile(ttfts, 99)),
            "queue_wait_mean_s": float(qwaits.mean()),
            "queue_wait_p99_s": float(np.percentile(qwaits, 99)),
        },
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    report.row("serve", f"wrote {BENCH_JSON}",
               modeled_speedup=f"{speedup:.2f}x", ok=True)
