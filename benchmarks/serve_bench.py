"""Continuous-batching serve benchmark (smoke-sized, CPU host).

Runs the ragged acceptance trace — prompt lens 4/16/8/32, max_tokens
8/32/16/4 — through the continuous 2-slot engine, checks every request
is bit-identical to a solo batch-1 greedy run, and scores it against the
old lockstep engine with a traffic-style work model:

* one **slot-token unit** = one batch-slot occupying one sequence
  position of work (a decode step costs ``n_slots`` units whether or not
  every slot is live; a prefill costs its processed token positions,
  padding included) — the serving analogue of the paper's HBM-traffic
  scoring, where cost follows what is *streamed*, not what is useful;
* **lockstep** groups requests FIFO into static batches of ``n_slots``,
  pads prompts to the batch max, and decodes every member until the
  batch max_tokens finishes (the old engine's semantics);
* **continuous** admits per slot (exact prompts, no padding) and counts
  its real measured decode steps — idle-slot tail steps included.

Modeled tokens/sec is useful tokens per unit; the ratio is asserted
>= 1.5x and written to ``BENCH_serve.json`` (with measured wall-clock
numbers alongside) so the serving trajectory is machine-readable across
PRs; the pallas-interpret CI job uploads it as an artifact.

The paged sections extend the trace with one long-prompt request and
score the block-paged KV pool: admitted capacity at equal pool bytes
(asserted >= 2x the dense-rows engine), the decode stall chunked
prefill bounds (asserted below the unchunked run's), prefix sharing
(the shared prefix prefills exactly once, counter-asserted), and the
same bit-identical-to-solo-greedy oracle with paging enabled.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import transformer as T
from repro.serve import paging
from repro.serve.engine import (ACCEPTANCE_TRACE, DecodeEngine, Request,
                                acceptance_requests, solo_greedy)

BENCH_JSON = os.environ.get("REPRO_SERVE_BENCH_JSON", "BENCH_serve.json")

PROMPT_LENS = tuple(p for p, _ in ACCEPTANCE_TRACE)
MAX_TOKENS = tuple(mt for _, mt in ACCEPTANCE_TRACE)
N_SLOTS = 2
SPEEDUP_FLOOR = 1.5

#: the acceptance trace plus one long-prompt request — the ragged mix
#: where per-slot dense max_len rows waste the most cache
LONG_TRACE = ACCEPTANCE_TRACE + ((8, 8), (96, 8))
PAGE_SIZE = 16
CAPACITY_FLOOR = 2.0            # paged admitted tokens / dense, asserted
PREFILL_CHUNK = 16


def lockstep_units(prompt_lens, max_tokens, n_slots) -> dict:
    """Work model of the old lockstep engine: FIFO static batches of
    ``n_slots``; prompts pad to the batch max; every member decodes
    until the batch's slowest request finishes."""
    prefill = decode_steps = 0
    for i in range(0, len(prompt_lens), n_slots):
        pls = prompt_lens[i:i + n_slots]
        mts = max_tokens[i:i + n_slots]
        prefill += max(pls) * len(pls)          # padded prompt tokens
        decode_steps += max(mts) - 1            # first token rides prefill
    return {"prefill_tokens": prefill, "decode_steps": decode_steps,
            "slot_token_units": prefill + decode_steps * n_slots}


def _long_requests(vocab: int, seed: int = 0) -> list:
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_tokens=mt) for p, mt in LONG_TRACE]


def _fifo_admitted(needs, prompts, dense_len, usable_pages):
    """FIFO head-of-line admitted tokens at equal pool bytes: dense
    rows reserve ``dense_len`` per request; the paged pool (the real
    :class:`PagedKV` allocator) reserves only the pages each request's
    true need touches."""
    dense_tokens = free = usable_pages * PAGE_SIZE
    dense_admitted = 0
    for n in needs:
        if free < dense_len:
            break
        free -= dense_len
        dense_admitted += n
    kv = paging.PagedKV(len(needs), 1 + usable_pages, PAGE_SIZE,
                        dense_len // PAGE_SIZE, prefix_cache=False)
    paged_admitted = 0
    for slot, (n, prompt) in enumerate(zip(needs, prompts)):
        if not kv.can_admit(prompt, n):
            break
        kv.admit(slot, prompt, n)
        paged_admitted += n
    return dense_admitted, paged_admitted, dense_tokens


def _paged_sections(report, cfg, params) -> dict:
    """Paged-KV benchmark rows; returns the BENCH_serve.json subtree."""
    needs = [p + mt - 1 for p, mt in LONG_TRACE]
    dense_len = -(-max(needs) // PAGE_SIZE) * PAGE_SIZE
    usable_pages = N_SLOTS * dense_len // PAGE_SIZE

    # --- capacity at equal pool bytes: what FIFO admission fits
    dense_adm, paged_adm, pool_tokens = _fifo_admitted(
        needs, [r.prompt for r in _long_requests(cfg.vocab)],
        dense_len, usable_pages)
    cap_ratio = paged_adm / dense_adm
    report.row("serve",
               f"paged capacity at equal pool bytes ({pool_tokens} tok)",
               dense_admitted_tokens=dense_adm,
               paged_admitted_tokens=paged_adm,
               ratio=f"{cap_ratio:.2f}x",
               ok=cap_ratio >= CAPACITY_FLOOR)

    # --- solo oracles for the long trace
    solo = [solo_greedy(params, cfg, r.prompt, r.max_tokens, dense_len)
            for r in _long_requests(cfg.vocab)]

    def paged_run(**kw):
        eng = DecodeEngine(params, cfg, batch=N_SLOTS, max_len=dense_len,
                           page_size=PAGE_SIZE, n_pages=1 + usable_pages,
                           prefix_cache=False, **kw)
        res = {r.rid: r for r in eng.run(_long_requests(cfg.vocab))}
        exact = sum(bool(np.array_equal(res[i].tokens, solo[i]))
                    for i in range(len(solo)))
        return eng, res, exact

    eng_u, _, exact_u = paged_run()
    report.row("serve", "paged ragged trace vs solo batch-1 (greedy)",
               bit_identical=f"{exact_u}/{len(solo)}",
               ok=exact_u == len(solo))

    # --- chunked prefill bounds the decode stall the long prompt causes
    eng_c, res_c, exact_c = paged_run(prefill_chunk=PREFILL_CHUNK)
    stall_u = eng_u.metrics["max_prefill_stall_tokens"]
    stall_c = eng_c.metrics["max_prefill_stall_tokens"]
    report.row("serve",
               f"chunked prefill ({PREFILL_CHUNK} tok) decode stall",
               unchunked_stall=stall_u, chunked_stall=stall_c,
               chunks=max(r.prefill_chunks for r in res_c.values()),
               ok=stall_c < stall_u and stall_c <= PREFILL_CHUNK
               and exact_c == len(solo))

    # --- honest KV billing: true positions (page-rounded) vs what the
    # dense engine's max_len rows stream per step
    kv_true = eng_u.metrics["modeled_kv_bytes"]
    kv_dense = eng_u.metrics["modeled_kv_bytes_dense_rows"]
    report.row("serve", "modeled decode KV stream, paged vs dense rows",
               paged_mib=f"{kv_true / 2**20:.2f}",
               dense_rows_mib=f"{kv_dense / 2**20:.2f}",
               ratio=f"{kv_true / kv_dense:.2f}x", ok=kv_true < kv_dense)

    # --- prefix sharing: a 32-token shared prefix prefills exactly once
    rng = np.random.default_rng(7)
    pre = rng.integers(0, cfg.vocab, (2 * PAGE_SIZE,)).astype(np.int32)
    prompts = [np.concatenate([pre, rng.integers(0, cfg.vocab, (8,))
                               .astype(np.int32)]) for _ in range(2)]
    solo_p = [solo_greedy(params, cfg, p, 8, dense_len) for p in prompts]
    eng_p = DecodeEngine(params, cfg, batch=N_SLOTS, max_len=dense_len,
                         page_size=PAGE_SIZE, n_pages=1 + usable_pages)
    res_p = {r.rid: r for r in eng_p.run(
        [Request(prompt=p, max_tokens=8) for p in prompts])}
    exact_p = sum(bool(np.array_equal(res_p[i].tokens, solo_p[i]))
                  for i in range(2))
    mp = eng_p.metrics
    total_prompt = sum(int(p.shape[0]) for p in prompts)
    prefilled_once = mp["prefill_tokens"] == total_prompt - 2 * PAGE_SIZE
    report.row("serve", "prefix sharing (32-token shared prefix)",
               prefill_tokens=mp["prefill_tokens"],
               shared_tokens=mp["shared_prompt_tokens"],
               hits=mp["prefix_hits"], bit_identical=f"{exact_p}/2",
               ok=prefilled_once and mp["prefix_hits"] == 1
               and exact_p == 2)

    return {
        "trace": {"prompt_lens": [p for p, _ in LONG_TRACE],
                  "max_tokens": [mt for _, mt in LONG_TRACE],
                  "page_size": PAGE_SIZE, "pool_tokens": pool_tokens,
                  "prefill_chunk": PREFILL_CHUNK},
        "capacity": {"dense_admitted_tokens": dense_adm,
                     "paged_admitted_tokens": paged_adm,
                     "ratio": cap_ratio},
        "stall": {"unchunked": stall_u, "chunked": stall_c},
        "modeled_kv_bytes": {"paged": kv_true, "dense_rows": kv_dense,
                             "ratio": kv_true / kv_dense},
        "prefix": {"prefill_tokens": int(mp["prefill_tokens"]),
                   "shared_tokens": int(mp["shared_prompt_tokens"]),
                   "hits": int(mp["prefix_hits"])},
        "bit_identical": exact_u == len(solo) and exact_c == len(solo),
    }


def run(report) -> None:
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(p + mt for p, mt in zip(PROMPT_LENS, MAX_TOKENS)) + 1
    reqs = acceptance_requests(cfg.vocab)
    useful = sum(MAX_TOKENS)

    engine = DecodeEngine(params, cfg, batch=N_SLOTS, max_len=max_len)
    # warm-up: compile every prompt-length bucket + the step, so the
    # measured numbers exclude jit compilation
    engine.run(acceptance_requests(cfg.vocab, seed=1))
    engine.reset_metrics()

    t0 = time.perf_counter()
    results = {r.rid: r for r in engine.run(reqs)}
    wall = time.perf_counter() - t0

    # --- correctness: bit-identical to each request alone at batch 1
    exact = 0
    for req in reqs:
        want = solo_greedy(params, cfg, req.prompt, req.max_tokens,
                           max_len)
        if np.array_equal(results[req.rid].tokens, want):
            exact += 1
    report.row("serve", "ragged trace vs solo batch-1 (greedy)",
               bit_identical=f"{exact}/{len(reqs)}",
               ok=exact == len(reqs))

    # --- modeled work: slot-token units (see module docstring)
    m = engine.metrics
    cont_units = m["prefill_tokens"] + m["decode_steps"] * N_SLOTS
    lock = lockstep_units(PROMPT_LENS, MAX_TOKENS, N_SLOTS)
    cont_tps = useful / cont_units              # tokens per unit
    lock_tps = useful / lock["slot_token_units"]
    speedup = cont_tps / lock_tps
    occupancy = engine.occupancy()
    report.row("serve",
               f"continuous vs lockstep, {N_SLOTS} slots (modeled)",
               cont_units=cont_units,
               lockstep_units=lock["slot_token_units"],
               speedup=f"{speedup:.2f}x",
               occupancy=f"{occupancy:.2f}",
               ok=speedup >= SPEEDUP_FLOOR)
    report.row("serve", "measured wall-clock (smoke, CPU)",
               tok_s=f"{useful / wall:.1f}",
               decode_tok_s=f"{engine.tokens_per_sec():.1f}",
               steps=m["decode_steps"], ok=True)

    # --- per-request latency breakdown: TTFT + queue wait from the
    # engine's request lifecycle (every acceptance request arrives at
    # t=0, so queue wait here IS the scheduler's admission delay)
    ttfts = np.asarray([results[r.rid].ttft for r in reqs])
    qwaits = np.asarray([results[r.rid].queue_wait for r in reqs])
    per_request = [
        {"rid": r.rid, "prompt_len": int(r.prompt.shape[0]),
         "n_tokens": results[r.rid].n_tokens,
         "ttft_s": round(float(results[r.rid].ttft), 6),
         "queue_wait_s": round(float(results[r.rid].queue_wait), 6)}
        for r in reqs
    ]
    report.row("serve", "request latency breakdown",
               ttft_mean_ms=f"{ttfts.mean()*1e3:.1f}",
               ttft_p99_ms=f"{np.percentile(ttfts, 99)*1e3:.1f}",
               queue_wait_mean_ms=f"{qwaits.mean()*1e3:.1f}",
               ok=bool((ttfts > 0).all()))

    payload = {
        "trace": {"prompt_lens": PROMPT_LENS, "max_tokens": MAX_TOKENS,
                  "n_slots": N_SLOTS, "useful_tokens": useful},
        "paged": _paged_sections(report, cfg, params),
        "continuous": {
            "prefill_tokens": m["prefill_tokens"],
            "decode_steps": m["decode_steps"],
            "slot_token_units": cont_units,
            "occupancy": occupancy,
            "modeled_tokens_per_unit": cont_tps,
            "measured_tok_s": useful / wall,
            "measured_decode_tok_s": engine.tokens_per_sec(),
        },
        "lockstep": dict(lock, modeled_tokens_per_unit=lock_tps),
        "modeled_speedup": speedup,
        "bit_identical": exact == len(reqs),
        "requests": per_request,
        "latency": {
            "ttft_mean_s": float(ttfts.mean()),
            "ttft_p99_s": float(np.percentile(ttfts, 99)),
            "queue_wait_mean_s": float(qwaits.mean()),
            "queue_wait_p99_s": float(np.percentile(qwaits, 99)),
        },
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    report.row("serve", f"wrote {BENCH_JSON}",
               modeled_speedup=f"{speedup:.2f}x", ok=True)
