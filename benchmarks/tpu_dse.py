"""The adapted technique: reuse-maximizing tiling DSE on TPU v5e.

Runs the paper's IP/DSE formulation (lifted onto the HBM->VMEM hierarchy)
over the GEMM problems the assigned architectures actually produce —
per-arch projection shapes at the train_4k per-device scale plus the
paper's own square sweep — and reports, per problem, the winning
(strategy, bm, bk, bn), modeled arithmetic intensity, HBM traffic and
the roofline bound, exactly as Tables III/IV report (design, reuse, BW,
throughput) for the FPGAs.
"""

from __future__ import annotations

from repro.configs.base import ARCH_IDS, get_config
from repro.core import dse
from repro.core.hardware import TPU_V5E
from repro.core.tiling import GemmProblem

# per-device M for train_4k on the 16x16 mesh: (256/16) rows x 4096 seq
M_TRAIN = 16 * 4096


def arch_problems():
    """The dominant per-device projection GEMMs per architecture.

    Dense archs: d_ff/heads shard over the 16-way 'model' axis (TP).
    MoE archs: experts shard over 'model' (EP), so the per-expert GEMM
    keeps the full d_ff but sees only top_k/n_experts of the tokens —
    these come out *memory-bound* (skinny M), which is exactly the
    expert-dispatch bottleneck the §Perf pass attacks.
    """
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        tp = 16
        if cfg.n_experts:
            m_exp = max(M_TRAIN * cfg.top_k // cfg.n_experts, 8)
            out.append((f"{arch}:expert_ffn",
                        GemmProblem(m_exp, cfg.d_model, cfg.d_ff)))
        else:
            d_ff = cfg.d_ff if cfg.d_ff else cfg.d_model * 2
            out.append((f"{arch}:ffn_up",
                        GemmProblem(M_TRAIN, cfg.d_model,
                                    max(d_ff // tp, 128))))
        out.append((f"{arch}:attn_qkv",
                    GemmProblem(M_TRAIN, cfg.d_model,
                                max(cfg.n_heads * cfg.hd // tp, 128))))
    return out


def square_problems():
    return [(f"square_{s}", GemmProblem(s, s, s, "int8", "int8", "int32"))
            for s in (512, 2048, 8192)]


def run(report) -> None:
    chip = TPU_V5E
    for name, p in arch_problems() + square_problems():
        designs = dse.solve(p, chip, top=3)
        best = designs[0]
        t = best.tile
        # sanity gates: feasible, MXU-aligned, VMEM within budget,
        # and for the big square problems the DSE must find a
        # compute-bound tiling (arithmetic intensity above the ridge)
        ridge = (chip.peak_int8_ops if p.in_dtype == "int8"
                 else chip.peak_bf16_flops) / chip.hbm_bw
        ok = (t.mxu_aligned(chip)
              and best.vmem_bytes <= 0.75 * chip.vmem_bytes)
        if name.startswith("square") and p.m >= 2048:
            # large square GEMMs must tile compute-bound (paper regime)
            ok = ok and best.traffic.bound == "compute"
        report.row(
            "tpu_dse", name,
            tile=f"{t.strategy} {t.bm}x{t.bk}x{t.bn}",
            vmem=f"{best.vmem_bytes/2**20:.1f}MiB eff={best.vmem_eff:.2f}",
            traffic=f"AI={best.traffic.arithmetic_intensity:.0f} "
                    f"(ridge {ridge:.0f}) bound={best.traffic.bound}",
            ok=ok)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
