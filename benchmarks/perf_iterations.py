"""§Perf: hillclimb before/after tables from dry-run artifacts.

Three hillclimbed cells (chosen per the task sheet):

* **kimi-k2-1t-a32b × train_4k** — most collective-bound cell of the
  baseline table.  Change: shard_map expert-parallel dispatch
  (``REPRO_MOE_EP``); the 'before' record is regenerated under
  ``REPRO_MOE_EP=0`` into ``artifacts/ablations/no_ep``.
* **deepseek-67b × train_4k** — the remat-carry memory wall.  Change:
  sequence-parallel residual stream (``REPRO_TRAIN_SP``); 'before' under
  ``REPRO_TRAIN_SP=0`` in ``artifacts/ablations/no_sp``.
* **deepseek-67b × prefill_32k** — most representative of the paper's
  technique (pure GEMM+attention throughput, memory-dominated by the XLA
  blocked-attention lowering).  Change: Pallas flash-attention kernel —
  validated numerically in interpret mode (tests/test_kernels.py); its
  HBM traffic is deterministic (q/k/v/o streamed once per pass), so the
  'after' memory term substitutes the kernel's analytic traffic for the
  measured ``attention_blocked``/``_where`` scopes
  (:func:`flash_substituted`).

``repro.launch.dryrun`` wrote every record; this module only reads.
"""

from __future__ import annotations

import json
import os
from typing import Optional

from repro.configs.base import get_config
from repro.core.hardware import TPU_V5E
from repro.launch.shapes import SHAPES

ART = os.environ.get("REPRO_DRYRUN_DIR", "artifacts/dryrun")
ABL = os.environ.get("REPRO_ABLATION_DIR", "artifacts/ablations")

# scopes whose traffic the flash kernel eliminates (materialized scores,
# softmax intermediates, masking selects)
ATTN_SCOPES = ("attention_blocked", "_where", "flash_attention")


def _load(path: str) -> Optional[dict]:
    try:
        return json.load(open(path))
    except FileNotFoundError:
        return None


def cell(mesh: str, arch: str, shape: str, base: str = ART
         ) -> Optional[dict]:
    return _load(os.path.join(base, mesh, f"{arch}__{shape}.json"))


def flash_attention_bytes(arch: str, shape_name: str, *,
                          training: bool, tp: int = 16,
                          batch_shards: int = 16) -> float:
    """Analytic per-device HBM traffic of the Pallas flash kernel for
    every attention layer of one step.

    Per pass the kernel streams q, k, v once and writes o at storage
    dtype (online-softmax state lives in VMEM scratch).  Training ~4
    fwd-equivalent passes (fwd + remat recompute + bwd reading
    q,k,v,o,dO and writing dq,dk,dv); inference 1.  Heads shard over the
    16-way model axis when their projection dim divides; else they stay
    replicated (smollm) — matching the layout engine's relaxation.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b_dev = max(shape.global_batch // batch_shards, 1)
    s = shape.seq_len
    dt = 2  # bf16

    def shard(heads: int) -> float:
        dim = heads * cfg.hd
        return dim / tp if dim % tp == 0 else dim

    q_bytes = b_dev * s * shard(cfg.n_heads) * dt
    kv_bytes = 2 * b_dev * s * shard(cfg.n_kv_heads) * dt
    per_layer = 2 * q_bytes + kv_bytes          # q + o + k + v
    attn_layers = sum(
        (cfg.repeats if i < len(cfg.layer_pattern) else 1)
        for i, k in enumerate(cfg.layer_pattern + cfg.tail_pattern)
        if k in ("attn", "local", "moe"))
    passes = 4.0 if training else 1.0
    return per_layer * attn_layers * passes


def flash_substituted(rec: dict) -> dict:
    """Memory term with the attention scopes' measured traffic replaced
    by the flash kernel's analytic traffic."""
    scopes = rec.get("bytes_by_scope", {})
    attn_measured = sum(scopes.get(s, 0.0) for s in ATTN_SCOPES)
    kernel = flash_attention_bytes(
        rec["arch"], rec["shape"], training=(rec["kind"] == "train"))
    total = rec["roofline"]["hbm_bytes_per_device"]
    new_bytes = total - attn_measured + kernel
    t_mem = new_bytes / TPU_V5E.hbm_bw
    r = rec["roofline"]
    t_bound = max(r["t_compute"], t_mem, r["t_collective"])
    return {
        "attn_scope_bytes": attn_measured,
        "flash_kernel_bytes": kernel,
        "hbm_bytes": new_bytes,
        "t_memory": t_mem,
        "roofline_fraction": r["t_compute"] / t_bound if t_bound else 0.0,
        "dominant": max(
            (("compute", r["t_compute"]), ("memory", t_mem),
             ("collective", r["t_collective"])), key=lambda kv: kv[1])[0],
    }


def _fmt(rec: dict) -> str:
    r = rec["roofline"]
    mem = rec["memory_analysis"]["peak_bytes_per_device"] / 2 ** 30
    return (f"peak={mem:.1f}GiB t=(c {r['t_compute']:.2f} / m "
            f"{r['t_memory']:.2f} / x {r['t_collective']:.2f})s "
            f"dom={r['dominant']} frac={r['roofline_fraction']:.3f}")


def run(report) -> None:
    # hillclimb A: EP dispatch
    for arch in ("kimi-k2-1t-a32b", "qwen3-moe-235b-a22b"):
        before = cell("single", arch, "train_4k", ABL + "/no_ep")
        after = cell("single", arch, "train_4k")
        if before and after and before.get("ok") and after.get("ok"):
            rb, ra = before["roofline"], after["roofline"]
            gain = rb["t_collective"] / max(ra["t_collective"], 1e-9)
            report.row("perf", f"EP-dispatch {arch}/train_4k",
                       before=_fmt(before), after=_fmt(after),
                       coll_x=f"{gain:.1f}x", ok=gain > 2.0)
    # hillclimb B: sequence parallelism
    before = cell("single", "deepseek-67b", "train_4k", ABL + "/no_sp")
    after = cell("single", "deepseek-67b", "train_4k")
    if before and after and before.get("ok") and after.get("ok"):
        mb = before["memory_analysis"]["peak_bytes_per_device"]
        ma = after["memory_analysis"]["peak_bytes_per_device"]
        report.row("perf", "seq-parallel deepseek-67b/train_4k",
                   before=_fmt(before), after=_fmt(after),
                   peak_x=f"{mb/ma:.1f}x", ok=mb / ma > 2.0)
    # hillclimb C: flash-attention substitution on the prefill cell
    rec = cell("single", "deepseek-67b", "prefill_32k")
    if rec and rec.get("ok"):
        sub = flash_substituted(rec)
        r = rec["roofline"]
        report.row(
            "perf", "flash-kernel deepseek-67b/prefill_32k",
            before=f"t_mem={r['t_memory']:.1f}s "
                   f"frac={r['roofline_fraction']:.3f}",
            after=f"t_mem={sub['t_memory']:.1f}s "
                  f"frac={sub['roofline_fraction']:.3f} "
                  f"dom={sub['dominant']}",
            attn_bytes=f"{sub['attn_scope_bytes']:.2e}->"
                       f"{sub['flash_kernel_bytes']:.2e}",
            ok=sub["t_memory"] < 0.7 * r["t_memory"])


def markdown() -> str:
    """§Perf summary table for EXPERIMENTS.md."""
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    lines = ["| iteration | before | after | gain | ok |",
             "|---|---|---|---|---|"]
    for r in rep.rows:
        extra = [f"{k}={v}" for k, v in r.items()
                 if k not in ("bench", "name", "ok", "before", "after")]
        lines.append(f"| {r['name']} | {r.get('before','')} | "
                     f"{r.get('after','')} | {' '.join(extra)} | "
                     f"{'yes' if r['ok'] else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
