"""Benchmark harness — one module per paper table/figure plus the
TPU-adapted DSE, GEMM micro-bench and the dry-run roofline report.

    PYTHONPATH=src python -m benchmarks.run [--only table3,tpu_dse]

Every row prints ``bench,name,key=value,...,ok``; the process exits
non-zero if any row fails its check, so this doubles as an integration
gate (paper-fidelity regression suite).
"""

from __future__ import annotations

import argparse
import importlib
import os
import sys
import time
from typing import List

from repro import telemetry

MODULES = (
    "table2_memory_model",
    "table3_versal_dse",
    "table4_stratix_dse",
    "fig7_scalability",
    "tpu_dse",
    "gemm_bench",
    "serve_bench",
    "roofline_report",
    "perf_iterations",
)


class Report:
    def __init__(self):
        self.rows: List[dict] = []

    def row(self, bench: str, name: str, ok: bool = True, **fields):
        self.rows.append(dict(bench=bench, name=name, ok=ok, **fields))

    @property
    def failures(self) -> int:
        return sum(1 for r in self.rows if not r["ok"])

    def print(self) -> None:
        for r in self.rows:
            extra = ",".join(f"{k}={v}" for k, v in r.items()
                             if k not in ("bench", "name", "ok"))
            status = "ok" if r["ok"] else "FAIL"
            print(f"{r['bench']},{r['name']},{extra},{status}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    args = ap.parse_args()
    mods = args.only.split(",") if args.only else MODULES

    # REPRO_TELEMETRY=PATH records one shared telemetry stream across
    # every benchmark module and exports PATH.jsonl + PATH.trace.json
    # (CI uploads these next to the BENCH_*.json artifacts)
    telemetry_base = os.environ.get("REPRO_TELEMETRY")
    if telemetry_base:
        telemetry.enable()

    report = Report()
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        t0 = time.time()
        try:
            mod.run(report)
        except Exception as e:                      # pragma: no cover
            report.row(name, "run", ok=False, error=repr(e)[:200])
        print(f"# {name} ({time.time()-t0:.1f}s)", file=sys.stderr)
    if telemetry_base:
        snap = telemetry.snapshot()
        paths = telemetry.export(telemetry_base)
        print(f"# telemetry: {snap['n_events']} events, plan cache "
              f"{snap['plan_cache']}; wrote {paths[0]} and {paths[1]}",
              file=sys.stderr)
    report.print()
    n_fail = report.failures
    print(f"# {len(report.rows)} rows, {n_fail} failures",
          file=sys.stderr)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
