"""Paper Table III: 10 top-ranked GEMM designs on Versal VC1902.

For each published row: rebuild the design from (U,V,W, pattern), check
the analytical model reproduces the published BRAM/URAM counts (within
the implementation-overhead tolerance), RAM efficiency, throughput at the
published PL frequency, energy efficiency (using published power), and
the worst-case DDR bandwidth column; apply the paper's 102.4 GB/s DDR
feasibility gate.
"""

from __future__ import annotations

from repro.core import paper_model as pm
from repro.core.paper_tables import (
    VERSAL_DDR_LIMIT_GIBPS,
    VERSAL_TABLE3,
)


def rows():
    out = []
    for ref in VERSAL_TABLE3:
        sol = pm.MAXEVA_P1 if ref.pattern == "P1" else pm.MAXEVA_P2
        geom = pm.versal_buffer_geometry(sol, ref.u, ref.v, ref.w)
        found = pm.versal_best_mapping(geom)
        mapping, brams, urams = found
        thr = pm.versal_throughput_ops(sol, ref.pl_freq_mhz * 1e6)
        bw = pm.bytes_to_gibps(pm.versal_bw_bytes(
            sol, ref.u, ref.v, ref.w, thr))
        ram_eff = pm.versal_ram_efficiency(geom, ref.mapping or mapping)
        native = sol.native_buffer(ref.u, ref.v, ref.w)
        out.append({
            "design": f"{ref.u}x{ref.v}x{ref.w} ({ref.pattern})",
            "native": native, "ref_native": ref.native_buffer,
            "tops": thr / 1e12, "ref_tops": ref.throughput_tops,
            "eff": thr / 1e12 / ref.power_w, "ref_eff": ref.energy_eff,
            "ram_eff": ram_eff, "ref_ram_eff": ref.ram_eff,
            "bw": bw, "ref_bw": ref.bw_gibps,
            "bw_feasible": bw <= VERSAL_DDR_LIMIT_GIBPS * 1.005,
            "ref_feasible": ref.bw_gibps <= VERSAL_DDR_LIMIT_GIBPS * 1.08,
            "brams": brams, "ref_brams": ref.brams,
            "urams": urams, "ref_urams": ref.urams,
            "aie_cores": sol.aie_cores, "ref_aie": ref.aie_cores,
        })
    return out


def run(report) -> None:
    for r in rows():
        thr_err = abs(r["tops"] - r["ref_tops"]) / r["ref_tops"]
        bw_err = abs(r["bw"] - r["ref_bw"]) / r["ref_bw"]
        ram_err = abs(r["ram_eff"] - r["ref_ram_eff"])
        ok = (r["native"] == r["ref_native"] and thr_err < 0.01
              and bw_err < 0.02 and ram_err < 0.005
              and r["aie_cores"] == r["ref_aie"])
        report.row(
            "table3", r["design"],
            model=f"{r['tops']:.2f} TOPs {r['eff']:.3f} TOPs/W "
                  f"RAMeff={100*r['ram_eff']:.1f}% BW={r['bw']:.1f}",
            reference=f"{r['ref_tops']:.2f} TOPs {r['ref_eff']:.3f} "
                      f"TOPs/W RAMeff={100*r['ref_ram_eff']:.1f}% "
                      f"BW={r['ref_bw']:.1f}",
            gate=("OK" if r["bw_feasible"] else "REJECT>102.4GB/s"),
            ok=ok)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
