"""Paper Table IV: 10 top-ranked GEMM designs on Stratix 10 NX.

For each published (TBlen x Kp x Np x Mp) row: rebuild the TB layout,
check the compute-GEMM algebra, the eq. 9-14 M20K geometry against the
published count, throughput at the published frequency, energy
efficiency, RAM efficiency, and worst-case bandwidth.
"""

from __future__ import annotations

from repro.core import paper_model as pm
from repro.core.paper_tables import STRATIX_TABLE4


def rows():
    out = []
    for ref in STRATIX_TABLE4:
        lay = pm.TBLayout(ref.tb_len, ref.kp, ref.np_, ref.mp)
        geom = pm.stratix_check_design(lay, ref.native_buffer)
        thr = pm.stratix_throughput_ops(lay, ref.freq_mhz * 1e6)
        bw = pm.bytes_to_gibps(pm.stratix_bw_bytes(
            *ref.native_buffer, thr))
        ram_eff = pm.stratix_ram_efficiency(geom, m20ks=ref.brams)
        out.append({
            "design": f"{ref.tb_len}x{ref.kp}x{ref.np_}x{ref.mp}",
            "compute": lay.compute_gemm, "ref_compute": ref.compute_gemm,
            "tbs": lay.tbs, "ref_tbs": ref.tbs,
            "m20k": geom.m20ks, "ref_m20k": ref.brams,
            "tops": thr / 1e12, "ref_tops": ref.throughput_tops,
            "eff": thr / 1e12 / ref.power_w, "ref_eff": ref.energy_eff,
            "ram_eff": ram_eff, "ref_ram_eff": ref.ram_eff,
            "bw": bw, "ref_bw": ref.bw_gibps,
        })
    return out


def run(report) -> None:
    for r in rows():
        thr_err = abs(r["tops"] - r["ref_tops"]) / r["ref_tops"]
        bw_err = abs(r["bw"] - r["ref_bw"]) / r["ref_bw"]
        # RAM-eff tolerance 0.01: the paper's printed efficiencies use
        # *implemented* M20K counts, which exceed the eq. 12/14 model by
        # up to ~3% on some rows (extra FIFO/control blocks).
        ok = (r["compute"] == r["ref_compute"] and r["tbs"] == r["ref_tbs"]
              and thr_err < 0.005 and bw_err < 0.02
              and abs(r["ram_eff"] - r["ref_ram_eff"]) < 0.01
              and r["m20k"] <= r["ref_m20k"])
        report.row(
            "table4", r["design"],
            model=f"{r['tops']:.2f} TOPs {r['eff']:.3f} TOPs/W "
                  f"RAMeff={100*r['ram_eff']:.1f}% BW={r['bw']:.1f} "
                  f"M20K={r['m20k']}",
            reference=f"{r['ref_tops']:.2f} TOPs {r['ref_eff']:.3f} "
                      f"TOPs/W RAMeff={100*r['ref_ram_eff']:.1f}% "
                      f"BW={r['ref_bw']:.1f} M20K={r['ref_m20k']}",
            ok=ok)


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
