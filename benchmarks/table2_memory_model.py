"""Paper Table II: memory-model estimates vs Vitis HLS AUTO mapping.

Reproduces, for the four published (U,V,W,pattern) solutions: the model's
{A,B,C}->{BRAM,URAM} mapping, its exact BRAM/URAM counts, the HLS-AUTO
counts, and whether AUTO over-allocates URAM beyond the device (the
paper's PnR-failure mode on 5/10 top designs).
"""

from __future__ import annotations

from repro.core import paper_model as pm
from repro.core.hardware import VERSAL_VC1902
from repro.core.paper_tables import VERSAL_TABLE2


def rows():
    out = []
    for ref in VERSAL_TABLE2:
        sol = pm.MAXEVA_P1 if ref.pattern == "P1" else pm.MAXEVA_P2
        geom = pm.versal_buffer_geometry(sol, ref.u, ref.v, ref.w)
        mapping, brams, urams = pm.versal_best_mapping(geom)
        auto_map, a_brams, a_urams, fails = pm.versal_hls_auto_mapping(geom)
        out.append({
            "design": f"{ref.u}x{ref.v}x{ref.w} ({ref.pattern})",
            "model_mapping": "".join(mapping),
            "model_brams": int(brams), "model_urams": int(urams),
            "ref_brams": ref.model_brams, "ref_urams": ref.model_urams,
            "auto_brams": int(a_brams), "auto_urams": int(a_urams),
            "ref_auto_brams": ref.auto_brams,
            "ref_auto_urams": ref.auto_urams,
            "auto_fails": fails, "ref_auto_fails": ref.auto_fails,
            "match": (int(brams) == ref.model_brams
                      and int(urams) == ref.model_urams
                      and "".join(mapping) == "".join(ref.mapping)
                      and int(a_urams) == ref.auto_urams
                      and fails == ref.auto_fails),
        })
    return out


def run(report) -> None:
    b36, u288 = VERSAL_VC1902.bram_36k, VERSAL_VC1902.uram_288k
    for r in rows():
        report.row(
            "table2", r["design"],
            model=f"{r['model_mapping']} B={r['model_brams']} "
                  f"({100*r['model_brams']/b36:.0f}%) "
                  f"U={r['model_urams']} ({100*r['model_urams']/u288:.0f}%)",
            reference=f"B={r['ref_brams']} U={r['ref_urams']}",
            auto=f"B={r['auto_brams']} U={r['auto_urams']}"
                 f"{' FAILS-PnR' if r['auto_fails'] else ''}",
            ok=r["match"])


if __name__ == "__main__":
    from benchmarks.run import Report
    rep = Report()
    run(rep)
    rep.print()
