"""``repro.ops`` — the public operator API.

The GEMM family is the declarative planned pipeline from
:mod:`repro.kernels.api`:

    spec = ops.GemmSpec.for_operands(x, w, residual=r)   # or GemmSpec(...)
    pl   = ops.plan(spec, ops.gemm_shapes(x, w))         # cached, once
    y    = ops.execute(pl, x, w, residual=r)
    print(pl.explain())                                  # kernel/tile/bytes

or the one-shot form every model layer uses (identical dispatch — it
builds the spec and goes through the same plan cache):

    y = ops.gemm(x, w, residual=r)

The grouped ragged family member (the MoE expert sweep) is
``ops.gemm_grouped(xs, bank, group_sizes)`` — same spec/plan/execute
pipeline with the extended ``gemm_grouped_shapes`` plan key.

Attention is the same framework applied to the second hot-spot
(:mod:`repro.kernels.attn_api`):

    spec = ops.AttnSpec(mode="decode", group=6)
    pl   = ops.attn_plan(spec, (b, skv, hq, hkv, d))
    o    = ops.attn_execute(pl, q, k_cache, v_cache, pos=pos)

with the one-shots ``ops.attention`` / ``ops.decode_attention`` /
``ops.decode_attention_paged`` building the spec from live operands.
The pre-redesign entrypoints (``gemm_fused``/``gemm_gated``/
``gemm_int8``, the old ``gemm``, and the same-named attention trio)
live on as deprecated shims in :mod:`repro.kernels.ops`.
"""

from repro.kernels.api import (  # noqa: F401
    GemmPlan,
    GemmSpec,
    PlanCacheInfo,
    TunedInfo,
    execute,
    gemm,
    gemm_grouped,
    gemm_grouped_shapes,
    gemm_shapes,
    plan,
    plan_cache_clear,
    plan_cache_info,
    plans,
    solve_topk,
    use_pallas,
)
from repro.kernels.attn_api import (  # noqa: F401
    BLOCKED_ATTN_THRESHOLD,
    AttnPlan,
    AttnPlanCacheInfo,
    AttnProblem,
    AttnSpec,
    attention,
    attn_execute,
    attn_plan,
    attn_plan_cache_clear,
    attn_plan_cache_info,
    attn_plans,
    attn_solve_topk,
    decode_attention,
    decode_attention_paged,
)
from repro.kernels.epilogue import ACTIVATIONS, Epilogue  # noqa: F401
from repro.kernels.ops import dequantize, quantize_int8  # noqa: F401
from repro.core.tiling import TileConfig  # noqa: F401
