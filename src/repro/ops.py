"""``repro.ops`` — the public operator API.

The GEMM family is the declarative planned pipeline from
:mod:`repro.kernels.api`:

    spec = ops.GemmSpec.for_operands(x, w, residual=r)   # or GemmSpec(...)
    pl   = ops.plan(spec, ops.gemm_shapes(x, w))         # cached, once
    y    = ops.execute(pl, x, w, residual=r)
    print(pl.explain())                                  # kernel/tile/bytes

or the one-shot form every model layer uses (identical dispatch — it
builds the spec and goes through the same plan cache):

    y = ops.gemm(x, w, residual=r)

The grouped ragged family member (the MoE expert sweep) is
``ops.gemm_grouped(xs, bank, group_sizes)`` — same spec/plan/execute
pipeline with the extended ``gemm_grouped_shapes`` plan key.

Attention and the quantization helpers ride along so model code needs a
single ``from repro import ops``.  The pre-redesign entrypoints
(``gemm_fused``/``gemm_gated``/``gemm_int8`` and the old ``gemm``) live
on as deprecated shims in :mod:`repro.kernels.ops`.
"""

from repro.kernels.api import (  # noqa: F401
    GemmPlan,
    GemmSpec,
    PlanCacheInfo,
    TunedInfo,
    execute,
    gemm,
    gemm_grouped,
    gemm_grouped_shapes,
    gemm_shapes,
    plan,
    plan_cache_clear,
    plan_cache_info,
    plans,
    solve_topk,
    use_pallas,
)
from repro.kernels.epilogue import ACTIVATIONS, Epilogue  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    BLOCKED_ATTN_THRESHOLD,
    attention,
    decode_attention,
    decode_attention_paged,
    dequantize,
    quantize_int8,
)
from repro.core.tiling import TileConfig  # noqa: F401
