"""The shared on-device measurement harness — the *measured* half of
every model-vs-reality loop in the repo.

One plan, one number: synthesize operands matching the plan's spec
(quantized ``{q, scale}`` structs, gated dual-B, bias/residual/out-scale
epilogue terms), run it through the public ``execute`` path under
``jax.jit`` with an explicit warm-up count (compile excluded), then time
``iters`` device-synced repeats and reduce them **robustly**: outliers
are rejected by median-absolute-deviation before the median is taken, and
the surviving spread is reported so noisy hosts are *visible* instead of
silently folded into a mean.

Consumers:

* :mod:`repro.telemetry.report` — the model-vs-measured table
  (``repro-dryrun --measure``) joins each plan's modeled bytes/roofline
  time with a :class:`Measurement`.
* :mod:`repro.tune.autotune` — the top-K tile search times each analytic
  candidate with this harness and picks the measured winner.
* :mod:`repro.tune.calibrate` — every sample the tuner records regresses
  against modeled bytes/flops to fit effective hardware constants.

The ``timer`` parameter exists for determinism tests: injecting a fake
clock makes the winner selection reproducible without real devices.
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, Optional, Tuple

import numpy as np

from repro import telemetry

#: per-GEMM flop budget for measured passes — dryrun plan caches contain
#: million-token train GEMMs that would take hours on a CPU host
DEFAULT_MAX_FLOPS = 5e10

#: default repeat / warm-up counts (median-of-5 after 2 warm-up calls)
DEFAULT_ITERS = 5
DEFAULT_WARMUP = 2

#: samples farther than this many (scaled) MADs from the median are
#: rejected before the median is taken — one GC pause or page-fault storm
#: must not decide a tile search
MAD_CUTOFF = 3.0


@dataclasses.dataclass(frozen=True)
class Measurement:
    """Robust wall-clock summary of repeated plan executions."""

    times_s: Tuple[float, ...]      # every post-warm-up sample
    kept_s: Tuple[float, ...]       # samples surviving outlier rejection
    warmup: int                     # warm-up calls excluded from times_s

    @property
    def iters(self) -> int:
        return len(self.times_s)

    @property
    def rejected(self) -> int:
        return len(self.times_s) - len(self.kept_s)

    @property
    def median_s(self) -> float:
        return statistics.median(self.kept_s)

    @property
    def mean_s(self) -> float:
        return statistics.fmean(self.kept_s)

    @property
    def spread(self) -> float:
        """(max - min) / median over the kept samples — the honest
        noise-floor indicator reported next to every measured number."""
        med = self.median_s
        if not med:
            return 0.0
        return (max(self.kept_s) - min(self.kept_s)) / med


def reject_outliers(times: Tuple[float, ...],
                    cutoff: float = MAD_CUTOFF) -> Tuple[float, ...]:
    """Drop samples beyond ``cutoff`` scaled MADs from the median.  At
    least half the samples always survive (a bimodal run keeps its
    faster mode rather than rejecting everything)."""
    if len(times) <= 2:
        return tuple(times)
    med = statistics.median(times)
    mad = statistics.median(abs(t - med) for t in times)
    if mad == 0.0:
        return tuple(times)
    scaled = 1.4826 * mad           # MAD -> sigma under normality
    kept = tuple(t for t in times if abs(t - med) <= cutoff * scaled)
    if len(kept) < max(1, len(times) // 2):
        return tuple(times)
    return kept


def _rand(rng: np.random.Generator, shape, dtype: str):
    import jax.numpy as jnp
    if dtype == "int8":
        return jnp.asarray(
            rng.integers(-127, 128, shape).astype(np.int8))
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32)
                       ).astype(dtype)


def synthesize_operands(pl, rng: np.random.Generator) -> dict:
    """execute() operands matching the plan's spec — quantized weight
    structs, the gated second B, and every epilogue term it declares."""
    spec, ep = pl.spec, pl.spec.epilogue
    m, k, n = pl.m, pl.k, pl.n

    def weight():
        if spec.b_quant:
            return {"q": _rand(rng, (k, n), "int8"),
                    "scale": _rand(rng, (1, n), "float32") * 0.01 + 0.02}
        return _rand(rng, (k, n), spec.b_dtype)

    return {
        "a": _rand(rng, (m, k), spec.a_dtype),
        "b": weight(),
        "b2": weight() if spec.gated else None,
        "bias": _rand(rng, (n,), spec.a_dtype) if ep.bias else None,
        "residual": (_rand(rng, (m, n), spec.a_dtype)
                     if ep.residual else None),
        "out_scale": 0.05 if ep.out_quant else None,
    }


def measure_plan(pl, *, iters: int = DEFAULT_ITERS,
                 warmup: int = DEFAULT_WARMUP,
                 rng: Optional[np.random.Generator] = None,
                 timer: Callable[[], float] = time.perf_counter
                 ) -> Measurement:
    """Time one plan's forward execution: jit once, warm up ``warmup``
    times, then take ``iters`` individually device-synced samples and
    summarize them robustly (median after MAD outlier rejection)."""
    import jax
    from repro.kernels import api
    rng = rng or np.random.default_rng(0)
    ops = synthesize_operands(pl, rng)
    out_scale = ops["out_scale"]

    def f(a, b, b2, bias, residual):
        return api.execute(pl, a, b, b2=b2, bias=bias,
                           residual=residual, out_scale=out_scale)

    jitted = jax.jit(f)
    args = (ops["a"], ops["b"], ops["b2"], ops["bias"], ops["residual"])
    for _ in range(max(1, warmup)):          # compile + warm-up
        jax.block_until_ready(jitted(*args))
    times = []
    with telemetry.span("measure.gemm", spec=pl.spec.key,
                        m=pl.m, k=pl.k, n=pl.n, iters=iters,
                        warmup=warmup) as sp:
        for _ in range(max(1, iters)):
            t0 = timer()
            out = jitted(*args)
            jax.block_until_ready(out)
            times.append(timer() - t0)
        sp.sync(out)
    return Measurement(times_s=tuple(times),
                       kept_s=reject_outliers(tuple(times)),
                       warmup=max(1, warmup))


def synthesize_attn_operands(pl, rng: np.random.Generator) -> dict:
    """attn_execute() operands matching an :class:`AttnPlan` — dense
    q/k/v at spec dtypes for prefill, a full cache (worst-case ``pos``,
    what the plan bills) for decode, and a pool where each slot owns its
    own pages for paged decode."""
    import jax.numpy as jnp
    spec = pl.spec
    if spec.mode == "prefill":
        return {
            "q": _rand(rng, (pl.b, pl.sq, pl.hq, pl.d), spec.q_dtype),
            "k": _rand(rng, (pl.b, pl.skv, pl.hkv, pl.d), spec.kv_dtype),
            "v": _rand(rng, (pl.b, pl.skv, pl.hkv, pl.d), spec.kv_dtype),
            "pos": None, "page_table": None,
        }
    q = _rand(rng, (pl.b, pl.hq, pl.d), spec.q_dtype)
    pos = jnp.full((pl.b,), pl.skv - 1, jnp.int32)
    if spec.mode == "decode":
        kv = (pl.b, pl.skv, pl.hkv, pl.d)
        return {"q": q, "k": _rand(rng, kv, spec.kv_dtype),
                "v": _rand(rng, kv, spec.kv_dtype),
                "pos": pos, "page_table": None}
    pool = (pl.b * pl.max_pages, pl.page_size, pl.hkv, pl.d)
    table = jnp.arange(pl.b * pl.max_pages, dtype=jnp.int32
                       ).reshape(pl.b, pl.max_pages)
    return {"q": q, "k": _rand(rng, pool, spec.kv_dtype),
            "v": _rand(rng, pool, spec.kv_dtype),
            "pos": pos, "page_table": table}


def measure_attn_plan(pl, *, iters: int = DEFAULT_ITERS,
                      warmup: int = DEFAULT_WARMUP,
                      rng: Optional[np.random.Generator] = None,
                      timer: Callable[[], float] = time.perf_counter
                      ) -> Measurement:
    """The :func:`measure_plan` harness for attention plans — same jit /
    warm-up / device-sync / robust-median contract."""
    import jax
    from repro.kernels import attn_api
    rng = rng or np.random.default_rng(0)
    ops = synthesize_attn_operands(pl, rng)

    def f(q, k, v, pos, page_table):
        return attn_api.attn_execute(pl, q, k, v, pos=pos,
                                     page_table=page_table)

    jitted = jax.jit(f)
    args = (ops["q"], ops["k"], ops["v"], ops["pos"], ops["page_table"])
    for _ in range(max(1, warmup)):          # compile + warm-up
        jax.block_until_ready(jitted(*args))
    times = []
    with telemetry.span("measure.attn", spec=pl.spec.key,
                        shape=pl.shape_key, kernel=pl.kernel,
                        iters=iters, warmup=warmup) as sp:
        for _ in range(max(1, iters)):
            t0 = timer()
            out = jitted(*args)
            jax.block_until_ready(out)
            times.append(timer() - t0)
        sp.sync(out)
    return Measurement(times_s=tuple(times),
                       kept_s=reject_outliers(tuple(times)),
                       warmup=max(1, warmup))
