"""Cost-model calibration: fit effective hardware constants from the
tuning cache's measured samples.

The analytic DSE prices every tile with two constants — peak flop/s and
HBM bytes/s (:mod:`repro.core.bandwidth`).  Those are *datasheet* numbers
for the target TPU; the host actually measured (a CPU in CI, a TPU in
production) achieves some effective fraction of each.  This module
regresses, per dispatch mode, every sample the tuner recorded:

    t_measured  ≈  t0  +  modeled_hbm_bytes / BW_eff  +  flops / F_eff

by ordinary least squares over ``[1, bytes, flops]``, reporting R² and
the per-call overhead ``t0`` (host dispatch — large on CPU, where it
*is* the fused-SwiGLU wash BENCH_gemm records).  A term whose fitted
coefficient is non-positive is dropped and refit — on a tiny CPU sweep
the flops term is often not identifiable, and reporting a negative
"effective bandwidth" would be worse than saying so.

``apply()`` feeds the fitted constants back into the analytic model
(:func:`repro.core.bandwidth.set_calibration`), so ``dse.solve`` /
``estimate`` / ``roofline.analyze`` re-rank designs with measured rather
than datasheet rates.  This is explicit and reversible
(:func:`clear`) — it is never switched on implicitly, because CPU-host
constants applied to TPU modeling would be nonsense.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import bandwidth
from repro.tune.cache import tuning_cache


@dataclasses.dataclass(frozen=True)
class CalibrationFit:
    """Fitted effective constants for one dispatch mode."""

    mode: str
    n_samples: int
    t0_us: float                    # fixed per-call overhead
    hbm_bw: Optional[float]         # effective bytes/s (None: unidentifiable)
    peak_flops: Optional[float]     # effective flop/s  (None: unidentifiable)
    r2: float
    note: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _samples_by_mode(entries: Dict[str, dict]
                     ) -> Dict[str, List[dict]]:
    by_mode: Dict[str, List[dict]] = {}
    for ent in entries.values():
        mode = str(ent.get("mode", "?"))
        for s in ent.get("samples") or []:
            if {"t_us", "hbm_bytes", "flops"} <= set(s):
                by_mode.setdefault(mode, []).append(s)
    return by_mode


def _fit_mode(mode: str, samples: Sequence[dict]) -> CalibrationFit:
    t = np.asarray([s["t_us"] * 1e-6 for s in samples], dtype=np.float64)
    b = np.asarray([s["hbm_bytes"] for s in samples], dtype=np.float64)
    f = np.asarray([s["flops"] for s in samples], dtype=np.float64)
    n = len(t)
    if n < 3:
        return CalibrationFit(mode, n, 0.0, None, None, 0.0,
                              note=f"insufficient samples ({n} < 3)")
    # least squares over [1, bytes, flops]; drop-and-refit any term whose
    # coefficient comes out non-positive (not identifiable on this host)
    use_b, use_f = True, True
    for _ in range(3):
        cols = [np.ones_like(t)]
        if use_b:
            cols.append(b)
        if use_f:
            cols.append(f)
        X = np.stack(cols, axis=1)
        coef, *_ = np.linalg.lstsq(X, t, rcond=None)
        i = 1
        cb = cf = None
        if use_b:
            cb = coef[i]
            i += 1
        if use_f:
            cf = coef[i]
        if use_b and cb is not None and cb <= 0:
            use_b = False
            continue
        if use_f and cf is not None and cf <= 0:
            use_f = False
            continue
        break
    pred = X @ coef
    ss_res = float(np.sum((t - pred) ** 2))
    ss_tot = float(np.sum((t - np.mean(t)) ** 2))
    r2 = 1.0 if ss_tot == 0.0 else 1.0 - ss_res / ss_tot
    t0 = float(coef[0])
    note = ""
    if not use_b or not use_f:
        dropped = [name for used, name in ((use_b, "bytes"),
                                           (use_f, "flops")) if not used]
        note = f"dropped non-identifiable term(s): {', '.join(dropped)}"
    return CalibrationFit(
        mode=mode, n_samples=n, t0_us=t0 * 1e6,
        hbm_bw=float(1.0 / cb) if use_b and cb else None,
        peak_flops=float(1.0 / cf) if use_f and cf else None,
        r2=round(r2, 5), note=note)


def fit(entries: Optional[Dict[str, dict]] = None
        ) -> Dict[str, CalibrationFit]:
    """One :class:`CalibrationFit` per dispatch mode present in the
    tuning cache (or in explicitly passed ``entries``)."""
    if entries is None:
        entries = tuning_cache().entries()
    return {mode: _fit_mode(mode, samples)
            for mode, samples in sorted(_samples_by_mode(entries).items())}


def render(fits: Dict[str, CalibrationFit]) -> str:
    """Aligned text report of the fitted constants."""
    if not fits:
        return ("[calibrate] no measured samples in the tuning cache — "
                "run an --autotune pass first")
    lines = []
    for mode, c in fits.items():
        bw = f"{c.hbm_bw / 1e9:.2f} GB/s" if c.hbm_bw else "n/a"
        fl = f"{c.peak_flops / 1e9:.1f} GFLOP/s" if c.peak_flops else "n/a"
        lines.append(
            f"[calibrate] mode={mode}: eff BW {bw}, eff compute {fl}, "
            f"t0 {c.t0_us:.1f} us, R2 {c.r2:.4f} "
            f"({c.n_samples} samples{'; ' + c.note if c.note else ''})")
    return "\n".join(lines)


def apply(fits: Optional[Dict[str, CalibrationFit]] = None,
          mode: Optional[str] = None) -> Optional[CalibrationFit]:
    """Push the current mode's fitted constants into the analytic model
    (``bandwidth.set_calibration``), invalidating the DSE and plan
    caches so every later ``plan()`` re-ranks under measured rates.
    Returns the fit applied, or ``None`` when nothing usable exists."""
    from repro.kernels import api, attn_api
    if fits is None:
        fits = fit()
    mode = mode or api._mode()
    c = fits.get(mode)
    if c is None or (c.hbm_bw is None and c.peak_flops is None):
        return None
    bandwidth.set_calibration(bandwidth.Calibration(
        hbm_bw=c.hbm_bw, peak_bf16_flops=c.peak_flops,
        peak_int8_ops=c.peak_flops,     # one compute constant per mode
        source=f"tune.calibrate[{mode}, n={c.n_samples}, r2={c.r2}]"))
    api.plan_cache_clear()
    attn_api.attn_plan_cache_clear()    # attention prices via the same rates
    return c


def clear() -> None:
    """Back to datasheet constants (and fresh DSE/plan caches)."""
    from repro.kernels import api, attn_api
    bandwidth.clear_calibration()
    api.plan_cache_clear()
    attn_api.attn_plan_cache_clear()
