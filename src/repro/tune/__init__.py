"""``repro.tune`` — measured autotuning: on-device top-K tile search,
the persistent tuning cache, and cost-model calibration.

The analytic DSE (:mod:`repro.core.dse`) picks tiles from a traffic
model; this package closes the loop against reality:

* :mod:`repro.tune.measure` — the shared timing harness (synthesized
  operands, jit + explicit warm-up, median-of-N with outlier rejection
  and reported spread);
* :mod:`repro.tune.autotune` — when enabled, ``plan()`` times the top-K
  analytic candidates and picks the measured winner;
* :mod:`repro.tune.cache` — winners persist to a schema-versioned JSON
  file keyed like the plan cache (spec key + shape + dispatch mode), so
  a second process re-measures nothing;
* :mod:`repro.tune.calibrate` — least-squares fit of effective
  bandwidth/compute constants from the recorded samples, optionally fed
  back into the analytic model.

Enable per spec (``GemmSpec(tune=True)``), per process
(:func:`enable` / ``--autotune`` on dryrun and serve), or via the
``REPRO_AUTOTUNE`` env var.
"""

from repro.tune import calibrate  # noqa: F401
from repro.tune.autotune import (  # noqa: F401
    DEFAULT_K,
    attn_lookup_or_search,
    disable,
    enable,
    is_enabled,
    lookup_or_search,
    search_k,
)
from repro.tune.cache import (  # noqa: F401
    SCHEMA_VERSION as CACHE_SCHEMA_VERSION,
    TuningCache,
    TuningCacheInfo,
    attn_cache_key,
    cache_key,
    cache_path,
    tuning_cache,
    tuning_cache_info,
    tuning_cache_reset,
)
from repro.tune.measure import (  # noqa: F401
    DEFAULT_ITERS,
    DEFAULT_MAX_FLOPS,
    DEFAULT_WARMUP,
    Measurement,
    measure_attn_plan,
    measure_plan,
    synthesize_attn_operands,
    synthesize_operands,
)
