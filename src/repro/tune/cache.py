"""The persistent tuning cache: measured tile winners, keyed exactly
like the plan cache (spec key + shape) plus the dispatch mode.

One schema-versioned JSON file maps

    "<GemmSpec.key>|<m>x<k>x<n>|<mode>"  ->  winner entry

where ``mode`` is the kernel dispatch backend (``pallas`` / ``interpret``
/ ``ref``) — a winner measured on the CPU reference path must never be
served to a TPU process.  Entries carry the winner tile, its measured
median + spread, the analytic rank-0 candidate it displaced, and every
per-candidate sample (modeled bytes/flops vs measured time) so
:mod:`repro.tune.calibrate` can regress the cost-model constants without
re-measuring anything.

Failure policy — the cache must never take ``plan()`` down with it: a
missing file is an empty cache, a corrupt or stale-schema file warns and
starts empty (it is overwritten wholesale on the next save), and saves
go through an atomic tempfile replace.  Counters (hits / misses /
measurements / load errors) make cache behavior assertable: a second
process over the same file must show hits with **zero** measurements.
"""

from __future__ import annotations

import json
import os
import tempfile
import time
import warnings
from typing import Dict, NamedTuple, Optional

#: bump when the entry layout changes shape — older files are discarded
#: with a warning, never half-parsed
SCHEMA_VERSION = 1

#: default on-disk location; override with REPRO_TUNE_CACHE
DEFAULT_PATH = os.path.join("artifacts", "tune_cache.json")


def cache_path() -> str:
    return os.environ.get("REPRO_TUNE_CACHE", DEFAULT_PATH)


def cache_key(spec, shapes, mode: str) -> str:
    """The persistent join key: the plan cache's (spec, m, k, n) key
    serialized through ``GemmSpec.key`` (canonical, process-stable)
    plus the dispatch mode."""
    m, k, n = (int(x) for x in shapes)
    return f"{spec.key}|{m}x{k}x{n}|{mode}"


def attn_cache_key(spec, shapes, mode: str) -> str:
    """Attention join key — ``AttnSpec.key`` already starts with
    ``attn|``, so attention winners live in their own namespace next to
    the GEMM entries in the same file (shape tuples are per-mode, see
    :func:`repro.kernels.attn_api._shape_fields`)."""
    dims = "x".join(str(int(x)) for x in shapes)
    return f"{spec.key}|{dims}|{mode}"


class TuningCacheInfo(NamedTuple):
    entries: int
    hits: int
    misses: int
    measurements: int
    load_errors: int


class TuningCache:
    """One JSON file of measured winners, lazily loaded, with counted
    access so tests and benchmarks can assert re-measurement never
    happens once a winner is persisted."""

    def __init__(self, path: str):
        self.path = path
        self._entries: Optional[Dict[str, dict]] = None
        self.hits = 0
        self.misses = 0
        self.measurements = 0
        self.load_errors = 0

    # ------------------------------------------------------------- load/save

    def _load(self) -> Dict[str, dict]:
        if self._entries is not None:
            return self._entries
        self._entries = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    payload = json.load(f)
                if not isinstance(payload, dict):
                    raise ValueError("top level is not an object")
                schema = payload.get("schema")
                if schema != SCHEMA_VERSION:
                    raise ValueError(
                        f"schema {schema!r} != {SCHEMA_VERSION} (stale)")
                entries = payload.get("entries")
                if not isinstance(entries, dict):
                    raise ValueError("'entries' is not an object")
                self._entries = entries
            except (OSError, ValueError, json.JSONDecodeError) as e:
                self.load_errors += 1
                warnings.warn(
                    f"tuning cache {self.path!r} unreadable ({e}); "
                    "falling back to analytic plans — the file will be "
                    "rewritten on the next autotune save", stacklevel=3)
                self._entries = {}
        return self._entries

    def save(self) -> None:
        entries = self._load()
        payload = {"schema": SCHEMA_VERSION, "entries": entries}
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # ------------------------------------------------------------- access

    def get(self, key: str) -> Optional[dict]:
        ent = self._load().get(key)
        if ent is None:
            self.misses += 1
        else:
            self.hits += 1
        return ent

    def put(self, key: str, entry: dict, *, save: bool = True) -> None:
        entry = dict(entry)
        entry.setdefault("created", time.time())
        self._load()[key] = entry
        self.measurements += 1
        if save:
            self.save()

    def entries(self) -> Dict[str, dict]:
        return dict(self._load())

    def info(self) -> TuningCacheInfo:
        # deliberately does NOT force a load: telemetry snapshots call
        # this and must stay free of disk I/O when tuning is unused
        n = len(self._entries) if self._entries is not None else 0
        return TuningCacheInfo(n, self.hits, self.misses,
                               self.measurements, self.load_errors)


# one live instance per resolved path, so every consumer in a process
# shares counters and an in-memory view of the same file
_caches: Dict[str, TuningCache] = {}


def tuning_cache(path: Optional[str] = None) -> TuningCache:
    p = path or cache_path()
    cache = _caches.get(p)
    if cache is None:
        cache = _caches.setdefault(p, TuningCache(p))
    return cache


def tuning_cache_info() -> TuningCacheInfo:
    """Counters of the *current-path* cache (the one ``plan()`` uses)."""
    return tuning_cache().info()


def tuning_cache_reset() -> None:
    """Drop every live in-memory cache instance (files are untouched) —
    the next access re-reads from disk with fresh counters.  Tests use
    this to simulate a second process over the same file."""
    _caches.clear()
