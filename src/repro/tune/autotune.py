"""Measured top-K tile search: close the analytic DSE's model-vs-reality
loop by timing its best candidates on the device that will run them.

``lookup_or_search`` is the single entrypoint ``plan()`` consults when
autotuning is enabled (``GemmSpec(tune=True)``, ``repro.tune.enable()``
or ``REPRO_AUTOTUNE=1``):

1. the persistent :mod:`repro.tune.cache` is checked first — a winner
   measured by any previous process on the same dispatch mode is reused
   with **zero** re-measurement;
2. on a miss, the top-K candidates of ``dse.solve`` (already ranked by
   modeled roofline time) are each resolved to a real plan and timed with
   the :mod:`repro.tune.measure` harness (median-of-N, outlier-rejected);
3. the measured winner is persisted — tile, median, spread, the analytic
   rank-0 time it displaced, and every per-candidate sample so
   :mod:`repro.tune.calibrate` can fit cost-model constants later.

The search *never* raises into ``plan()``: problems too large for the
flop budget, candidates that fail post-clamp feasibility, and measurement
errors all degrade to the analytic answer (``None``).
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np

from repro import telemetry
from repro.core.tiling import TileConfig
from repro.tune import measure
from repro.tune.cache import attn_cache_key, cache_key, tuning_cache

#: candidates swept per search when nothing narrower is configured
DEFAULT_K = 4

_enabled: Optional[bool] = None     # module switch; None -> env
_k: Optional[int] = None


def enable(k: Optional[int] = None) -> None:
    """Turn autotuning on for this process (what ``--autotune`` does);
    ``k`` narrows the per-shape candidate sweep."""
    global _enabled, _k
    _enabled = True
    if k is not None:
        _k = int(k)


def disable() -> None:
    global _enabled, _k
    _enabled = False
    _k = None


def is_enabled(spec_tune: Optional[bool] = None) -> bool:
    """The three-level switch: the spec's own ``tune`` field wins, then
    the process switch (:func:`enable`/:func:`disable`), then the
    ``REPRO_AUTOTUNE`` env var ('0'/'false'/'' = off, anything else on;
    an integer > 1 doubles as the search K)."""
    if spec_tune is not None:
        return bool(spec_tune)
    if _enabled is not None:
        return _enabled
    return os.environ.get("REPRO_AUTOTUNE", "").lower() \
        not in ("", "0", "false")


def search_k() -> int:
    if _k is not None:
        return _k
    env = os.environ.get("REPRO_AUTOTUNE", "")
    try:
        if int(env) > 1:
            return int(env)
    except ValueError:
        pass
    return DEFAULT_K


def _tile_from(d: dict) -> TileConfig:
    return TileConfig(int(d["bm"]), int(d["bk"]), int(d["bn"]),
                      str(d["strategy"]))


def _tile_dict(t: TileConfig) -> dict:
    return {"bm": t.bm, "bk": t.bk, "bn": t.bn, "strategy": t.strategy}


def _tile_str(t: TileConfig) -> str:
    return f"{t.strategy} {t.bm}x{t.bk}x{t.bn}"


def lookup_or_search(spec, shapes: Tuple[int, int, int], problem, *,
                     k: Optional[int] = None,
                     iters: int = measure.DEFAULT_ITERS,
                     warmup: int = measure.DEFAULT_WARMUP,
                     max_flops: float = measure.DEFAULT_MAX_FLOPS,
                     seed: int = 0):
    """Measured winner for (spec, shapes) — ``(TileConfig, TunedInfo)``
    from the persistent cache or a fresh top-K sweep, or ``None`` when
    the analytic path should decide (over-budget problem, nothing
    measurable, stale cache tile that no longer fits)."""
    from repro.kernels import api
    mode = api._mode()
    cache = tuning_cache()
    key = cache_key(spec, shapes, mode)
    ent = cache.get(key)
    if ent is not None:
        try:
            tile = _tile_from(ent["tile"])
        except (KeyError, TypeError, ValueError):
            tile = None             # malformed entry -> analytic
        if tile is not None:
            analytic = ent.get("analytic") or {}
            telemetry.counter("gemm.autotune.cache_hits").add(1)
            return tile, api.TunedInfo(
                t_measured_us=float(ent.get("t_us", 0.0)),
                spread=float(ent.get("spread", 0.0)),
                t_analytic_us=analytic.get("t_us"),
                analytic_tile=str(analytic.get("tile", "")),
                k_searched=int(ent.get("k_searched", 0)),
                from_cache=True)
    if problem.flops > max_flops:
        telemetry.counter("gemm.autotune.flops_skips").add(1)
        return None                 # too big to sweep on this host

    k = k or search_k()
    designs = api.solve_topk(spec, shapes, k)
    rng = np.random.default_rng(seed)
    candidates = []                 # (median_s, rank, plan, Measurement)
    for rank, d in enumerate(designs):
        cand = dataclasses.replace(spec, tile=d.tile, tune=False)
        try:
            pl = api._resolve(cand, *shapes)    # no plan-cache pollution
            meas = measure.measure_plan(pl, iters=iters, warmup=warmup,
                                        rng=rng)
        except Exception as e:      # infeasible post-clamp / exec error
            telemetry.event("gemm.autotune.candidate_error",
                            spec=spec.key, tile=_tile_str(d.tile),
                            error=repr(e))
            continue
        candidates.append((meas.median_s, rank, pl, meas))
    if not candidates:
        return None
    candidates.sort(key=lambda c: (c[0], c[1]))     # ties: analytic rank
    _, win_rank, win_pl, win_meas = candidates[0]
    analytic_first = next((c for c in candidates if c[1] == 0), None)
    entry = {
        "tile": _tile_dict(win_pl.tile),
        "t_us": win_meas.median_s * 1e6,
        "spread": win_meas.spread,
        "t_model_us": win_pl.traffic.t_model * 1e6,
        "hbm_bytes": win_pl.hbm_bytes,
        "flops": win_pl.flops,
        "analytic": {
            "tile": _tile_str(analytic_first[2].tile),
            "t_us": analytic_first[0] * 1e6,
        } if analytic_first is not None else None,
        "k_searched": len(candidates),
        "iters": iters, "warmup": warmup,
        "mode": mode, "spec": spec.key,
        "shape": f"{shapes[0]}x{shapes[1]}x{shapes[2]}",
        "samples": [
            {"tile": _tile_dict(pl.tile), "rank": rank,
             "t_us": med * 1e6, "spread": meas.spread,
             "t_model_us": pl.traffic.t_model * 1e6,
             "hbm_bytes": pl.hbm_bytes, "flops": pl.flops}
            for med, rank, pl, meas in sorted(candidates,
                                              key=lambda c: c[1])
        ],
    }
    cache.put(key, entry)
    telemetry.counter("gemm.autotune.searches").add(1)
    telemetry.event(
        "gemm.autotune", spec=spec.key, m=shapes[0], k=shapes[1],
        n=shapes[2], mode=mode, k_searched=len(candidates),
        winner=_tile_str(win_pl.tile), winner_rank=win_rank,
        t_us=entry["t_us"], spread=entry["spread"],
        analytic=entry["analytic"])
    analytic = entry["analytic"] or {}
    return win_pl.tile, api.TunedInfo(
        t_measured_us=entry["t_us"], spread=entry["spread"],
        t_analytic_us=analytic.get("t_us"),
        analytic_tile=str(analytic.get("tile", "")),
        k_searched=len(candidates), from_cache=False)


# ---------------------------------------------------------------------------
# Attention block search — same cache-then-sweep loop over AttnPlan
# block candidates, with one extra degree of freedom: a batch proxy.
# ---------------------------------------------------------------------------

def _blocks_dict(bq, bkv) -> dict:
    return {"bq": bq, "bkv": bkv}


def _blocks_str(bq, bkv) -> str:
    return f"bq={bq or '-'} bkv={bkv or '-'}"


def _attn_proxy_shapes(spec, shapes, problem, max_flops: float):
    """(proxy shapes, measured_b) — attention blocks are batch-invariant
    (``b`` only multiplies grid axis 0), so an over-budget problem is
    measured at the largest batch whose flops fit instead of being
    skipped outright.  Returns ``None`` when even b=1 blows the budget."""
    if problem.flops <= max_flops:
        return tuple(int(x) for x in shapes), int(shapes[0])
    per_b = problem.flops / max(1, problem.b)
    b_proxy = int(max_flops // per_b)
    if b_proxy < 1:
        return None
    return (b_proxy,) + tuple(int(x) for x in shapes[1:]), b_proxy


def attn_lookup_or_search(spec, shapes, problem, *,
                          k: Optional[int] = None,
                          iters: int = measure.DEFAULT_ITERS,
                          warmup: int = measure.DEFAULT_WARMUP,
                          max_flops: float = measure.DEFAULT_MAX_FLOPS,
                          seed: int = 0):
    """Measured attention block winner for (spec, shapes) —
    ``((bq, bkv), TunedInfo)`` from the persistent ``attn|...`` cache
    namespace or a fresh top-K sweep, or ``None`` when the analytic path
    should decide.  Same degradation policy as the GEMM search: never
    raises into ``attn_plan()``."""
    import dataclasses as _dc

    from repro.kernels import api
    from repro.kernels import attn_api
    mode = api._mode()
    cache = tuning_cache()
    key = attn_cache_key(spec, shapes, mode)
    ent = cache.get(key)
    if ent is not None:
        blocks = ent.get("blocks")
        if isinstance(blocks, dict):
            bq = blocks.get("bq")
            bkv = blocks.get("bkv")
            analytic = ent.get("analytic") or {}
            telemetry.counter("attn.autotune.cache_hits").add(1)
            return (bq, bkv), api.TunedInfo(
                t_measured_us=float(ent.get("t_us", 0.0)),
                spread=float(ent.get("spread", 0.0)),
                t_analytic_us=analytic.get("t_us"),
                analytic_tile=str(analytic.get("blocks", "")),
                k_searched=int(ent.get("k_searched", 0)),
                from_cache=True)
    proxy = _attn_proxy_shapes(spec, shapes, problem, max_flops)
    if proxy is None:
        telemetry.counter("attn.autotune.flops_skips").add(1)
        return None                 # even b=1 is too big for this host
    proxy_shapes, measured_b = proxy

    k = k or search_k()
    designs = attn_api.attn_solve_topk(spec, shapes, k)
    rng = np.random.default_rng(seed)
    candidates = []                 # (median_s, rank, plan, Measurement)
    for rank, d in enumerate(designs):
        cand = _dc.replace(spec, bq=d.bq, bkv=d.bkv, tune=False)
        try:
            pl = attn_api._resolve(cand, proxy_shapes)
            meas = measure.measure_attn_plan(pl, iters=iters,
                                             warmup=warmup, rng=rng)
        except Exception as e:      # infeasible post-clamp / exec error
            telemetry.event("attn.autotune.candidate_error",
                            spec=spec.key,
                            blocks=_blocks_str(d.bq, d.bkv),
                            error=repr(e))
            continue
        candidates.append((meas.median_s, rank, pl, meas))
    if not candidates:
        return None
    candidates.sort(key=lambda c: (c[0], c[1]))     # ties: analytic rank
    _, win_rank, win_pl, win_meas = candidates[0]
    analytic_first = next((c for c in candidates if c[1] == 0), None)
    shape_str = "x".join(str(int(x)) for x in shapes)
    entry = {
        "blocks": _blocks_dict(win_pl.bq, win_pl.bkv),
        "t_us": win_meas.median_s * 1e6,
        "spread": win_meas.spread,
        "t_model_us": win_pl.traffic.t_model * 1e6,
        "hbm_bytes": win_pl.hbm_bytes,
        "flops": win_pl.flops,
        "analytic": {
            "blocks": _blocks_str(analytic_first[2].bq,
                                  analytic_first[2].bkv),
            "t_us": analytic_first[0] * 1e6,
        } if analytic_first is not None else None,
        "k_searched": len(candidates),
        "iters": iters, "warmup": warmup,
        "measured_b": measured_b,
        "mode": mode, "spec": spec.key, "shape": shape_str,
        "samples": [
            {"blocks": _blocks_dict(pl.bq, pl.bkv), "rank": rank,
             "t_us": med * 1e6, "spread": meas.spread,
             "t_model_us": pl.traffic.t_model * 1e6,
             "hbm_bytes": pl.hbm_bytes, "flops": pl.flops}
            for med, rank, pl, meas in sorted(candidates,
                                              key=lambda c: c[1])
        ],
    }
    cache.put(key, entry)
    telemetry.counter("attn.autotune.searches").add(1)
    telemetry.event(
        "attn.autotune", spec=spec.key, shape=shape_str, mode=mode,
        k_searched=len(candidates), measured_b=measured_b,
        winner=_blocks_str(win_pl.bq, win_pl.bkv), winner_rank=win_rank,
        t_us=entry["t_us"], spread=entry["spread"],
        analytic=entry["analytic"])
    analytic = entry["analytic"] or {}
    return (win_pl.bq, win_pl.bkv), api.TunedInfo(
        t_measured_us=entry["t_us"], spread=entry["spread"],
        t_analytic_us=analytic.get("t_us"),
        analytic_tile=str(analytic.get("blocks", "")),
        k_searched=len(candidates), from_cache=False)
