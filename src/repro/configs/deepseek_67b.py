"""deepseek-67b — llama-arch dense LM [arXiv:2401.02954; hf]."""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, head_dim=128,
    notes="full attention -> long_500k skipped",
))

register(ModelConfig(
    name="deepseek-67b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=512, head_dim=16,
    dtype="float32",
))
