"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 2:1
[arXiv:2402.19427; unverified].

38 layers, attention at every third layer (local window 2048, MQA kv=1):
layer i is 'local' iff i % 3 == 2, i.e. 12 x (rec, rec, local) + a
(rec, rec) tail — expressed as a scanned triplet plus ``tail_pattern``
so the published 38-layer sequence lowers to one compact loop (a ~25x
dry-run compile-time difference vs inlining all 38 layers).  Bounded
window + O(1) recurrent state -> long_500k RUNS.
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, head_dim=256,
    layer_pattern=("rec", "rec", "local"), tail_pattern=("rec", "rec"),
    local_window=2048, lru_width=4096,
    notes="RG-LRU + local attn 1:2; long_500k runs",
))

register(ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=128, vocab=512, head_dim=16,
    layer_pattern=("rec", "rec", "local"), tail_pattern=("rec", "rec"),
    local_window=32, lru_width=64,
    dtype="float32",
))
