"""qwen3-moe-235b-a22b — 128-expert top-8 MoE [hf:Qwen/Qwen3; hf].

head_dim=128 (the Qwen3 family decouples head_dim from d_model/n_heads).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, head_dim=128,
    layer_pattern=("moe",), n_experts=128, top_k=8,
    notes="MoE 128e top-8; full attention -> long_500k skipped",
))

register(ModelConfig(
    name="qwen3-moe-235b-a22b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=512, head_dim=16,
    layer_pattern=("moe",), n_experts=8, top_k=2,
    dtype="float32",
    capacity_factor=8.0,
))
