"""mamba2-370m — attention-free SSD (state-space duality)
[arXiv:2405.21060; unverified].

d_inner = 2*d_model, head_dim 64, scalar decay per head, d_state 128.
Attention-free: O(1) decode state -> long_500k RUNS.  The paper's tiled-
GEMM methodology still applies: SSD's chunked form is matmul-dominated
(DESIGN.md SSArch-applicability).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=50280, ssm_state=128,
    layer_pattern=("ssm",),
    notes="attention-free; long_500k runs (O(1) state)",
))

register(ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab=512, ssm_state=16,
    layer_pattern=("ssm",),
    dtype="float32",
))
