"""Architecture configuration schema + registry.

One ``ModelConfig`` per assigned architecture lives in
``repro/configs/<arch>.py`` with the exact published shape, plus a
``smoke()`` reduction of the same family for CPU tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

ARCH_IDS = (
    "minitron-8b",
    "deepseek-67b",
    "smollm-360m",
    "h2o-danube-3-4b",
    "whisper-medium",
    "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b",
    "mamba2-370m",
    "recurrentgemma-9b",
    "internvl2-76b",
)

# Layer kinds usable in ``layer_pattern``:
#   'attn'  GQA attention (+ SwiGLU MLP), window = cfg.window
#   'local' GQA attention with window = cfg.local_window (+ MLP)
#   'moe'   GQA attention + MoE FFN
#   'ssm'   Mamba-2 (SSD) mixer, no MLP
#   'rec'   RG-LRU recurrent block + MLP
LAYER_KINDS = ("attn", "local", "moe", "ssm", "rec")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    layer_pattern: Tuple[str, ...] = ("attn",)
    # trailing layers that don't fit the repeating unit (e.g. Griffin's
    # 38 = 12x(rec,rec,local) + (rec,rec)); applied after the scan so the
    # HLO stays one compact loop + a short tail instead of 38 inlined
    # layers (a ~25x compile-time difference on the 512-chip dry-run)
    tail_pattern: Tuple[str, ...] = ()
    window: int = 0                 # SWA width for 'attn' layers
    local_window: int = 0           # window for 'local' layers
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    # hybrid
    lru_width: int = 0
    # encoder-decoder (audio)
    encoder_layers: int = 0
    encoder_seq: int = 0            # stub frontend frames
    # vlm
    prefix_tokens: int = 0          # stub vision patch tokens
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    use_rope: bool = True
    notes: str = ""

    def __post_init__(self):
        assert (self.n_layers - len(self.tail_pattern)) \
            % len(self.layer_pattern) == 0, \
            (self.name, self.n_layers, self.layer_pattern,
             self.tail_pattern)
        for kind in self.layer_pattern + self.tail_pattern:
            assert kind in LAYER_KINDS, kind

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def repeats(self) -> int:
        return (self.n_layers - len(self.tail_pattern)) \
            // len(self.layer_pattern)

    @property
    def all_kinds(self) -> Tuple[str, ...]:
        return self.layer_pattern + self.tail_pattern

    @property
    def attention_free(self) -> bool:
        return all(k == "ssm" for k in self.all_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if no layer needs an unbounded KV cache (long_500k gate)."""
        for k in self.all_kinds:
            if k == "attn" and self.window == 0:
                return False
            if k == "moe" and self.window == 0:
                return False
            if k == "local" and self.local_window == 0:
                return False
        return True

    # ----- parameter / FLOP accounting (MODEL_FLOPS for SSRoofline) -----

    def _attn_params(self) -> int:
        return self.d_model * self.hd * (2 * self.n_heads
                                         + 2 * self.n_kv_heads)

    def _mlp_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _layer_params(self, kind: str, active_only: bool = False) -> int:
        if kind in ("attn", "local"):
            return self._attn_params() + self._mlp_params()
        if kind == "moe":
            experts = self.top_k if active_only else self.n_experts
            return self._attn_params() + self.d_model * self.n_experts \
                + experts * 3 * self.d_model * self.d_ff
        if kind == "ssm":
            from repro.models.mamba2 import dims
            dd = dims(self.d_model, self.ssm_state)
            return (self.d_model * dd["proj_out"]
                    + dd["d_inner"] * self.d_model)
        if kind == "rec":
            w = self.lru_width or self.d_model
            return (self.d_model * 2 * w + 2 * w * w + w * self.d_model
                    + self._mlp_params())
        raise ValueError(kind)

    def param_count(self, active_only: bool = False) -> int:
        unit = sum(self._layer_params(k, active_only)
                   for k in self.layer_pattern)
        total = unit * self.repeats
        total += sum(self._layer_params(k, active_only)
                     for k in self.tail_pattern)
        total += 2 * self.vocab * self.d_model          # embed + lm head
        if self.encoder_layers:
            total += self.encoder_layers * (
                self._attn_params() + 2 * self.d_model * self.d_ff)
            # decoder cross-attention
            total += self.n_layers * self._attn_params()
        return total

    def model_flops(self, tokens: int, *, training: bool) -> float:
        """6*N*D (train) / 2*N*D (inference) with N = active params."""
        n = self.param_count(active_only=True)
        return (6.0 if training else 2.0) * n * tokens


_REGISTRY: Dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    get_config(name)                      # ensure module imported
    return _REGISTRY[name + "-smoke"]


def all_configs() -> Dict[str, ModelConfig]:
    for a in ARCH_IDS:
        get_config(a)
    return {a: _REGISTRY[a] for a in ARCH_IDS}
