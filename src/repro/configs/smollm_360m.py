"""smollm-360m — small llama-arch LM [hf:HuggingFaceTB/SmolLM; hf].

15 q-heads / 5 kv-heads are not divisible by the 16-way 'model' axis; the
layout solver replicates head-sharded tensors where divisibility fails
(see DESIGN.md SS4) while keeping d_ff / vocab sharded (2560 and 49152 are
16-divisible).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="smollm-360m", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64,
    notes="full attention -> long_500k skipped; heads %16 != 0",
))

register(ModelConfig(
    name="smollm-360m-smoke", family="dense",
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
    d_ff=160, vocab=512, head_dim=20,
    dtype="float32",
))
