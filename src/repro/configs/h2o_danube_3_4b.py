"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; unverified].

SWA window 4096 bounds the KV cache -> sub-quadratic -> long_500k RUNS
(ring-buffer caches of 4096 slots at 524k positions).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, head_dim=120,
    window=4096,
    notes="SWA -> long_500k runs",
))

register(ModelConfig(
    name="h2o-danube-3-4b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, head_dim=16, window=32,
    dtype="float32",
))
