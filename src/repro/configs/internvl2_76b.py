"""internvl2-76b — InternViT + llama-3-70B-class backbone
[arXiv:2404.16821; unverified].

Backbone only: the InternViT tower is a stub; ``input_specs`` feeds 256
precomputed patch embeddings (b, 256, d) prepended to the text tokens.
Loss is computed on text positions only.
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    prefix_tokens=256,
    notes="ViT frontend stubbed; full attention -> long_500k skipped",
))

register(ModelConfig(
    name="internvl2-76b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=512, head_dim=16, prefix_tokens=8,
    dtype="float32",
))
