from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    ModelConfig,
    all_configs,
    get_config,
    get_smoke_config,
)
