"""whisper-medium — encoder-decoder audio model [arXiv:2212.04356;
unverified].

Backbone only per the task sheet: the conv/mel frontend is a stub;
``input_specs`` feeds precomputed frame embeddings (b, 1500, d) to the
encoder.  Decoder: causal self-attn + cross-attn + GELU MLP, LayerNorm,
absolute (sinusoidal) positions, MHA (kv = heads).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, head_dim=64,
    encoder_layers=24, encoder_seq=1500, use_rope=False,
    notes="enc-dec; full attention -> long_500k skipped",
))

register(ModelConfig(
    name="whisper-medium-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=512, head_dim=16,
    encoder_layers=2, encoder_seq=16, use_rope=False,
    dtype="float32",
))
