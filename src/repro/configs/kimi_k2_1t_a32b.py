"""kimi-k2-1t-a32b — trillion-parameter MoE (384 experts, top-8)
[arXiv:2501.kimi2; unverified].

~1.03e12 total / ~32e9 active parameters.  EP posture: expert dim sharded
over 'model'; expert d_model/d_ff dims sharded over 'data' (2D weight
sharding — AdamW states would not fit; the trainer selects Adafactor for
this config, see repro.optim).
"""

from repro.configs.base import ModelConfig, register

register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, head_dim=112,
    layer_pattern=("moe",), n_experts=384, top_k=8,
    notes="MoE 384e top-8; full attention -> long_500k skipped",
))

register(ModelConfig(
    name="kimi-k2-1t-a32b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=512, head_dim=16,
    layer_pattern=("moe",), n_experts=8, top_k=2,
    dtype="float32",
    capacity_factor=8.0,
))
