"""Composable decoder stack covering all ten assigned architectures.

A model is a repeated ``layer_pattern`` unit (e.g. ('rec','rec','local')
for recurrentgemma) scanned over ``cfg.repeats`` repetitions with
optional remat — so a 95-layer model lowers to one while-loop and the
HLO stays compact for the 40-cell multi-pod dry-run.

Three execution paths per architecture:
  * :func:`forward` / :func:`loss_fn`    — training (full seq, remat+scan)
  * :func:`prefill`                      — fill caches from a prompt
  * :func:`decode_step`                  — one token with caches (serve)

Family add-ons: encoder-decoder w/ cross-attention (whisper), prefix
patch embeddings (internvl2).  Modality frontends are stubs per the task
sheet: ``input_specs`` feeds precomputed frame/patch embeddings.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist import sharding as shd
from repro import ops
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rglru as RG

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------

def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Absolute sinusoidal position encoding (audio family — whisper uses
    absolute positions, not RoPE)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(10000.0) / max(half - 1, 1)))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _maybe_abs_pos(cfg: ModelConfig, x: jax.Array, start: jax.Array | int
                   ) -> jax.Array:
    """``start`` may be a scalar (whole batch at one offset — train /
    prefill) or a (b,) per-slot vector (continuous-batching decode, each
    row at its own position)."""
    if cfg.use_rope:
        return x
    s, d = x.shape[1], x.shape[2]
    start = jnp.asarray(start)
    if start.ndim == 1:
        pos = jnp.arange(s)[None, :] + start[:, None]       # (b, s)
        return x + _sinusoid(pos, d).astype(x.dtype)
    pos = jnp.arange(s) + start
    return x + _sinusoid(pos, d)[None].astype(x.dtype)


def _attn_spec(cfg: ModelConfig, kind: str, *, causal: bool = True
               ) -> L.AttnLayerSpec:
    window = cfg.window if kind in ("attn", "moe") else cfg.local_window
    return L.AttnLayerSpec(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd, window=window,
        rope_theta=cfg.rope_theta, causal=causal, use_rope=cfg.use_rope)


def _norm_init(cfg: ModelConfig):
    return L.init_layer_norm(cfg.d_model) if cfg.family == "audio" \
        else L.init_rms_norm(cfg.d_model)


def _norm(cfg: ModelConfig, p, x):
    return L.layer_norm(p, x) if cfg.family == "audio" \
        else L.rms_norm(p, x, cfg.norm_eps)


def _mlp_init(key, cfg: ModelConfig):
    if cfg.family == "audio":
        return L.init_gelu_mlp(key, cfg.d_model, cfg.d_ff, cfg.dtype)
    return L.init_swiglu(key, cfg.d_model, cfg.d_ff, cfg.dtype)


def _mlp(cfg: ModelConfig, p, x, residual=None):
    """MLP through the fused kernels; ``residual`` rides the down
    projection's epilogue (one C write instead of GEMM + XLA add)."""
    if cfg.family == "audio":
        return L.gelu_mlp(p, x, residual=residual)
    return L.swiglu(p, x, residual=residual)


def init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    dt = cfg.dtype
    if kind in ("attn", "local"):
        return {"norm1": _norm_init(cfg),
                "attn": L.init_attention(ks[0], _attn_spec(cfg, kind), dt),
                "norm2": _norm_init(cfg),
                "mlp": _mlp_init(ks[1], cfg)}
    if kind == "moe":
        return {"norm1": _norm_init(cfg),
                "attn": L.init_attention(ks[0], _attn_spec(cfg, kind), dt),
                "norm2": _norm_init(cfg),
                "moe": MOE.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                    cfg.n_experts, dt)}
    if kind == "ssm":
        return {"norm1": _norm_init(cfg),
                "mixer": M2.init_mamba2(ks[0], cfg.d_model, cfg.ssm_state,
                                        dt)}
    if kind == "rec":
        return {"norm1": _norm_init(cfg),
                "rec": RG.init_rglru(ks[0], cfg.d_model,
                                     cfg.lru_width or cfg.d_model, dt),
                "norm2": _norm_init(cfg),
                "mlp": _mlp_init(ks[1], cfg)}
    raise ValueError(kind)


def _init_decoder_layer(key, cfg: ModelConfig, kind: str) -> dict:
    p = init_layer(key, cfg, kind)
    if cfg.encoder_layers:                 # audio: add cross-attention
        kc = jax.random.fold_in(key, 777)
        p["norm_x"] = _norm_init(cfg)
        p["cross"] = L.init_attention(
            kc, _attn_spec(cfg, kind, causal=False), cfg.dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    keys = jax.random.split(key, 8)
    params: Dict = {
        "embed": L.init_embedding(keys[0], cfg.vocab, cfg.d_model,
                                  cfg.dtype),
        "final_norm": _norm_init(cfg),
        "lm_head": L.dense_init(keys[1], cfg.d_model, cfg.vocab, cfg.dtype),
        "layers": {},
    }
    for i, kind in enumerate(cfg.layer_pattern):
        lk = jax.random.fold_in(keys[2], i)
        params["layers"][f"u{i}"] = jax.vmap(
            lambda k: _init_decoder_layer(k, cfg, kind))(
                jax.random.split(lk, cfg.repeats))
    if cfg.tail_pattern:
        assert not cfg.encoder_layers, "tail + enc-dec unsupported"
        params["tail"] = {
            f"t{i}": init_layer(jax.random.fold_in(keys[4], i), cfg, kind)
            for i, kind in enumerate(cfg.tail_pattern)}
    if cfg.encoder_layers:
        enc: Dict = {"final_norm": _norm_init(cfg), "layers": {}}
        ek = jax.random.fold_in(keys[3], 0)
        enc["layers"]["u0"] = jax.vmap(
            lambda k: init_layer(k, cfg, "attn"))(
                jax.random.split(ek, cfg.encoder_layers))
        params["encoder"] = enc
    return params


# ---------------------------------------------------------------------------
# Full-sequence apply (training / encoder)
# ---------------------------------------------------------------------------

def apply_layer(p: dict, cfg: ModelConfig, kind: str, x: jax.Array, *,
                enc_out: Optional[jax.Array] = None,
                causal: bool = True) -> Tuple[jax.Array, jax.Array]:
    """One layer, full sequence.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    spec = _attn_spec(cfg, kind, causal=causal)
    if kind in ("attn", "local", "moe"):
        # the residual-stream adds fuse into the output/down projections'
        # kernel flushes (epilogue) — no separate XLA add round-trips
        x = L.attention_block(p["attn"], _norm(cfg, p["norm1"], x),
                              spec, residual=x)
        if enc_out is not None:
            x = L.attention_block(p["cross"],
                                  _norm(cfg, p["norm_x"], x), spec,
                                  memory=enc_out, residual=x)
        h = _norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, aux = MOE.moe_ffn(p["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = _mlp(cfg, p["mlp"], h, residual=x)
    elif kind == "ssm":
        x = x + M2.mamba2_block(p["mixer"], _norm(cfg, p["norm1"], x),
                                cfg.ssm_state)
    elif kind == "rec":
        x = x + RG.rglru_block(p["rec"], _norm(cfg, p["norm1"], x))
        x = _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], x), residual=x)
    else:
        raise ValueError(kind)
    return x, aux


def _encode(params: dict, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """Encoder stack over stub frame embeddings (b, F, d)."""
    enc = params["encoder"]

    def unit(x, p):
        y, _ = apply_layer(p, cfg, "attn", x, causal=False)
        return y, None

    x, _ = jax.lax.scan(unit, frames, enc["layers"]["u0"])
    return _norm(cfg, enc["final_norm"], x)


def _project_cross_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    return L.project_kv(p["cross"], enc_out, _attn_spec(cfg, "attn"))


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            prefix_embeds: Optional[jax.Array] = None,
            frames: Optional[jax.Array] = None,
            remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (hidden (b, s, d), aux_loss).

    ``prefix_embeds`` (vlm): (b, P, d) prepended to token embeddings.
    ``frames`` (audio): (b, F, d) stub encoder input.
    """
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = _maybe_abs_pos(cfg, x, 0)
    # 'seq' resolves to 'model' under sequence parallelism (the stored
    # remat carry is then 1/|model| per device), else to None
    x = shd.act(x, ("batch", "seq", None))
    enc_out = _encode(params, cfg, frames) if frames is not None else None

    kinds = cfg.layer_pattern

    def unit(carry, p_unit):
        h, aux = carry
        for i, kind in enumerate(kinds):
            h, a = apply_layer(p_unit[f"u{i}"], cfg, kind, h,
                               enc_out=enc_out)
            aux = aux + a
        h = shd.act(h, ("batch", "seq", None))
        return (h, aux), None

    fn = jax.checkpoint(unit) if remat else unit
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    for i, kind in enumerate(cfg.tail_pattern):
        x, a = apply_layer(params["tail"][f"t{i}"], cfg, kind, x,
                           enc_out=enc_out)
        aux = aux + a
    return _norm(cfg, params["final_norm"], x), aux


def loss_fn(params: dict, cfg: ModelConfig, batch: dict, *,
            n_chunks: int = 8, remat: bool = True
            ) -> Tuple[jax.Array, dict]:
    """batch: tokens (b,s), labels (b,s), optional mask/frames/prefix."""
    h, aux = forward(params, cfg, batch["tokens"],
                     prefix_embeds=batch.get("prefix_embeds"),
                     frames=batch.get("frames"), remat=remat)
    labels = batch["labels"]
    mask = batch.get("mask")
    if batch.get("prefix_embeds") is not None:
        p = batch["prefix_embeds"].shape[1]
        h = h[:, p:]                       # loss over text positions only
    ce = L.chunked_softmax_xent(h, params["lm_head"], labels,
                                n_chunks=n_chunks, label_mask=mask)
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    """Sliding-window layers only ever need `window` cache slots — this is
    what makes long_500k feasible for SWA/hybrid archs."""
    window = cfg.window if kind in ("attn", "moe") else cfg.local_window
    return min(max_len, window) if window > 0 else max_len


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int
                     ) -> dict:
    dt = jnp.dtype(cfg.dtype)
    if kind in ("attn", "local", "moe"):
        spec = _attn_spec(cfg, kind)
        c = L.init_kv_cache(batch, _cache_len(cfg, kind, max_len), spec, dt)
    elif kind == "ssm":
        c = M2.init_mamba2_cache(batch, cfg.d_model, cfg.ssm_state, dt)
    elif kind == "rec":
        c = RG.init_rglru_cache(batch, cfg.lru_width or cfg.d_model, dt)
    else:
        raise ValueError(kind)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """``pos`` is a (batch,) per-slot position vector: every batch slot
    decodes at its own position (the continuous-batching cache
    contract), so a freshly admitted request can sit next to one that is
    hundreds of tokens into its generation."""
    cache: Dict = {"pos": jnp.zeros((batch,), jnp.int32), "layers": {}}

    def stack(make):
        return jax.vmap(lambda _: make())(jnp.arange(cfg.repeats))

    for i, kind in enumerate(cfg.layer_pattern):
        cache["layers"][f"u{i}"] = stack(
            lambda kind=kind: init_layer_cache(cfg, kind, batch, max_len))
    if cfg.tail_pattern:
        cache["tail"] = {
            f"t{i}": init_layer_cache(cfg, kind, batch, max_len)
            for i, kind in enumerate(cfg.tail_pattern)}
    if cfg.encoder_layers:
        spec = _attn_spec(cfg, "attn")
        f = cfg.encoder_seq
        shape = (batch, f, spec.n_kv_heads, spec.head_dim)
        cache["cross"] = {
            f"u{i}": {"k": jnp.zeros((cfg.repeats,) + shape, cfg.dtype),
                      "v": jnp.zeros((cfg.repeats,) + shape, cfg.dtype)}
            for i in range(len(cfg.layer_pattern))}
    return cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _sliding_pos(cfg: ModelConfig, kind: str, pos: jax.Array,
                 cache_max: int) -> jax.Array:
    """Ring-buffer write position for bounded (windowed) caches.
    Elementwise, so a (b,) per-slot position vector maps to (b,) ring
    write positions."""
    return jnp.remainder(pos, cache_max)


def decode_layer(p: dict, cache: dict, cfg: ModelConfig, kind: str,
                 x: jax.Array, pos: jax.Array,
                 cross_kv=None, page_table=None) -> Tuple[jax.Array, dict]:
    spec = _attn_spec(cfg, kind)
    if kind in ("attn", "local", "moe"):
        h = _norm(cfg, p["norm1"], x)
        if page_table is not None:
            # block-paged pool: windowed layers page at full length and
            # window-mask in the kernel (the ring-buffer optimization is
            # a dense-cache feature)
            x, cache = L.paged_attention_decode(
                p["attn"], h, cache, page_table, pos, spec, residual=x)
        elif spec.window > 0 and cache["k"].shape[1] <= spec.window:
            # bounded ring-buffer cache (the long_500k enabler)
            wpos = _sliding_pos(cfg, kind, pos, cache["k"].shape[1])
            x, cache = _decode_ring(p, cache, spec, h, pos, wpos,
                                    residual=x)
        else:
            x, cache = L.attention_decode(p["attn"], h, cache, pos, spec,
                                          residual=x)
        if cross_kv is not None:
            q = _norm(cfg, p["norm_x"], x)
            x = L.attention_block(
                p["cross"], q, spec, kv=(cross_kv["k"], cross_kv["v"]),
                residual=x)
        h = _norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, _ = MOE.moe_ffn(p["moe"], h, top_k=cfg.top_k,
                               capacity_factor=4.0)
            x = x + y
        else:
            x = _mlp(cfg, p["mlp"], h, residual=x)
    elif kind == "ssm":
        y, cache = M2.mamba2_decode(p["mixer"], _norm(cfg, p["norm1"], x),
                                    cache, cfg.ssm_state)
        x = x + y
    elif kind == "rec":
        y, cache = RG.rglru_decode(p["rec"], _norm(cfg, p["norm1"], x),
                                   cache)
        x = x + y
        x = _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], x), residual=x)
    return x, cache


def _decode_ring(p, cache, spec: L.AttnLayerSpec, x, pos, wpos,
                 residual=None):
    """Windowed decode against a ring-buffer cache of size <= window:
    every resident entry is in-window by construction, so attention masks
    only un-written slots.  ``pos``/``wpos`` are (b,) per-slot vectors —
    each row writes at its own ring offset and masks at its own
    fill level."""
    b = x.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    wpos = jnp.broadcast_to(jnp.asarray(wpos, jnp.int32), (b,))
    positions = pos[:, None]
    q, k_new, v_new = L._project_qkv(p["attn"], x, spec, positions)
    k_cache = L.scatter_rows(cache["k"], k_new, wpos)
    v_cache = L.scatter_rows(cache["v"], v_new, wpos)
    groups = spec.n_heads // spec.n_kv_heads
    cache_max = k_cache.shape[1]
    # bf16 operands + fp32 accumulation: never materialize an f32 cache
    qg = q.reshape(b, 1, spec.n_kv_heads, groups, spec.head_dim)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                        preferred_element_type=jnp.float32) \
        * spec.head_dim ** -0.5
    slot = jnp.arange(cache_max)
    written = slot[None, :] <= pos[:, None]   # before first wrap
    written |= pos[:, None] >= cache_max      # after wrap: all slots valid
    logits = jnp.where(written[:, None, None, None, :], logits,
                       jnp.float32(-1e30))
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32) \
        .astype(x.dtype)
    out = ops.gemm(out.reshape(b, 1, -1), p["attn"]["wo"],
                   residual=residual)
    return out, {"k": k_cache, "v": v_cache}


def decode_step(params: dict, cfg: ModelConfig, token: jax.Array,
                cache: dict) -> Tuple[jax.Array, dict]:
    """One decode step.  token: (b, 1) int32.  Returns (logits (b, V),
    updated cache).

    ``cache["pos"]`` is (b,): every batch slot decodes at its own
    position, so one compiled step serves a continuous batch of requests
    at arbitrary phases of their generations."""
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32),
                           (token.shape[0],))
    x = L.embed(params["embed"], token)
    x = _maybe_abs_pos(cfg, x, pos)
    kinds = cfg.layer_pattern
    table = cache.get("page_table")

    def unit(h, xs):
        p_unit, c_unit, x_unit = xs
        new_c = {}
        for i, kind in enumerate(kinds):
            ck = f"u{i}"
            h, new_c[ck] = decode_layer(
                p_unit[ck], c_unit[ck], cfg, kind, h, pos,
                cross_kv=x_unit[ck] if x_unit is not None else None,
                page_table=table)
        return h, new_c

    cross = cache.get("cross")
    xs = (params["layers"], cache["layers"], cross)
    x, new_layer_cache = jax.lax.scan(unit, x, xs)
    new_cache = dict(cache, layers=new_layer_cache, pos=pos + 1)
    if cfg.tail_pattern:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            tk = f"t{i}"
            x, new_tail[tk] = decode_layer(
                params["tail"][tk], cache["tail"][tk], cfg, kind, x, pos,
                page_table=table)
        new_cache["tail"] = new_tail
    x = _norm(cfg, params["final_norm"], x)
    logits = ops.gemm(x[:, 0], params["lm_head"], out_dtype=jnp.float32)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill_layer(p: dict, cache: dict, cfg: ModelConfig, kind: str,
                  x: jax.Array, cross_kv=None) -> Tuple[jax.Array, dict]:
    """Full-prompt forward that also fills this layer's cache (fresh cache,
    prompt starts at position 0)."""
    b, s, _ = x.shape
    spec = _attn_spec(cfg, kind)
    if kind in ("attn", "local", "moe"):
        h = _norm(cfg, p["norm1"], x)
        positions = jnp.arange(s)
        q, k, v = L._project_qkv(p["attn"], h, spec, positions)
        out = ops.attention(q, k, v, causal=True, window=spec.window)
        out = ops.gemm(out.reshape(b, s, -1), p["attn"]["wo"],
                       residual=x)
        cache_max = cache["k"].shape[1]
        if cache_max >= s:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        else:   # windowed ring buffer: keep the tail, ring-aligned
            tail_k, tail_v = k[:, s - cache_max:], v[:, s - cache_max:]
            shift = jnp.remainder(s - cache_max, cache_max)
            ck = jnp.roll(tail_k, shift, axis=1)
            cv = jnp.roll(tail_v, shift, axis=1)
        cache = {"k": ck, "v": cv}
        x = out
        if cross_kv is not None:
            qx = _norm(cfg, p["norm_x"], x)
            x = L.attention_block(
                p["cross"], qx, spec, kv=(cross_kv["k"], cross_kv["v"]),
                residual=x)
        hh = _norm(cfg, p["norm2"], x)
        if kind == "moe":
            y, _ = MOE.moe_ffn(p["moe"], hh, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor)
            x = x + y
        else:
            x = _mlp(cfg, p["mlp"], hh, residual=x)
    elif kind == "ssm":
        h = _norm(cfg, p["norm1"], x)
        y, cache = _mamba2_prefill(p["mixer"], h, cache, cfg.ssm_state)
        x = x + y
    elif kind == "rec":
        h = _norm(cfg, p["norm1"], x)
        y, cache = _rglru_prefill(p["rec"], h, cache)
        x = x + y
        x = _mlp(cfg, p["mlp"], _norm(cfg, p["norm2"], x), residual=x)
    return x, cache


def _mamba2_prefill(p, x, cache, d_state):
    bsz, s, d_model = x.shape
    dd = M2.dims(d_model, d_state)
    proj = ops.gemm(x, p["in_proj"])
    z, xs, b_, c_, dt = M2._split_proj(proj, d_model, d_state)
    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)
    conv_out, conv_state = M2._causal_conv(conv_in, p["conv_w"],
                                           p["conv_b"], cache["conv"])
    xs = conv_out[..., :dd["d_inner"]]
    b_ = conv_out[..., dd["d_inner"]:dd["d_inner"] + d_state]
    c_ = conv_out[..., dd["d_inner"] + d_state:]
    xh = xs.reshape(bsz, s, dd["heads"], dd["head_dim"])
    y, state = M2.ssd_chunked(xh, dt, p["a_log"], b_, c_, p["d_skip"],
                              p["dt_bias"], init_state=cache["ssd"])
    y = y.reshape(bsz, s, dd["d_inner"])
    y = L.rms_norm(p["norm"], y) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return ops.gemm(y, p["out_proj"]), {"conv": conv_state, "ssd": state}


def _rglru_prefill(p, x, cache):
    proj = ops.gemm(x, p["in_proj"])
    branch, gate = jnp.split(proj, 2, axis=-1)
    branch, conv_state = RG._conv(branch, p["conv_w"], p["conv_b"],
                                  cache["conv"])
    a, bx = RG._gates(p, branch)
    h = RG._lru_scan(a, bx, cache["h"])
    y = h.astype(x.dtype) \
        * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return ops.gemm(y, p["out_proj"]), \
        {"conv": conv_state, "h": h[:, -1, :]}


def prefill(params: dict, cfg: ModelConfig, tokens: jax.Array,
            cache: dict, *, prefix_embeds=None, frames=None
            ) -> Tuple[jax.Array, dict]:
    """Run the prompt, fill caches.  Returns (last-token logits, cache)."""
    x = L.embed(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = _maybe_abs_pos(cfg, x, 0)
    s_total = x.shape[1]
    kinds = cfg.layer_pattern

    cross = cache.get("cross")
    if frames is not None:
        enc_out = _encode(params, cfg, frames)
        cross = {}
        for i in range(len(kinds)):
            ck = f"u{i}"
            k, v = jax.vmap(
                lambda pl: _project_cross_kv(pl, cfg, enc_out))(
                    params["layers"][ck])
            cross[ck] = {"k": k, "v": v}

    def unit(h, xs):
        p_unit, c_unit, x_unit = xs
        new_c = {}
        for i, kind in enumerate(kinds):
            ck = f"u{i}"
            h, new_c[ck] = prefill_layer(
                p_unit[ck], c_unit[ck], cfg, kind, h,
                cross_kv=x_unit[ck] if x_unit is not None else None)
        return h, new_c

    xs = (params["layers"], cache["layers"], cross)
    x, new_layer_cache = jax.lax.scan(unit, x, xs)
    new_cache = dict(cache, layers=new_layer_cache,
                     pos=jnp.full((tokens.shape[0],), s_total, jnp.int32))
    if cfg.tail_pattern:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            tk = f"t{i}"
            x, new_tail[tk] = prefill_layer(
                params["tail"][tk], cache["tail"][tk], cfg, kind, x)
        new_cache["tail"] = new_tail
    x = _norm(cfg, params["final_norm"], x)
    logits = ops.gemm(x[:, -1], params["lm_head"], out_dtype=jnp.float32)
    if cross is not None:
        new_cache["cross"] = cross
    return logits, new_cache


# ---------------------------------------------------------------------------
# Slot-targeted prefill (continuous batching)
# ---------------------------------------------------------------------------

def _cache_batch_dim(path, leaf) -> int:
    """Batch axis of one cache leaf: leaves under the scanned ``layers``
    / ``cross`` subtrees are stacked (repeats, batch, ...); ``tail`` and
    ``pos`` leaves carry batch at dim 0 (mirrors
    :func:`repro.dist.layout.cache_specs`)."""
    keys = [str(p.key) for p in path
            if isinstance(p, jax.tree_util.DictKey)]
    stacked = bool(keys) and keys[0] in ("layers", "cross")
    return 1 if stacked and leaf.ndim >= 2 else 0


def insert_cache_slot(live: dict, sub: dict, slot: jax.Array) -> dict:
    """Scatter a batch-1 cache into batch row ``slot`` of a live
    multi-slot cache (``jax.lax.dynamic_update_slice`` on every leaf's
    batch dim) — resident slots are untouched."""
    slot = jnp.asarray(slot, jnp.int32)

    def one(path, leaf, subleaf):
        return jax.lax.dynamic_update_slice_in_dim(
            leaf, subleaf.astype(leaf.dtype), slot,
            axis=_cache_batch_dim(path, leaf))

    return jax.tree_util.tree_map_with_path(one, live, sub)


def prefill_into_slot(params: dict, cfg: ModelConfig, tokens: jax.Array,
                      cache: dict, slot: jax.Array, *, max_len: int,
                      prefix_embeds=None, frames=None
                      ) -> Tuple[jax.Array, dict]:
    """Admit ONE request into slot ``slot`` of a live multi-slot cache.

    The (1, s) prompt prefills a fresh batch-1 cache, and every leaf —
    k/v, ring/conv/SSM states, the per-slot ``pos`` — is scattered into
    the slot's batch row; resident slots keep decoding from exactly the
    state they had (no re-prefill).  Stale entries beyond the new
    request's length are invisible by construction: decode masks cache
    positions > ``pos[slot]`` and overwrites them sequentially.

    Returns (last-token logits (1, V), updated cache).  ``slot`` may be
    traced, so one compiled prefill per prompt length serves every slot.
    """
    assert tokens.shape[0] == 1, "slot prefill admits one request"
    fresh = init_cache(cfg, 1, max_len)
    logits, sub = prefill(params, cfg, tokens, fresh,
                          prefix_embeds=prefix_embeds, frames=frames)
    return logits, insert_cache_slot(cache, sub, slot)


# ---------------------------------------------------------------------------
# Block-paged KV cache (serve)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, batch: int, n_pages: int,
                     page_size: int, max_pages: int) -> dict:
    """Decode cache whose attention K/V live in a shared block pool.

    Every attn-family layer gets one {"k", "v"} pool of
    ``(n_pages, page_size, n_kv_heads, head_dim)``; slots address it
    through ``cache["page_table"]`` ((batch, max_pages) int32, where
    entry 0 is the engine's reserved sink page — free or mid-prefill
    rows stay all-sink so their junk decode writes never touch live
    pages).  Windowed layers page at full length and rely on kernel
    window masking (the dense path's ring buffer doesn't apply).

    Recurrent layer kinds (ssm/rec) are rejected: their per-slot state
    has no page-table indirection, so chunked prefill would reuse the
    slot's stale state, interleaved decode bursts would mutate a
    mid-prefill slot's recurrence (only attention writes are
    sink-masked), and prefix sharing can't skip tokens through a
    recurrence.  Those archs serve through the dense engine.
    """
    assert not cfg.encoder_layers, \
        "paged cache: encoder-decoder archs unsupported"
    bad = sorted({k for k in cfg.all_kinds if k in ("ssm", "rec")})
    assert not bad, \
        f"paged cache: recurrent layer kinds {bad} unsupported"

    def paged_layer(kind):
        spec = _attn_spec(cfg, kind)
        shape = (n_pages, page_size, spec.n_kv_heads, spec.head_dim)
        dt = jnp.dtype(cfg.dtype)
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    cache: Dict = {
        "pos": jnp.zeros((batch,), jnp.int32),
        "page_table": jnp.zeros((batch, max_pages), jnp.int32),
        "layers": {},
    }

    def stack(make):
        return jax.vmap(lambda _: make())(jnp.arange(cfg.repeats))

    for i, kind in enumerate(cfg.layer_pattern):
        cache["layers"][f"u{i}"] = stack(
            lambda kind=kind: paged_layer(kind))
    if cfg.tail_pattern:
        cache["tail"] = {f"t{i}": paged_layer(kind)
                         for i, kind in enumerate(cfg.tail_pattern)}
    return cache


def _prefill_chunk_layer(p: dict, cache: dict, cfg: ModelConfig,
                         kind: str, x: jax.Array,
                         table_row: jax.Array, start: int
                         ) -> Tuple[jax.Array, dict]:
    """One layer of a fixed-offset prompt chunk against the paged cache.

    ``start`` is static: the chunk's k/v scatter indices into
    ``table_row`` and the exact-length history slice are compile-time,
    so the attention call sees operands of exactly ``(s, start + s)``
    — the same per-row math (and bits) as a full-prompt reference
    prefill.  Only attn-family kinds exist here —
    :func:`init_paged_cache` rejects recurrent stacks.
    """
    b, s, _ = x.shape
    if kind not in ("attn", "local", "moe"):
        raise ValueError(f"paged chunk prefill: unsupported layer "
                         f"kind {kind!r}")
    spec = _attn_spec(cfg, kind)
    h = _norm(cfg, p["norm1"], x)
    positions = jnp.arange(start, start + s)
    q, k, v = L._project_qkv(p["attn"], h, spec, positions)
    ps = cache["k"].shape[1]
    pages = table_row[jnp.asarray(
        [(start + j) // ps for j in range(s)])]
    offs = jnp.asarray([(start + j) % ps for j in range(s)],
                       jnp.int32)
    ck = cache["k"].at[pages, offs].set(k[0].astype(cache["k"].dtype))
    cv = cache["v"].at[pages, offs].set(v[0].astype(cache["v"].dtype))
    # same CPU-XLA bf16-hoisting workaround as attention_decode
    ckb, cvb = jax.lax.optimization_barrier((ck, cv))
    n_hist = -(-(start + s) // ps)            # pages holding history
    hist = table_row[:n_hist]
    kf = ckb[hist].reshape(1, n_hist * ps, spec.n_kv_heads,
                           spec.head_dim)[:, :start + s]
    vf = cvb[hist].reshape(1, n_hist * ps, spec.n_kv_heads,
                           spec.head_dim)[:, :start + s]
    out = ops.attention(q, kf, vf, causal=True, window=spec.window,
                        q_offset=start)
    x = ops.gemm(out.reshape(b, s, -1), p["attn"]["wo"], residual=x)
    cache = {"k": ck, "v": cv}
    hh = _norm(cfg, p["norm2"], x)
    if kind == "moe":
        y, _ = MOE.moe_ffn(p["moe"], hh, top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor)
        x = x + y
    else:
        x = _mlp(cfg, p["mlp"], hh, residual=x)
    return x, cache


def prefill_paged_chunk(params: dict, cfg: ModelConfig,
                        tokens: jax.Array, cache: dict, slot: jax.Array,
                        table_row: jax.Array, start_pos: int
                        ) -> Tuple[jax.Array, dict]:
    """Prefill ONE chunk of a prompt into the paged cache.

    tokens: (1, s) — prompt positions [start_pos, start_pos + s);
    ``table_row``: the slot's TRUE (max_pages,) int32 table (the device
    ``cache["page_table"]`` row stays masked/sink until the engine
    promotes the slot after its last chunk, so interleaved decode
    bursts can't read a half-written prompt); ``start_pos`` is STATIC —
    one compiled chunk per (length, offset) pair.

    Prefix sharing enters here too: a prompt whose first ``start_pos``
    tokens ride cached shared pages prefills only its suffix, attending
    the shared history through ``table_row``.  Returns (last-position
    logits (1, V), updated cache) with ``pos[slot] = start_pos + s``.
    """
    assert tokens.shape[0] == 1, "chunk prefill admits one request"
    s = tokens.shape[1]
    slot = jnp.asarray(slot, jnp.int32)
    table_row = jnp.asarray(table_row, jnp.int32)
    x = L.embed(params["embed"], tokens)
    x = _maybe_abs_pos(cfg, x, start_pos)
    kinds = cfg.layer_pattern

    def unit(h, xs):
        p_unit, c_unit = xs
        new_c = {}
        for i, kind in enumerate(kinds):
            ck = f"u{i}"
            h, new_c[ck] = _prefill_chunk_layer(
                p_unit[ck], c_unit[ck], cfg, kind, h, table_row,
                start_pos)
        return h, new_c

    x, new_layer_cache = jax.lax.scan(
        unit, x, (params["layers"], cache["layers"]))
    new_cache = dict(cache, layers=new_layer_cache,
                     pos=cache["pos"].at[slot].set(start_pos + s))
    if cfg.tail_pattern:
        new_tail = {}
        for i, kind in enumerate(cfg.tail_pattern):
            tk = f"t{i}"
            x, new_tail[tk] = _prefill_chunk_layer(
                params["tail"][tk], cache["tail"][tk], cfg, kind, x,
                table_row, start_pos)
        new_cache["tail"] = new_tail
    x = _norm(cfg, params["final_norm"], x)
    logits = ops.gemm(x[:, -1], params["lm_head"], out_dtype=jnp.float32)
    return logits, new_cache


def copy_kv_pages(cache: dict, src: jax.Array, dst: jax.Array) -> dict:
    """Copy physical pages ``src[i] -> dst[i]`` on every paged K/V leaf
    (the copy-on-write primitive: a slot diverging mid-page gets its own
    copy of the shared page before it writes).  src/dst: (n,) int32."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def one(path, leaf):
        keys = [str(p.key) for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        if not keys or keys[-1] not in ("k", "v"):
            return leaf
        if keys[0] == "layers":            # stacked (repeats, pages, ...)
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf.at[dst].set(leaf[src])

    return jax.tree_util.tree_map_with_path(one, cache)
