"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The recurrence:  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
with a_t = exp(-c * softplus(Lambda) * r_t), r_t/i_t input-dependent
sigmoid gates.  Training/prefill uses an associative scan over the
sequence (log-depth); decode is a single state update.

Block structure (Griffin residual block): in-proj to (branch, gate),
short causal conv on the branch, RG-LRU, gated by gelu(gate), out-proj.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.models.layers import _split, dense_init

CONV_WIDTH = 4
C_FACTOR = 8.0


def init_rglru(key, d_model: int, lru_width: int, dtype) -> dict:
    k1, k2, k3, k4, k5, k6 = _split(key, 6)
    # Lambda init so a^c spans ~(0.9, 0.999) as in the paper
    u = jax.random.uniform(k4, (lru_width,), jnp.float32,
                           0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / C_FACTOR) - 1.0)
    return {
        "in_proj": dense_init(k1, d_model, 2 * lru_width, dtype),
        "conv_w": (jax.random.normal(k2, (CONV_WIDTH, lru_width),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((lru_width,), dtype),
        "w_r": dense_init(k3, lru_width, lru_width, dtype),
        "w_i": dense_init(k5, lru_width, lru_width, dtype),
        "lambda": lam,
        "out_proj": dense_init(k6, lru_width, d_model, dtype),
    }


def _conv(x, w, b, state):
    bsz, s, ch = x.shape
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + s, :] * w[i] for i in range(CONV_WIDTH))
    return y + b, xp[:, -(CONV_WIDTH - 1):, :]


def _gates(params, x):
    r = jax.nn.sigmoid(ops.gemm(x, params["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid(ops.gemm(x, params["w_i"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in log space for stability
    gate_x = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gate_x * i * x.astype(jnp.float32)


def _lru_scan(a: jax.Array, bx: jax.Array, h0: jax.Array) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + bx_t along axis 1.
    a, bx: (b, s, w) fp32; h0: (b, w)."""
    bx = bx.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h


def rglru_block(params: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Griffin recurrent block.  x: (b, s, d_model)."""
    bsz, s, _ = x.shape
    lru_width = params["conv_b"].shape[0]
    proj = ops.gemm(x, params["in_proj"])
    branch, gate = jnp.split(proj, 2, axis=-1)
    state0 = jnp.zeros((bsz, CONV_WIDTH - 1, lru_width), x.dtype)
    branch, _ = _conv(branch, params["conv_w"], params["conv_b"], state0)
    a, bx = _gates(params, branch)
    h0 = jnp.zeros((bsz, lru_width), jnp.float32)
    h = _lru_scan(a, bx, h0).astype(x.dtype)
    h = h * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return ops.gemm(h, params["out_proj"])


def init_rglru_cache(batch: int, lru_width: int, dtype) -> dict:
    return {"conv": jnp.zeros((batch, CONV_WIDTH - 1, lru_width), dtype),
            "h": jnp.zeros((batch, lru_width), jnp.float32)}


def rglru_decode(params: dict, x: jax.Array, cache: dict
                 ) -> Tuple[jax.Array, dict]:
    """Single-token step.  x: (b, 1, d_model)."""
    proj = ops.gemm(x, params["in_proj"])
    branch, gate = jnp.split(proj, 2, axis=-1)
    branch, conv_state = _conv(branch, params["conv_w"], params["conv_b"],
                               cache["conv"])
    a, bx = _gates(params, branch)
    h = a[:, 0] * cache["h"] + bx[:, 0]
    y = h[:, None, :].astype(x.dtype) \
        * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return ops.gemm(y, params["out_proj"]), {"conv": conv_state, "h": h}
