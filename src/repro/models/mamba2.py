"""Mamba-2 (SSD — state-space duality) block.

Implements the chunked, matmul-form SSD algorithm from arXiv:2405.21060:
the sequence is split into chunks; within a chunk the output is a masked
(attention-like) matmul, across chunks a small recurrent state
(h, p, n) = (heads, head_dim, d_state) is carried.  This keeps the whole
layer GEMM-dominated — which is exactly why the paper's tiled-GEMM
methodology still applies to this attention-free architecture (see
DESIGN.md SSArch-applicability).

Decode is O(1): a single state update per token.

Layout: d_inner = 2 * d_model, heads = d_inner / head_dim, one B/C group
(G=1), scalar A per head (Mamba-2 simplification).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.models.layers import _split, dense_init, init_rms_norm, rms_norm

CONV_WIDTH = 4
HEAD_DIM = 64


def dims(d_model: int, d_state: int) -> dict:
    d_inner = 2 * d_model
    heads = d_inner // HEAD_DIM
    return {"d_inner": d_inner, "heads": heads, "head_dim": HEAD_DIM,
            "d_state": d_state,
            # in_proj produces: z, x, B, C, dt
            "proj_out": 2 * d_inner + 2 * d_state + heads}


def init_mamba2(key, d_model: int, d_state: int, dtype) -> dict:
    dd = dims(d_model, d_state)
    k1, k2, k3, k4, k5 = _split(key, 5)
    conv_channels = dd["d_inner"] + 2 * d_state      # x, B, C get conv'd
    return {
        "in_proj": dense_init(k1, d_model, dd["proj_out"], dtype),
        "conv_w": (jax.random.normal(k2, (CONV_WIDTH, conv_channels),
                                     jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_channels,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, dd["heads"],
                                      dtype=jnp.float32)),
        "d_skip": jnp.ones((dd["heads"],), jnp.float32),
        "dt_bias": jnp.log(
            jnp.exp(jnp.linspace(1e-3, 0.1, dd["heads"],
                                 dtype=jnp.float32)) - 1.0 + 1e-9),
        "norm": init_rms_norm(dd["d_inner"]),
        "out_proj": dense_init(k5, dd["d_inner"], d_model, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.  x: (b, s, ch); w: (W, ch).
    ``state``: (b, W-1, ch) carry-in; returns (y, new state)."""
    bsz, s, ch = x.shape
    if state is None:
        state = jnp.zeros((bsz, CONV_WIDTH - 1, ch), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + s, :] * w[i] for i in range(CONV_WIDTH))
    y = jax.nn.silu((y + b).astype(jnp.float32)).astype(x.dtype)
    return y, xp[:, -(CONV_WIDTH - 1):, :]


def _split_proj(proj: jax.Array, d_model: int, d_state: int):
    dd = dims(d_model, d_state)
    di, h = dd["d_inner"], dd["heads"]
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    b_ = proj[..., 2 * di:2 * di + d_state]
    c_ = proj[..., 2 * di + d_state:2 * di + 2 * d_state]
    dt = proj[..., 2 * di + 2 * d_state:]
    return z, x, b_, c_, dt


def _segsum(a: jax.Array) -> jax.Array:
    """Causal segment-sum: out[i, j] = sum_{j < l <= i} a[l] (lower-tri),
    -inf above the diagonal.  a: (..., q)."""
    q = a.shape[-1]
    cums = jnp.cumsum(a, axis=-1)
    diff = cums[..., :, None] - cums[..., None, :]  # sum_(j,i]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a_log: jax.Array,
                b_: jax.Array, c_: jax.Array, d_skip: jax.Array,
                dt_bias: jax.Array, *, chunk: int = 128,
                init_state: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (bsz, s, h, p); dt: (bsz, s, h); b_, c_: (bsz, s, n) single group.
    Returns (y: (bsz, s, h, p), final_state: (bsz, h, p, n)).
    """
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + dt_bias)      # (b,sp,h)
    if pad:
        # padded positions must neither decay the state (da=0) nor feed it
        valid = (jnp.arange(sp) < s)[None, :, None]
        dtf = jnp.where(valid, dtf, 0.0)
    a = -jnp.exp(a_log)                                          # (h,)
    da = dtf * a                                                  # log-decay
    xb = (x.astype(jnp.float32) * dtf[..., None])                # dt-scaled

    # reshape into chunks: (b, nc, q, ...)
    def ch(t):
        return t.reshape((bsz, nc, chunk) + t.shape[2:])
    xc, dac, bc, cc = ch(xb), ch(da), ch(b_.astype(jnp.float32)), \
        ch(c_.astype(jnp.float32))

    # intra-chunk (diagonal) term: attention-like masked matmul
    lmat = jnp.exp(_segsum(dac.transpose(0, 1, 3, 2)))  # (b,nc,h,q,q)
    scores = jnp.einsum("bzqn,bzkn->bzqk", cc, bc)      # (b,nc,q,q)
    y_diag = jnp.einsum("bzhqk,bzqk,bzkhp->bzqhp", lmat, scores, xc)
    # (k indexes source positions within the chunk)

    # chunk-final states: sum_k decay_to_end(k) * B_k (x) x_k
    cumsum_da = jnp.cumsum(dac, axis=2)                  # (b,nc,q,h)
    decay_to_end = jnp.exp(cumsum_da[:, :, -1:, :] - cumsum_da)
    states = jnp.einsum("bzkh,bzkn,bzkhp->bzhpn",
                        decay_to_end, bc, xc)            # per-chunk state

    # inter-chunk recurrence over nc (sequential scan, nc is small)
    chunk_decay = jnp.exp(cumsum_da[:, :, -1, :])        # (b,nc,h)
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def step(carry, inp):
        st_prev = carry
        st_chunk, decay = inp
        st_new = st_prev * decay[..., None, None] + st_chunk
        return st_new, st_prev

    (final_state, prev_states) = jax.lax.scan(
        step, init_state,
        (states.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # (b,nc,h,p,n)

    # inter-chunk (off-diagonal) output: C_q . decay_from_start . h_prev
    decay_from_start = jnp.exp(cumsum_da)                # (b,nc,q,h)
    y_off = jnp.einsum("bzqn,bzqh,bzhpn->bzqhp",
                       cc, decay_from_start, prev_states)

    y = (y_diag + y_off).reshape(bsz, sp, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y[:, :s].astype(x.dtype), final_state


def mamba2_block(params: dict, x: jax.Array, d_state: int,
                 ) -> jax.Array:
    """Full-sequence Mamba-2 mixer.  x: (b, s, d_model)."""
    bsz, s, d_model = x.shape
    dd = dims(d_model, d_state)
    proj = ops.gemm(x, params["in_proj"])
    z, xs, b_, c_, dt = _split_proj(proj, d_model, d_state)
    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)
    conv_out, _ = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xs = conv_out[..., :dd["d_inner"]]
    b_ = conv_out[..., dd["d_inner"]:dd["d_inner"] + d_state]
    c_ = conv_out[..., dd["d_inner"] + d_state:]
    xh = xs.reshape(bsz, s, dd["heads"], dd["head_dim"])
    y, _ = ssd_chunked(xh, dt, params["a_log"], b_, c_, params["d_skip"],
                       params["dt_bias"])
    y = y.reshape(bsz, s, dd["d_inner"])
    y = rms_norm(params["norm"], y) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return ops.gemm(y, params["out_proj"])


def init_mamba2_cache(batch: int, d_model: int, d_state: int, dtype) -> dict:
    dd = dims(d_model, d_state)
    conv_ch = dd["d_inner"] + 2 * d_state
    return {
        "conv": jnp.zeros((batch, CONV_WIDTH - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, dd["heads"], dd["head_dim"], d_state),
                         jnp.float32),
    }


def mamba2_decode(params: dict, x: jax.Array, cache: dict, d_state: int
                  ) -> Tuple[jax.Array, dict]:
    """Single-token step.  x: (b, 1, d_model)."""
    bsz, s, d_model = x.shape
    assert s == 1
    dd = dims(d_model, d_state)
    proj = ops.gemm(x, params["in_proj"])
    z, xs, b_, c_, dt = _split_proj(proj, d_model, d_state)
    conv_in = jnp.concatenate([xs, b_, c_], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"],
                                        params["conv_b"], cache["conv"])
    xs = conv_out[..., :dd["d_inner"]]
    b_ = conv_out[..., dd["d_inner"]:dd["d_inner"] + d_state]
    c_ = conv_out[..., dd["d_inner"] + d_state:]

    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"])           # (b, h)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(dtf * a)                             # (b, h)
    xh = xs[:, 0].reshape(bsz, dd["heads"], dd["head_dim"])
    xb = xh.astype(jnp.float32) * dtf[..., None]
    state = cache["ssd"] * decay[..., None, None] \
        + jnp.einsum("bn,bhp->bhpn", b_[:, 0].astype(jnp.float32), xb)
    y = jnp.einsum("bn,bhpn->bhp", c_[:, 0].astype(jnp.float32), state)
    y = y + xh.astype(jnp.float32) * params["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, dd["d_inner"]).astype(x.dtype)
    y = rms_norm(params["norm"], y) \
        * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = ops.gemm(y, params["out_proj"])
    return out, {"conv": conv_state, "ssd": state}
