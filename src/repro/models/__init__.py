from repro.models import transformer  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
    prefill_into_slot,
)
