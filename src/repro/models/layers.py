"""Shared model layers: norms, rotary embeddings, MLPs, GQA attention
(with KV cache + sliding window), embeddings, chunked cross-entropy.

Params are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of pure functions ``init_*(key, ...) -> params`` and
``*(params, x, ...) -> y``.  All dense projections route through the
planned :func:`repro.ops.gemm` (GemmSpec -> plan -> execute) so the
paper's tiled-GEMM layer is the compute substrate of every
architecture.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import ops
from repro.dist import sharding as shd
from repro.kernels.ref import NEG_INF


def _split(key, n):
    return jax.random.split(key, n)


def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std) \
        .astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rms_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def init_layer_norm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps) * params["scale"] \
        + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (b, s, h, d) with even d; positions: (b, s) or (s,)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_swiglu(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = _split(key, 3)
    return {"w_gate": dense_init(k1, d, d_ff, dtype),
            "w_up": dense_init(k2, d, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d, dtype)}


def swiglu(params: dict, x: jax.Array,
           residual: Optional[jax.Array] = None) -> jax.Array:
    """SwiGLU through the fused dual-B gated kernel: one call computes
    silu(x W_gate) * (x W_up) with a single resident x stream — the
    (m, d_ff) gate/up intermediates never round-trip through HBM the way
    the old three-GEMM + XLA-silu composition did.  ``residual`` (the
    transformer residual-stream x) fuses into the down-projection's
    flush."""
    h = ops.gemm(x, params["w_gate"], b2=params["w_up"],
                 activation="silu")
    h = shd.act(h, ("batch", None, "model"))
    return ops.gemm(h, params["w_down"], residual=residual)


def init_gelu_mlp(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2 = _split(key, 2)
    return {"w_in": dense_init(k1, d, d_ff, dtype),
            "w_out": dense_init(k2, d_ff, d, dtype)}


def gelu_mlp(params: dict, x: jax.Array,
             residual: Optional[jax.Array] = None) -> jax.Array:
    h = ops.gemm(x, params["w_in"], activation="gelu")
    h = shd.act(h, ("batch", None, "model"))
    return ops.gemm(h, params["w_out"], residual=residual)


# ---------------------------------------------------------------------------
# GQA attention with KV cache + sliding window
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnLayerSpec:
    """Layer *configuration* (weights + head geometry) — distinct from
    ``ops.AttnSpec``, which describes one attention *operation* to the
    kernel planner."""

    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    window: int = 0          # 0 = full attention
    rope_theta: float = 10000.0
    causal: bool = True
    use_rope: bool = True


def init_attention(key, spec: AttnLayerSpec, dtype) -> dict:
    k1, k2, k3, k4 = _split(key, 4)
    d, hd = spec.d_model, spec.head_dim
    return {
        "wq": dense_init(k1, d, spec.n_heads * hd, dtype),
        "wk": dense_init(k2, d, spec.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, d, spec.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, spec.n_heads * hd, d, dtype),
    }


def _project_qkv(params, x, spec: AttnLayerSpec, positions):
    b, s, _ = x.shape
    q = ops.gemm(x, params["wq"]).reshape(b, s, spec.n_heads, spec.head_dim)
    k = ops.gemm(x, params["wk"]).reshape(b, s, spec.n_kv_heads,
                                          spec.head_dim)
    v = ops.gemm(x, params["wv"]).reshape(b, s, spec.n_kv_heads,
                                          spec.head_dim)
    if spec.use_rope:
        q = rope(q, positions, spec.rope_theta)
        k = rope(k, positions, spec.rope_theta)
    return q, k, v


def project_kv(params: dict, memory: jax.Array, spec: AttnLayerSpec
               ) -> Tuple[jax.Array, jax.Array]:
    """Project cross-attention k/v heads from raw encoder memory."""
    b, f, _ = memory.shape
    k = ops.gemm(memory, params["wk"]).reshape(b, f, spec.n_kv_heads,
                                               spec.head_dim)
    v = ops.gemm(memory, params["wv"]).reshape(b, f, spec.n_kv_heads,
                                               spec.head_dim)
    return k, v


def attention_block(params: dict, x: jax.Array, spec: AttnLayerSpec,
                    positions: Optional[jax.Array] = None,
                    kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    memory: Optional[jax.Array] = None,
                    residual: Optional[jax.Array] = None) -> jax.Array:
    """Full-sequence (train / prefill / encoder) attention.

    Cross-attention: pass ``memory`` (raw (b, f, d) encoder output — k/v
    are projected here) or ``kv`` (already-projected heads, e.g. from a
    decode cache).  Either disables causality.

    ``residual`` (the pre-norm residual-stream x) fuses into the output
    projection's kernel flush instead of a separate XLA add.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    if kv is None and memory is None:
        q, k, v = _project_qkv(params, x, spec, positions)
        out = ops.attention(q, k, v, causal=spec.causal,
                            window=spec.window)
    else:
        q = ops.gemm(x, params["wq"]).reshape(b, s, spec.n_heads,
                                              spec.head_dim)
        if spec.use_rope:
            q = rope(q, positions, spec.rope_theta)
        if kv is None:
            kv = project_kv(params, memory, spec)
        k, v = kv
        out = ops.attention(q, k, v, causal=False, window=0)
    out = shd.act(out, ("batch", None, "model", None))
    return ops.gemm(out.reshape(b, s, -1), params["wo"],
                    residual=residual)


def init_kv_cache(batch: int, max_len: int, spec: AttnLayerSpec, dtype) -> dict:
    shape = (batch, max_len, spec.n_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def scatter_rows(cache: jax.Array, new: jax.Array, idx: jax.Array
                 ) -> jax.Array:
    """Per-row dynamic insertion: row ``i`` of ``cache`` (b, S, ...) takes
    ``new[i]`` (1, ...) at sequence position ``idx[i]`` — the per-slot
    write primitive of continuous batching, where every batch row decodes
    at its own position."""
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n, i, axis=0))(cache, new, idx)


def attention_decode(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, spec: AttnLayerSpec,
                     residual: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, dict]:
    """Single-step decode: insert each row's k/v at its own position
    ``pos`` ((b,) int32, scalar broadcasts) and attend over the cache
    with per-row position masking (+ sliding window).

    x: (b, 1, d).  Returns (out (b, 1, d), new cache); ``residual`` fuses
    the residual-stream add into the output projection.
    """
    b, s, _ = x.shape
    assert s == 1
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(params, x, spec, positions)

    k_cache = scatter_rows(cache["k"], k_new, pos)
    v_cache = scatter_rows(cache["v"], v_new, pos)
    # pin the cache values inside the layer loop: without this, CPU
    # XLA's bf16-dot legalization hoists a convert of the ENTIRE stacked
    # cache out of the scan and maintains a second full-precision copy
    # (full-stack rewrite per layer); on TPU the bf16 dot is native and
    # the barrier is free
    k_att, v_att = jax.lax.optimization_barrier((k_cache, v_cache))

    out = ops.decode_attention(q[:, 0], k_att, v_att, pos,
                               window=spec.window)
    out = ops.gemm(out.reshape(b, 1, -1), params["wo"],
                   residual=residual)
    return out, {"k": k_cache, "v": v_cache}


def paged_attention_decode(params: dict, x: jax.Array, cache: dict,
                           page_table: jax.Array, pos: jax.Array,
                           spec: AttnLayerSpec,
                           residual: Optional[jax.Array] = None
                           ) -> Tuple[jax.Array, dict]:
    """Single-step decode against a block-paged KV pool.

    ``cache``: {"k", "v"} of (n_pages, page_size, hkv, hd) — one pool
    shared by every slot; ``page_table``: (b, max_pages) int32 per-slot
    tables.  Row i's new k/v lands in physical page
    ``page_table[i, pos[i] // page_size]`` at offset ``pos[i] %
    page_size``; rows the engine has masked (all-sink tables) write
    into the reserved sink page, which no live table references.
    """
    b, s, _ = x.shape
    assert s == 1
    ps = cache["k"].shape[1]
    max_pages = page_table.shape[1]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(params, x, spec, positions)

    rows = jnp.arange(b)
    # clamp so a masked row whose junk position overruns the table still
    # indexes in-bounds (it lands on the sink page regardless)
    pages = page_table[rows, jnp.minimum(pos // ps, max_pages - 1)]
    offs = pos % ps
    k_cache = cache["k"].at[pages, offs].set(
        k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[pages, offs].set(
        v_new[:, 0].astype(cache["v"].dtype))
    # same CPU-XLA bf16-hoisting workaround as the dense path
    k_att, v_att = jax.lax.optimization_barrier((k_cache, v_cache))

    out = ops.decode_attention_paged(q[:, 0], k_att, v_att, page_table,
                                     pos, window=spec.window)
    out = ops.gemm(out.reshape(b, 1, -1), params["wo"],
                   residual=residual)
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Embedding + chunked cross-entropy
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02) \
        .astype(dtype)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def chunked_softmax_xent(h: jax.Array, lm_head: jax.Array,
                         labels: jax.Array, *, n_chunks: int = 8,
                         label_mask: Optional[jax.Array] = None
                         ) -> jax.Array:
    """Cross-entropy over a large vocab without materializing full logits.

    h: (b, s, d); lm_head: (d, V); labels: (b, s) int32.  Chunks run over
    the *sequence* axis (lax.map), so each chunk keeps the batch dim —
    and with it the 'data'-axis sharding — while peak logits memory is
    (b, s/n_chunks, V) instead of (b, s, V).
    """
    b, s, d = h.shape
    n_chunks = max(1, min(n_chunks, s))
    pad = (-s) % n_chunks
    mf = jnp.ones((b, s), jnp.float32) if label_mask is None \
        else label_mask.astype(jnp.float32)
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mf = jnp.pad(mf, ((0, 0), (0, pad)))
    cs = (s + pad) // n_chunks
    hs = h.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)
    ms = mf.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    def chunk_loss(args):
        hc, lc, mc = args                       # (b, cs, d) / (b, cs)
        # fp32 logits come straight out of the GEMM accumulator
        # (out_dtype) — no bf16 logits tensor is written and re-upcast,
        # and the reference path keeps operands at storage dtype
        # (preferred_element_type accumulation), so no fp32 copy of
        # lm_head round-trips HBM either
        logits = ops.gemm(hc, lm_head, out_dtype=jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mc), jnp.sum(mc)

    losses, counts = jax.lax.map(jax.checkpoint(chunk_loss), (hs, ls, ms))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)
