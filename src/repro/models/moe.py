"""Mixture-of-Experts FFN: sort-based dispatch + grouped ragged expert
GEMMs + shard_map expert parallelism.

Design notes (EP posture for kimi-k2's 384 experts / qwen3's 128):

* Routing: softmax -> top-k -> renormalized gates (standard token-choice).
* Dispatch (:func:`_sort_dispatch`): tokens are *sorted by expert* and
  packed **ragged** — a ``(t*k, d)`` buffer where expert ``e``'s rows
  occupy ``[start_e, start_e + size_e)`` with ``size_e =
  min(count_e, C)`` (capacity C per expert, overflow dropped — GShard
  capacity semantics).  No ``(T, E, C)`` one-hot tensor and no padded
  ``(E, C, d)`` compute buffer is ever materialized on the compute path.
* Expert compute (:func:`_expert_gemms`): ONE grouped ragged GEMM per
  projection (``ops.gemm_grouped`` — a single Pallas sweep over the
  concatenated groups against the stacked ``(E, d, f)`` bank), so the
  expert FLOPs are the *true routed rows*, not ``E*C`` dense capacity —
  the megablocks formulation, planned and billed by the same
  spec->plan->execute pipeline as every other GEMM in the model
  (``plan.explain()`` shows the per-group billing and the
  padding-FLOPs saving).  ``REPRO_MOE_GROUPED=0`` falls back to the
  padded dense einsum (:func:`_expert_gemms_dense`), kept as the A/B
  baseline and capacity-FLOPs reference.
* **EP path** (:func:`_moe_ffn_ep`, the default under a mesh): the
  dispatch runs inside ``shard_map`` — each device sorts its *local*
  tokens into per-expert send buffers and ONE tiled ``all_to_all`` over
  the 'model' axis delivers every expert its tokens; the per-source
  group sizes ride a second (tiny, ``(E, 1)`` int32) all_to_all so the
  receiver can compact its ``(E/m, m*C, d)`` recv buffer into the same
  ragged layout and run the same grouped GEMMs.  The combine is the
  mirror-image all_to_all.  This is what GSPMD cannot derive from the
  pjit scatter formulation (data-dependent scatter indices into an
  expert-sharded buffer force it to replicate the 150 GB dispatch
  buffer — measured 1.5 TB/device on kimi-k2 train_4k; the shard_map
  path is ~40x smaller and turns the collective term from broadcast
  all-gathers into the minimal token all-to-all).
* **pjit path** (:func:`_moe_ffn_pjit`): kept for decode steps (tiny
  token counts), meshless unit tests, and as the A/B baseline
  (``REPRO_MOE_EP=0``).

Expert banks may arrive quantized (``{"q": int8 (E,k,n), "scale": f32
(E,1,n)}`` from :func:`repro.quant.quantize_params`) — the grouped GEMM
dequantizes in-register per expert panel (W8A16); the dense fallback
and the dense oracle dequantize up front.

The load-balancing auxiliary loss (Switch-style) is returned alongside,
computed from the dispatch's own expert counts and psum-reduced over
the mesh on the EP path.  When telemetry is enabled the pjit path
emits ``moe.group_sizes`` (routed rows actually computed) and
``moe.dropped_tokens`` (capacity-dropped assignments) counters.
"""

from __future__ import annotations

import math
import os
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro import ops, quant, telemetry
from repro.models.layers import dense_init, _split


def init_moe(key, d: int, d_ff: int, n_experts: int, dtype) -> dict:
    k1, k2, k3, k4 = _split(key, 4)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(d_ff)

    def expert_init(k, shape, std):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)

    return {
        "router": dense_init(k1, d, n_experts, jnp.float32),
        "w_gate": expert_init(k2, (n_experts, d, d_ff), std_in),
        "w_up": expert_init(k3, (n_experts, d, d_ff), std_in),
        "w_down": expert_init(k4, (n_experts, d_ff, d), std_out),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             factor: float = 1.25, multiple: int = 8) -> int:
    c = math.ceil(n_tokens * top_k * factor / n_experts)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def grouped_enabled() -> bool:
    """Grouped ragged expert GEMMs (default); ``REPRO_MOE_GROUPED=0``
    selects the padded dense-einsum baseline."""
    return os.environ.get("REPRO_MOE_GROUPED", "1") != "0"


def ep_enabled() -> bool:
    return os.environ.get("REPRO_MOE_EP", "1") != "0"


class MoeDispatch(NamedTuple):
    """Sort-based dispatch of ``t*k`` (token, expert) assignments.

    The assignment axis is sorted by expert (stable, so source order is
    preserved within each expert).  ``xs`` is the ragged pack: kept
    assignment ``i`` lives at row ``dest[i]`` — expert ``e``'s rows are
    ``[starts_e, starts_e + sizes[e])`` with the group starts the
    exclusive cumsum of ``sizes`` — and rows past ``sum(sizes)`` are
    zero.  Dropped assignments (position within their expert >= the
    capacity) have ``dest == t*k`` (out of range) and ``in_cap False``.
    """

    xs: jax.Array           # (t*k, d) ragged expert-sorted tokens
    sizes: jax.Array        # (E,) int32 kept rows per expert (<= capacity)
    counts: jax.Array       # (E,) int32 raw routed counts (pre-capacity)
    dest: jax.Array         # (t*k,) ragged row per assignment (t*k = drop)
    slot: jax.Array         # (t*k,) position within the expert group
    token_idx: jax.Array    # (t*k,) source token of each assignment
    order: jax.Array        # (t*k,) argsort permutation of flat ids
    in_cap: jax.Array       # (t*k,) bool — assignment kept
    sorted_e: jax.Array     # (t*k,) expert id, ascending


def _sort_dispatch(xe: jax.Array, top_ids: jax.Array, top_k: int,
                   n_experts: int, c: int) -> MoeDispatch:
    """Sort tokens by expert into the ragged ``(t*k, d)`` pack
    (overflow beyond capacity ``c`` dropped)."""
    t = xe.shape[0]
    flat_e = top_ids.reshape(-1)                               # (t*k,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    token_idx = order // top_k
    counts = jnp.bincount(flat_e, length=n_experts).astype(jnp.int32)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    slot = jnp.arange(t * top_k, dtype=jnp.int32) - starts[sorted_e]
    in_cap = slot < c
    sizes = jnp.minimum(counts, c)
    rstarts = jnp.cumsum(sizes) - sizes                        # ragged
    # out-of-capacity entries get dest=t*k -> dropped by scatter 'drop'
    dest = jnp.where(in_cap, rstarts[sorted_e] + slot, t * top_k)
    xs = jnp.zeros((t * top_k, xe.shape[-1]), xe.dtype)
    xs = xs.at[dest].set(xe[token_idx], mode="drop")
    return MoeDispatch(xs, sizes, counts, dest, slot, token_idx, order,
                       in_cap, sorted_e)


def _route(xe: jax.Array, router: jax.Array, top_k: int):
    logits = ops.gemm(xe, router, out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (t, E)
    gate_vals, top_ids = jax.lax.top_k(probs, top_k)           # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    return probs, gate_vals, top_ids


def _aux_loss(counts: jax.Array, probs: jax.Array, n_tokens) -> jax.Array:
    """Switch-style load-balance loss ``E * sum_e f_e * p_e`` straight
    from the dispatch's expert counts (``f_e = counts_e / t`` — the same
    value the one-hot formulation computes, without re-materializing
    the (t, k, E) one-hot)."""
    n_experts = counts.shape[0]
    freq = counts.astype(jnp.float32) / n_tokens
    return n_experts * jnp.sum(freq * jnp.mean(probs, axis=0))


def _bank(w, dtype) -> jax.Array:
    """Dense view of an expert bank (dequantizes ``{"q","scale"}``)."""
    return quant.dequantize_weight(w, dtype) if quant.is_quantized(w) \
        else w


def _expert_gemms(params: dict, xs: jax.Array, sizes: jax.Array,
                  dtype, dense_rows: int = 0) -> jax.Array:
    """SwiGLU over the ragged expert-sorted rows: three grouped ragged
    GEMMs against the stacked banks (silu fused into the gate GEMM's
    epilogue).  Quantized banks stream int8 and dequantize in-register
    (W8A16).  ``dense_rows`` is the E*C capacity row count the padded
    formulation would compute — plan-level billing context only."""
    dr = dense_rows or None
    gate = ops.gemm_grouped(xs, params["w_gate"], sizes,
                            activation="silu", out_dtype=dtype,
                            dense_rows=dr)
    up = ops.gemm_grouped(xs, params["w_up"], sizes, out_dtype=dtype,
                          dense_rows=dr)
    h = gate * up
    return ops.gemm_grouped(h, params["w_down"], sizes, out_dtype=dtype,
                            dense_rows=dr)


def _expert_gemms_dense(params: dict, buf: jax.Array, dtype) -> jax.Array:
    """Padded dense-capacity baseline: batched einsum over (E, C, d)."""
    w_gate = _bank(params["w_gate"], dtype)
    w_up = _bank(params["w_up"], dtype)
    w_down = _bank(params["w_down"], dtype)
    gate = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _emit_moe_counters(n_assignments: int, sizes: jax.Array) -> None:
    """``moe.group_sizes`` (rows actually routed through the grouped
    GEMMs) and ``moe.dropped_tokens`` (capacity-dropped assignments) —
    host counters fed by a debug callback, trace-time gated on
    :func:`repro.telemetry.enabled`."""
    if not telemetry.enabled():
        return

    def cb(kept):
        rec = telemetry.recorder()
        if rec is not None:
            rec.counter("moe.group_sizes").add(int(kept))
            rec.counter("moe.dropped_tokens").add(
                n_assignments - int(kept))

    jax.debug.callback(cb, jnp.sum(sizes))


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar).

    Dispatches to the shard_map EP path when a mesh with a non-trivial
    'model' axis is active and shapes divide; else the pjit path.
    """
    mesh = shd.current_mesh()
    n_experts = params["router"].shape[-1]
    if mesh is not None and ep_enabled():
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 1)
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        bsz = 1
        for a in batch_axes:
            bsz *= sizes[a]
        b, s, _ = x.shape
        if (m > 1 and n_experts % m == 0 and b % bsz == 0
                and s % m == 0 and (b // bsz) * (s // m) >= 1):
            return _moe_ffn_ep(params, x, top_k=top_k,
                               capacity_factor=capacity_factor,
                               mesh=mesh, batch_axes=batch_axes)
    return _moe_ffn_pjit(params, x, top_k=top_k,
                         capacity_factor=capacity_factor)


def _ep_grouped_gemms(params: dict, recv: jax.Array, sz: jax.Array,
                      c: int, dtype) -> jax.Array:
    """Grouped expert GEMMs on one EP shard's recv buffer.

    ``recv`` is the (E_loc, n_src*c, d) all_to_all product — each local
    expert's tokens arrive as n_src chunks of capacity c with
    ``sz[e, src]`` live rows each.  Compact into the ragged layout
    (one scatter), run the same grouped GEMMs as the pjit path with
    group sizes summed over sources, and scatter back to the dense
    chunk layout the mirror all_to_all expects.
    """
    e_loc, n_src = sz.shape
    d = recv.shape[-1]
    rows = e_loc * n_src * c
    gsize = jnp.sum(sz, axis=1).astype(jnp.int32)              # (E_loc,)
    gstart = jnp.cumsum(gsize) - gsize
    src_off = jnp.cumsum(sz, axis=1) - sz                      # (E_loc, n_src)
    i = jnp.arange(c, dtype=jnp.int32)
    dest = gstart[:, None, None] + src_off[:, :, None] + i[None, None, :]
    valid = i[None, None, :] < sz[:, :, None]
    dest = jnp.where(valid, dest, rows).reshape(rows)          # drop dead
    xs = jnp.zeros((rows, d), dtype).at[dest].set(
        recv.reshape(rows, d), mode="drop")
    ys = _expert_gemms(params, xs, gsize, dtype, dense_rows=rows)
    out = jnp.where(valid.reshape(rows, 1),
                    ys[jnp.minimum(dest, rows - 1)], 0)
    return out.reshape(e_loc, n_src * c, d)


def _moe_ffn_ep(params: dict, x: jax.Array, *, top_k: int,
                capacity_factor: float, mesh, batch_axes
                ) -> Tuple[jax.Array, jax.Array]:
    """shard_map EP: local sort-dispatch + one tiled all_to_all each way.

    Per device: local tokens t_loc = (b/|batch|)·(s/|model|); send buffer
    (E, C_src, d) with per-source-shard capacity C_src; the tiled
    all_to_all over 'model' yields (E/m, m·C_src, d) — every local expert
    sees its tokens from all sources — and the per-source kept counts
    ride an (E, 1) int32 all_to_all alongside so the receiver can pack
    the chunks ragged for the grouped expert GEMMs.  Weights enter with
    full d/f per device (the boundary all-gather is FSDP's per-layer
    unshard, same traffic GSPMD emits).
    """
    n_experts = params["router"].shape[-1]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    all_axes = tuple(batch_axes) + ("model",)

    def local(w_gate, w_up, w_down, router, x_loc):
        b_loc, s_loc, d = x_loc.shape
        t_loc = b_loc * s_loc
        xe = x_loc.reshape(t_loc, d)
        probs, gate_vals, top_ids = _route(xe, router, top_k)
        c_src = capacity(t_loc, n_experts, top_k, capacity_factor)
        dsp = _sort_dispatch(xe, top_ids, top_k, n_experts, c_src)
        slot_c = jnp.where(dsp.in_cap, dsp.slot, c_src)
        buf = jnp.zeros((n_experts, c_src, d), x_loc.dtype)
        buf = buf.at[dsp.sorted_e, slot_c].set(xe[dsp.token_idx],
                                               mode="drop")

        # (E, C, d) -> (E/m, m*C, d): one tiled all_to_all over 'model'
        recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                  concat_axis=1, tiled=True)
        eparams = {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}
        if grouped_enabled():
            sz = jax.lax.all_to_all(
                dsp.sizes.reshape(n_experts, 1), "model",
                split_axis=0, concat_axis=1, tiled=True)       # (E/m, m)
            out_loc = _ep_grouped_gemms(eparams, recv, sz, c_src,
                                        x_loc.dtype)
        else:
            out_loc = _expert_gemms_dense(eparams, recv, x_loc.dtype)
        # mirror: (E/m, m*C, d) -> (E, C, d) back at the source shard
        back = jax.lax.all_to_all(out_loc, "model", split_axis=1,
                                  concat_axis=0, tiled=True)

        gathered = back[dsp.sorted_e, slot_c]                  # (t*k, d)
        weights = (gate_vals.reshape(-1)[dsp.order]
                   * dsp.in_cap.astype(jnp.float32)).astype(x_loc.dtype)
        y = jnp.zeros((t_loc, d), x_loc.dtype).at[dsp.token_idx].add(
            gathered * weights[:, None])

        # global Switch aux loss: psum sums over every mesh axis
        freq_sum = dsp.counts.astype(jnp.float32)
        prob_sum = jnp.sum(probs, axis=0)
        n = jnp.float32(t_loc)
        for ax in all_axes:
            freq_sum = jax.lax.psum(freq_sum, ax)
            prob_sum = jax.lax.psum(prob_sum, ax)
            n = jax.lax.psum(n, ax)
        aux = n_experts * jnp.sum((freq_sum / n) * (prob_sum / n))
        return y.reshape(b_loc, s_loc, d), aux

    batch_spec = batch_axes if batch_axes else None
    fn = shd.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), P(),
                  P(batch_spec, "model", None)),
        out_specs=(P(batch_spec, "model", None), P()),
        check=False)
    return fn(params["w_gate"], params["w_up"], params["w_down"],
              params["router"], x)


def _moe_ffn_pjit(params: dict, x: jax.Array, *, top_k: int,
                  capacity_factor: float = 1.25
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar)."""
    b, s, d = x.shape
    t = b * s
    n_experts = params["router"].shape[-1]
    c = capacity(t, n_experts, top_k, capacity_factor)
    xe = x.reshape(t, d)

    probs, gate_vals, top_ids = _route(xe, params["router"], top_k)
    dsp = _sort_dispatch(xe, top_ids, top_k, n_experts, c)
    aux = _aux_loss(dsp.counts, probs, t)
    _emit_moe_counters(t * top_k, dsp.sizes)

    if grouped_enabled():
        # ragged grouped expert GEMMs over the true routed rows
        ys = _expert_gemms(params, dsp.xs, dsp.sizes, x.dtype,
                           dense_rows=n_experts * c)
        gathered = ys[jnp.minimum(dsp.dest, t * top_k - 1)]    # (t*k, d)
    else:
        # dense-capacity baseline: padded (E, C, d) buffer + einsum
        slot_c = jnp.where(dsp.in_cap, dsp.slot, c)
        buf = jnp.zeros((n_experts, c, d), x.dtype)
        buf = buf.at[dsp.sorted_e, slot_c].set(xe[dsp.token_idx],
                                               mode="drop")
        buf = shd.act(buf, ("expert", None, None))
        out = _expert_gemms_dense(params, buf, x.dtype)
        out = shd.act(out, ("expert", None, None))
        gathered = out[dsp.sorted_e, slot_c]                   # (t*k, d)

    weights = (gate_vals.reshape(-1)[dsp.order]
               * dsp.in_cap.astype(jnp.float32)).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[dsp.token_idx].add(
        gathered * weights[:, None])
    return y.reshape(b, s, d), aux


def moe_ffn_dense_ref(params: dict, x: jax.Array, *, top_k: int
                      ) -> jax.Array:
    """Dense oracle: every expert computed for every token, combined with
    the same renormalized top-k gates, no capacity drops.  Used by tests
    to validate the sort-dispatch path (with capacity_factor high enough
    that nothing drops).  Quantized expert banks are dequantized up
    front, so it also oracles the W8A16 grouped path at einsum
    tolerance."""
    b, s, d = x.shape
    xe = x.reshape(b * s, d)
    logits = xe.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    n_experts = params["router"].shape[-1]
    combine = jnp.zeros_like(probs).at[
        jnp.arange(xe.shape[0])[:, None], top_ids].set(gate_vals)

    w_gate = _bank(params["w_gate"], x.dtype)
    w_up = _bank(params["w_up"], x.dtype)
    w_down = _bank(params["w_down"], x.dtype)
    gate = jnp.einsum("td,edf->tef", xe, w_gate)
    up = jnp.einsum("td,edf->tef", xe, w_up)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("tef,efd->ted", h, w_down)
    y = jnp.einsum("ted,te->td", out.astype(jnp.float32), combine)
    return y.astype(x.dtype).reshape(b, s, d)
