"""Mixture-of-Experts FFN: sort-based dispatch + shard_map expert
parallelism.

Design notes (EP posture for kimi-k2's 384 experts / qwen3's 128):

* Routing: softmax -> top-k -> renormalized gates (standard token-choice).
* Dispatch: tokens are *sorted by expert* and scattered into a dense
  ``(E, C, d)`` buffer (capacity C per expert, overflow dropped — GShard
  capacity semantics) — no (T, E, C) one-hot tensor is ever materialized,
  so dispatch is O(T*k*d) memory and the expert compute is exactly the
  active-parameter FLOPs.
* **EP path** (:func:`_moe_ffn_ep`, the default under a mesh): the
  dispatch runs inside ``shard_map`` — each device sorts its *local*
  tokens into per-expert send buffers and ONE tiled ``all_to_all`` over
  the 'model' axis delivers every expert its tokens, already batched for
  the expert GEMM: ``(E, C, d) -> (E/m, m*C, d)``.  The combine is the
  mirror-image all_to_all.  This is what GSPMD cannot derive from the
  pjit scatter formulation (data-dependent scatter indices into an
  expert-sharded buffer force it to replicate the 150 GB dispatch
  buffer — measured 1.5 TB/device on kimi-k2 train_4k; the shard_map
  path is ~40x smaller and turns the collective term from broadcast
  all-gathers into the minimal token all-to-all).
* **pjit path** (:func:`_moe_ffn_pjit`): kept for decode steps (tiny
  token counts), meshless unit tests, and as the A/B baseline
  (``REPRO_MOE_EP=0``).

The load-balancing auxiliary loss (Switch-style) is returned alongside,
psum-reduced over the mesh on the EP path.
"""

from __future__ import annotations

import math
import os
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro import ops
from repro.models.layers import dense_init, _split


def init_moe(key, d: int, d_ff: int, n_experts: int, dtype) -> dict:
    k1, k2, k3, k4 = _split(key, 4)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(d_ff)

    def expert_init(k, shape, std):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)

    return {
        "router": dense_init(k1, d, n_experts, jnp.float32),
        "w_gate": expert_init(k2, (n_experts, d, d_ff), std_in),
        "w_up": expert_init(k3, (n_experts, d, d_ff), std_in),
        "w_down": expert_init(k4, (n_experts, d_ff, d), std_out),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             factor: float = 1.25, multiple: int = 8) -> int:
    c = math.ceil(n_tokens * top_k * factor / n_experts)
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def _sort_dispatch(xe: jax.Array, top_ids: jax.Array, top_k: int,
                   n_experts: int, c: int):
    """Sort tokens by expert into an (E, c, d) buffer (overflow dropped).
    Returns (buf, sorted_e, slot_c, token_idx, order, in_cap)."""
    t = xe.shape[0]
    flat_e = top_ids.reshape(-1)                               # (t*k,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    token_idx = order // top_k
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    slot = jnp.arange(t * top_k) - starts[sorted_e]            # pos in grp
    in_cap = slot < c
    slot_c = jnp.where(in_cap, slot, c)    # overflow -> dropped by 'drop'
    buf = jnp.zeros((n_experts, c, xe.shape[-1]), xe.dtype)
    buf = buf.at[sorted_e, slot_c].set(xe[token_idx], mode="drop")
    return buf, sorted_e, slot_c, token_idx, order, in_cap


def _route(xe: jax.Array, router: jax.Array, top_k: int):
    logits = ops.gemm(xe, router, out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (t, E)
    gate_vals, top_ids = jax.lax.top_k(probs, top_k)           # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    return probs, gate_vals, top_ids


def _expert_gemms(params: dict, buf: jax.Array, dtype) -> jax.Array:
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * up
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"])


def ep_enabled() -> bool:
    return os.environ.get("REPRO_MOE_EP", "1") != "0"


def moe_ffn(params: dict, x: jax.Array, *, top_k: int,
            capacity_factor: float = 1.25
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar).

    Dispatches to the shard_map EP path when a mesh with a non-trivial
    'model' axis is active and shapes divide; else the pjit path.
    """
    mesh = shd.current_mesh()
    n_experts = params["router"].shape[-1]
    if mesh is not None and ep_enabled():
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        m = sizes.get("model", 1)
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
        bsz = 1
        for a in batch_axes:
            bsz *= sizes[a]
        b, s, _ = x.shape
        if (m > 1 and n_experts % m == 0 and b % bsz == 0
                and s % m == 0 and (b // bsz) * (s // m) >= 1):
            return _moe_ffn_ep(params, x, top_k=top_k,
                               capacity_factor=capacity_factor,
                               mesh=mesh, batch_axes=batch_axes)
    return _moe_ffn_pjit(params, x, top_k=top_k,
                         capacity_factor=capacity_factor)


def _moe_ffn_ep(params: dict, x: jax.Array, *, top_k: int,
                capacity_factor: float, mesh, batch_axes
                ) -> Tuple[jax.Array, jax.Array]:
    """shard_map EP: local sort-dispatch + one tiled all_to_all each way.

    Per device: local tokens t_loc = (b/|batch|)·(s/|model|); send buffer
    (E, C_src, d) with per-source-shard capacity C_src; the tiled
    all_to_all over 'model' yields (E/m, m·C_src, d) — every local expert
    sees its tokens from all sources, already contiguous for the batched
    expert GEMM.  Weights enter with full d/f per device (the boundary
    all-gather is FSDP's per-layer unshard, same traffic GSPMD emits).
    """
    n_experts = params["router"].shape[-1]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    all_axes = tuple(batch_axes) + ("model",)

    def local(w_gate, w_up, w_down, router, x_loc):
        b_loc, s_loc, d = x_loc.shape
        t_loc = b_loc * s_loc
        xe = x_loc.reshape(t_loc, d)
        probs, gate_vals, top_ids = _route(xe, router, top_k)
        c_src = capacity(t_loc, n_experts, top_k, capacity_factor)
        buf, sorted_e, slot_c, token_idx, order, in_cap = \
            _sort_dispatch(xe, top_ids, top_k, n_experts, c_src)

        # (E, C, d) -> (E/m, m*C, d): one tiled all_to_all over 'model'
        recv = jax.lax.all_to_all(buf, "model", split_axis=0,
                                  concat_axis=1, tiled=True)
        out_loc = _expert_gemms(
            {"w_gate": w_gate, "w_up": w_up, "w_down": w_down},
            recv, x_loc.dtype)
        # mirror: (E/m, m*C, d) -> (E, C, d) back at the source shard
        back = jax.lax.all_to_all(out_loc, "model", split_axis=1,
                                  concat_axis=0, tiled=True)

        gathered = back[sorted_e, slot_c]                      # (t*k, d)
        weights = (gate_vals.reshape(-1)[order]
                   * in_cap.astype(jnp.float32)).astype(x_loc.dtype)
        y = jnp.zeros((t_loc, d), x_loc.dtype).at[token_idx].add(
            gathered * weights[:, None])

        # global Switch aux loss: psum sums over every mesh axis
        freq_sum = jnp.sum(
            jax.nn.one_hot(top_ids, n_experts, dtype=jnp.float32),
            axis=(0, 1))
        prob_sum = jnp.sum(probs, axis=0)
        n = jnp.float32(t_loc)
        for ax in all_axes:
            freq_sum = jax.lax.psum(freq_sum, ax)
            prob_sum = jax.lax.psum(prob_sum, ax)
            n = jax.lax.psum(n, ax)
        aux = n_experts * jnp.sum((freq_sum / n) * (prob_sum / n))
        return y.reshape(b_loc, s_loc, d), aux

    batch_spec = batch_axes if batch_axes else None
    fn = shd.shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None, None), P("model", None, None),
                  P("model", None, None), P(),
                  P(batch_spec, "model", None)),
        out_specs=(P(batch_spec, "model", None), P()),
        check=False)
    return fn(params["w_gate"], params["w_up"], params["w_down"],
              params["router"], x)


def _moe_ffn_pjit(params: dict, x: jax.Array, *, top_k: int,
                  capacity_factor: float = 1.25
                  ) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (y: (b, s, d), aux_loss: scalar)."""
    b, s, d = x.shape
    t = b * s
    xe = x.reshape(t, d)
    n_experts = params["router"].shape[-1]
    c = capacity(t, n_experts, top_k, capacity_factor)

    # --- routing ---
    logits = ops.gemm(xe, params["router"], out_dtype=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (t, E)
    gate_vals, top_ids = jax.lax.top_k(probs, top_k)           # (t, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e f_e * p_e.
    freq = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_ids, n_experts, dtype=jnp.float32),
                axis=1), axis=0)
    aux = n_experts * jnp.sum(freq * jnp.mean(probs, axis=0))

    # --- sort-based dispatch ---
    flat_e = top_ids.reshape(-1)                               # (t*k,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    token_idx = order // top_k
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.cumsum(counts) - counts                       # exclusive
    slot = jnp.arange(t * top_k) - starts[sorted_e]            # pos in group
    in_cap = slot < c
    # out-of-capacity entries get slot=c -> dropped by scatter mode='drop'
    slot_c = jnp.where(in_cap, slot, c)

    buf = jnp.zeros((n_experts, c, d), x.dtype)
    buf = buf.at[sorted_e, slot_c].set(xe[token_idx], mode="drop")
    buf = shd.act(buf, ("expert", None, None))

    # --- expert compute (batched over experts -> EP shards this) ---
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out = shd.act(out, ("expert", None, None))

    # --- combine ---
    gathered = out[sorted_e, slot_c]                           # (t*k, d)
    weights = (gate_vals.reshape(-1)[order]
               * in_cap.astype(jnp.float32)).astype(x.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(
        gathered * weights[:, None])
    return y.reshape(b, s, d), aux


def moe_ffn_dense_ref(params: dict, x: jax.Array, *, top_k: int
                      ) -> jax.Array:
    """Dense oracle: every expert computed for every token, combined with
    the same renormalized top-k gates, no capacity drops.  Used by tests
    to validate the sort-dispatch path (with capacity_factor high enough
    that nothing drops)."""
    b, s, d = x.shape
    xe = x.reshape(b * s, d)
    logits = xe.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, top_ids = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    n_experts = params["router"].shape[-1]
    combine = jnp.zeros_like(probs).at[
        jnp.arange(xe.shape[0])[:, None], top_ids].set(gate_vals)

    gate = jnp.einsum("td,edf->tef", xe, params["w_gate"])
    up = jnp.einsum("td,edf->tef", xe, params["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    out = jnp.einsum("tef,efd->ted", h, params["w_down"])
    y = jnp.einsum("ted,te->td", out.astype(jnp.float32), combine)
    return y.astype(x.dtype).reshape(b, s, d)
