"""The assigned input-shape suite and the 40-cell (arch x shape) matrix.

    train_4k      seq 4096   global_batch 256   -> train_step
    prefill_32k   seq 32768  global_batch 32    -> prefill (inference)
    decode_32k    seq 32768  global_batch 128   -> serve_step (1 token,
                                                  KV cache of seq_len)
    long_500k     seq 524288 global_batch 1     -> serve_step; requires
                  sub-quadratic attention: runs only for h2o-danube-3-4b
                  (SWA), mamba2-370m (SSM), recurrentgemma-9b (hybrid);
                  skipped cells are recorded with their reason.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ARCH_IDS, ModelConfig, get_config


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    """None if the cell runs; otherwise the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full attention is quadratic / unbounded-KV at 524k; "
                "runs only for SSM/SWA/hybrid archs (task sheet)")
    return None


def all_cells() -> List[Tuple[str, str, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            out.append((arch, shape.name, skip_reason(cfg, shape)))
    return out


def runnable_cells() -> List[Tuple[str, str]]:
    return [(a, s) for a, s, skip in all_cells() if skip is None]
