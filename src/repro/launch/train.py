"""Production training driver.

Wires every substrate together: config registry -> mesh -> layout engine
shardings -> donated/jitted train step -> deterministic data pipeline ->
async checkpointing -> straggler watchdog -> preemption-safe restart.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 200 --seq-len 512 --global-batch 8 --smoke

On a real cluster each host runs this same driver under its own
process-index (jax.distributed); the mesh builder and the row-sharded
data pipeline are already host-aware, so the single-host path here is
the degenerate case of the multi-pod one.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro import telemetry
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config, get_smoke_config
from repro.data import pipeline
from repro.dist import layout, sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.runtime import elastic
from repro.runtime.fault_tolerance import StepWatchdog
from repro.train import train_step as TS


def build(cfg, mesh, *, peak_lr=3e-4, total_steps=1000, microbatches=1,
          seed=0, optimizer: Optional[str] = None):
    """(state, jitted step, shardings) on ``mesh``."""
    step_fn = TS.make_train_step(cfg, peak_lr=peak_lr,
                                 total_steps=total_steps,
                                 microbatches=microbatches,
                                 optimizer=optimizer)
    with shd.use_mesh(mesh):
        state_struct = jax.eval_shape(
            lambda: TS.init_state(jax.random.PRNGKey(seed), cfg,
                                  optimizer))
        state_sh = elastic.state_shardings(state_struct, cfg, mesh)
        init = jax.jit(
            lambda k: TS.init_state(k, cfg, optimizer),
            out_shardings=state_sh)
        state = init(jax.random.PRNGKey(seed))
        jitted = jax.jit(step_fn, donate_argnums=(0,),
                         in_shardings=(state_sh, None),
                         out_shardings=(state_sh, None))
    return state, jitted, state_sh


def train(cfg, *, steps: int, seq_len: int, global_batch: int,
          mesh=None, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, log_every: int = 10, seed: int = 0,
          microbatches: int = 1, resume: bool = True,
          watchdog: Optional[StepWatchdog] = None) -> dict:
    """Run (or resume) a training job; returns final metrics."""
    mesh = mesh or make_host_mesh(data=len(jax.devices()))
    state, jitted, state_sh = build(cfg, mesh, total_steps=steps,
                                    microbatches=microbatches, seed=seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt and resume and ckpt.latest_step() is not None:
        state = elastic.remesh_restore(ckpt, state, cfg, mesh)
        start = int(state.step)
        print(f"[train] resumed from step {start}")

    data_cfg = pipeline.DataConfig(seq_len=seq_len,
                                   global_batch=global_batch, seed=seed)
    watchdog = watchdog or StepWatchdog()
    metrics = {}
    with shd.use_mesh(mesh):
        for step in range(start, steps):
            batch = pipeline.make_batch(cfg, data_cfg, step)
            t0 = time.time()
            with telemetry.span("train.step", step=step) as sp:
                state, metrics = jitted(state, batch)
                sp.sync(metrics["loss"])
                jax.block_until_ready(metrics["loss"])
            telemetry.counter("train.tokens").add(
                data_cfg.seq_len * data_cfg.global_batch)
            dt = time.time() - t0
            ev = watchdog.observe(step, dt)
            if ev:
                print(f"[train] straggler: step {ev.step} took "
                      f"{ev.duration:.2f}s (median {ev.median:.2f}s)")
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step} loss "
                      f"{float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms")
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, state, blocking=False)
    if ckpt:
        ckpt.save(steps, state, blocking=True)
    return {k: float(v) for k, v in metrics.items()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record per-step spans + GEMM plan events and "
                         "write PATH.jsonl + PATH.trace.json")
    args = ap.parse_args()
    if args.telemetry:
        telemetry.enable()
    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    out = train(cfg, steps=args.steps, seq_len=args.seq_len,
                global_batch=args.global_batch,
                microbatches=args.microbatches,
                ckpt_dir=args.ckpt_dir, seed=args.seed)
    print("[train] final:", {k: round(v, 4) for k, v in out.items()})
    if args.telemetry:
        snap = telemetry.snapshot()
        paths = telemetry.export(args.telemetry)
        print(f"[train] telemetry: {snap['n_events']} events, "
              f"plan cache {snap['plan_cache']}; wrote "
              f"{paths[0]} and {paths[1]}")
        routed = snap["counters"].get("moe.group_sizes")
        if routed is not None:
            dropped = snap["counters"].get("moe.dropped_tokens", 0)
            total = routed + dropped
            print(f"[train] moe: {int(routed)} rows through grouped "
                  f"expert GEMMs, {int(dropped)} capacity-dropped "
                  f"({dropped / max(total, 1):.1%} of assignments)")


if __name__ == "__main__":
    main()
