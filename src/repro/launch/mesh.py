"""Production mesh builders.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before first jax init.
"""

from __future__ import annotations

import jax

from repro.dist import sharding as shd


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return shd.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return shd.make_mesh((data, model), ("data", "model"))
