import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST precede every other import (jax locks the
# device count at first init).  REPRO_DRYRUN_DEVICES overrides the
# placeholder-device count for small-mesh debugging — still before any
# jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse          # noqa: E402
import json              # noqa: E402
import subprocess        # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from typing import Optional  # noqa: E402

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input-shape) cell, lower + compile the cell's
step function (train_step / prefill / decode_step) against the production
mesh — 16×16 ('data','model') single-pod and 2×16×16 ('pod','data',
'model') multi-pod — from ShapeDtypeStructs only (no allocation), then
record ``memory_analysis()`` (proves it fits), ``cost_analysis()``
(FLOPs/bytes for §Roofline) and per-collective operand bytes parsed from
the post-SPMD HLO.

Usage:
    # one cell (what --all spawns per cell, for crash isolation):
    python -m repro.launch.dryrun --arch smollm-360m --shape train_4k \
        --mesh single --out artifacts/dryrun
    # the full 40-cell × {single,multi} sweep (skips cached results):
    python -m repro.launch.dryrun --all --mesh both --out artifacts/dryrun
"""


def _mesh_for(mode: str, debug_shape: Optional[str]):
    from repro.dist import sharding as shd
    from repro.launch.mesh import make_production_mesh
    if debug_shape:
        dims = tuple(int(x) for x in debug_shape.split(","))
        names = {2: ("data", "model"),
                 3: ("pod", "data", "model")}[len(dims)]
        return shd.make_mesh(dims, names)
    return make_production_mesh(multi_pod=(mode == "multi"))


def _memory_analysis(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
    except Exception as e:                      # CPU backends may lack it
        return {"available": False, "error": repr(e)}
    if m is None:
        return {"available": False}
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    out = {f: int(getattr(m, f)) for f in fields if hasattr(m, f)}
    out["available"] = bool(out)
    if {"argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes"} <= out.keys():
        # peak per-device HBM: args + outputs + temps - donated aliases
        out["peak_bytes_per_device"] = (
            out["argument_size_in_bytes"] + out["output_size_in_bytes"]
            + out["temp_size_in_bytes"] - out["alias_size_in_bytes"])
    return out


def _shard_bytes(struct_tree, sharding_tree) -> int:
    """Per-device bytes of a (struct, sharding) pytree pair — the manual
    fallback when the backend lacks memory_analysis, and an input-side
    cross-check when it doesn't."""
    import jax
    import numpy as np
    total = 0
    structs = jax.tree.leaves(struct_tree)
    shards = jax.tree.leaves(
        sharding_tree, is_leaf=lambda x: hasattr(x, "shard_shape"))
    for s, sh in zip(structs, shards):
        shape = sh.shard_shape(s.shape) if hasattr(sh, "shard_shape") \
            else s.shape
        total += int(np.prod(shape, dtype=np.int64)) * s.dtype.itemsize
    return total


def run_cell(arch: str, shape_name: str, mesh_mode: str,
             debug_shape: Optional[str] = None,
             layout_name: Optional[str] = None,
             explain: bool = False, measure: bool = False,
             autotune: Optional[int] = None) -> dict:
    import jax
    from repro.configs.base import get_config
    from repro.core import hlo_cost, roofline
    from repro.core.hardware import TPU_V5E
    from repro.dist import sharding as shd
    from repro.launch import specs
    from repro.launch.shapes import SHAPES, skip_reason

    if autotune:
        # measured top-K tile search for every GEMM the cell plans;
        # winners persist to the tuning cache (REPRO_TUNE_CACHE)
        from repro import tune
        tune.enable(None if autotune is True else int(autotune))

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_mode,
           "kind": shape.kind, "ok": False}
    skip = skip_reason(cfg, shape)
    if skip:
        rec.update(skipped=True, skip_reason=skip, ok=True)
        return rec

    mesh = _mesh_for(mesh_mode, debug_shape)
    n_devices = mesh.devices.size
    rec.update(mesh_shape=list(mesh.devices.shape),
               mesh_axes=list(mesh.axis_names), n_devices=n_devices)

    from repro import telemetry
    with shd.use_mesh(mesh):
        p = specs.build_problem(arch, shape_name, mesh, layout_name)
        rec.update(layout=p.layout_name, tokens_per_step=p.tokens)
        t0 = time.time()
        with telemetry.span("dryrun.lower", arch=arch, shape=shape_name):
            lowered = specs.lower_problem(p)
        t1 = time.time()
        with telemetry.span("dryrun.compile", arch=arch,
                            shape=shape_name):
            compiled = lowered.compile()
        t2 = time.time()

    rec.update(lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2))

    mem = _memory_analysis(compiled)
    rec["memory_analysis"] = mem
    rec["arg_bytes_per_device"] = _shard_bytes(p.args, p.in_shardings)
    rec["hbm_per_device"] = TPU_V5E.hbm_bytes

    cost = hlo_cost.xla_cost(compiled)
    rec["cost_analysis"] = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
    }

    model_flops = cfg.model_flops(p.tokens, training=p.training)
    hlo_text = compiled.as_text()
    report = roofline.analyze(
        compiled, model_flops_per_device=model_flops / n_devices,
        hlo_text=hlo_text)
    rec["roofline"] = report.as_dict()
    parsed = hlo_cost.analyze_text(hlo_text)
    rec["bytes_by_scope"] = {k: round(v) for k, v
                             in parsed.bytes_by_scope.items()}
    rec["flops_by_scope"] = {k: round(v) for k, v
                             in parsed.flops_by_scope.items()}
    rec["params"] = cfg.param_count()
    rec["params_active"] = cfg.param_count(active_only=True)

    # Every GEMM the cell traced went through the planned GemmSpec API;
    # the plan cache therefore holds the cell's full per-GEMM decision
    # record (kernel, tile, modeled bytes, fallback reasons).
    from repro import ops as rops
    rec["gemm_plan_cache"] = rops.plan_cache_info()._asdict()
    rec["attn_plan_cache"] = rops.attn_plan_cache_info()._asdict()
    if autotune:
        from repro import tune
        rec["tuning_cache"] = tune.tuning_cache_info()._asdict()
        rec["gemm_sources"] = {
            s: sum(1 for p in rops.plans() if p.source == s)
            for s in ("tuned", "analytic")}
        rec["attn_sources"] = {
            s: sum(1 for p in rops.attn_plans() if p.source == s)
            for s in ("tuned", "analytic")}
    if explain:
        rec["gemm_plans"] = [p.explain() for p in rops.plans()]
        rec["attn_plans"] = [p.explain() for p in rops.attn_plans()]
    if measure:
        # the measured half: every GEMM the cell planned is executed
        # standalone (jitted, synced) and joined with its modeled
        # bytes/roofline time — the model-vs-measured table
        from repro.telemetry import report as treport
        rows = treport.model_vs_measured(rops.plans())
        rec["model_vs_measured"] = rows
        rec["model_vs_measured_summary"] = treport.summarize(rows)
    rec["ok"] = True
    return rec


# ---------------------------------------------------------------------------
# Sweep orchestration (subprocess per cell: fresh jax state + isolation)
# ---------------------------------------------------------------------------

def _out_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, mesh, f"{arch}__{shape}.json")


def sweep(out_dir: str, mesh_modes, force: bool = False,
          archs=None, shapes=None, timeout: int = 7200) -> int:
    from repro.launch.shapes import all_cells
    cells = all_cells()
    failures = 0
    for mesh_mode in mesh_modes:
        for arch, shape, skip in cells:
            if archs and arch not in archs:
                continue
            if shapes and shape not in shapes:
                continue
            path = _out_path(out_dir, arch, shape, mesh_mode)
            if os.path.exists(path) and not force:
                continue
            os.makedirs(os.path.dirname(path), exist_ok=True)
            if skip:
                json.dump({"arch": arch, "shape": shape,
                           "mesh": mesh_mode, "ok": True, "skipped": True,
                           "skip_reason": skip}, open(path, "w"), indent=1)
                print(f"[dryrun] SKIP {mesh_mode} {arch} {shape}: {skip}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_mode,
                   "--out", out_dir]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=timeout)
            except subprocess.TimeoutExpired:
                failures += 1
                json.dump({"arch": arch, "shape": shape,
                           "mesh": mesh_mode, "ok": False,
                           "error": f"timeout after {timeout}s"},
                          open(path, "w"), indent=1)
                print(f"[dryrun] TIMEOUT {mesh_mode} {arch} {shape}")
                continue
            dt = time.time() - t0
            if r.returncode != 0:
                failures += 1
                json.dump({"arch": arch, "shape": shape,
                           "mesh": mesh_mode, "ok": False,
                           "error": r.stderr[-4000:]},
                          open(path, "w"), indent=1)
                print(f"[dryrun] FAIL {mesh_mode} {arch} {shape} "
                      f"({dt:.0f}s)\n{r.stderr[-2000:]}")
            else:
                print(f"[dryrun] ok {mesh_mode} {arch} {shape} "
                      f"({dt:.0f}s)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) cell via subprocesses")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--explain", action="store_true",
                    help="print GemmPlan.explain() for every GEMM the "
                         "cell planned (kernel, tile, modeled HBM/VMEM "
                         "bytes, fallback reasons)")
    ap.add_argument("--measure", action="store_true",
                    help="execute every planned GEMM standalone and "
                         "print the model-vs-measured table (modeled "
                         "bytes + roofline time vs measured wall-clock "
                         "per spec+shape)")
    ap.add_argument("--autotune", nargs="?", const=True, default=None,
                    metavar="K",
                    help="measured top-K tile search for every GEMM the "
                         "cell plans (winners persist to the tuning "
                         "cache); optional K narrows the candidate sweep")
    ap.add_argument("--calibrate", action="store_true",
                    help="after the cell, regress the tuning cache's "
                         "measured samples against modeled HBM bytes + "
                         "flops and report effective per-mode bandwidth/"
                         "compute constants with R2")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record plan events + lower/compile/measure "
                         "spans; writes PATH.jsonl + PATH.trace.json")
    ap.add_argument("--layout", default=None,
                    choices=(None, "tp", "fsdp_tp"))
    ap.add_argument("--debug-mesh", default=None,
                    help="e.g. '2,4' — small mesh for local debugging "
                         "(set REPRO_DRYRUN_DEVICES to match)")
    ap.add_argument("--archs", nargs="*", default=None)
    ap.add_argument("--shapes", nargs="*", default=None)
    args = ap.parse_args()

    modes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        failures = sweep(args.out, modes, force=args.force,
                         archs=args.archs, shapes=args.shapes)
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape, "--arch/--shape or --all"
    if args.telemetry:
        from repro import telemetry
        telemetry.enable()
    try:
        rec = run_cell(args.arch, args.shape, modes[0],
                       debug_shape=args.debug_mesh,
                       layout_name=args.layout, explain=args.explain,
                       measure=args.measure, autotune=args.autotune)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": modes[0],
               "ok": False, "error": traceback.format_exc()}
    path = _out_path(args.out, args.arch, args.shape, modes[0])
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if args.explain and rec.get("gemm_plans"):
        print(f"[dryrun] {len(rec['gemm_plans'])} planned GEMMs "
              f"(cache {rec['gemm_plan_cache']}):")
        for text in rec["gemm_plans"]:
            print(text)
    if args.explain and rec.get("attn_plans"):
        print(f"[dryrun] {len(rec['attn_plans'])} planned attentions "
              f"(cache {rec['attn_plan_cache']}):")
        for text in rec["attn_plans"]:
            print(text)
    if args.measure and rec.get("model_vs_measured"):
        from repro.telemetry import report as treport
        print("[dryrun] model-vs-measured (per planned GEMM):")
        print(treport.render(rec["model_vs_measured"]))
    if args.autotune and rec.get("tuning_cache"):
        from repro import tune
        print(f"[dryrun] tuning cache {tune.cache_path()}: "
              f"{rec['tuning_cache']} gemm sources "
              f"{rec.get('gemm_sources')} attn sources "
              f"{rec.get('attn_sources')}")
    if args.calibrate:
        from repro import tune
        fits = tune.calibrate.fit()
        print(tune.calibrate.render(fits))
        rec["calibration"] = {m: c.as_dict() for m, c in fits.items()}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if args.telemetry:
        paths = telemetry.export(args.telemetry)
        print(f"[dryrun] telemetry: wrote {paths[0]} and {paths[1]}")
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("error", "gemm_plans", "attn_plans",
                                   "model_vs_measured")}, indent=1))
    if not rec["ok"]:
        print(rec.get("error", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
