"""Per-cell (arch × shape) dry-run problem construction.

For each of the 40 assigned (architecture × input-shape) cells this
builds the step function that cell lowers (``train_step`` for train
shapes, ``prefill`` / ``decode_step`` for inference shapes), its inputs
as ShapeDtypeStructs (no device allocation — the FULL configs are only
ever touched this way), and the in/out sharding pytrees derived by the
layout engine.  ``repro.launch.dryrun`` lowers + compiles these on the
production meshes; benchmarks read the same problems for roofline terms.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, get_config
from repro.data import pipeline
from repro.dist import layout
from repro.launch.shapes import SHAPES, ShapeSpec, skip_reason
from repro.models import transformer as T
from repro.runtime import elastic
from repro.train import train_step as TS

DRYRUN_LOSS_CHUNKS = 32     # (b, s/32, V) fp32 logits per xent chunk


@dataclasses.dataclass
class CellProblem:
    """Everything dryrun needs to lower one cell."""

    arch: str
    shape: str
    kind: str                       # train | prefill | decode
    fn: Callable
    args: Tuple[Any, ...]           # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    tokens: int                     # tokens processed per step (global)
    training: bool
    layout_name: str
    static: Dict[str, Any] = dataclasses.field(default_factory=dict)


def _named(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _logits_spec(mesh: Mesh, rows: int, vocab: int) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    b_axes = layout._data_axes(mesh, rows)
    v_ax = "model" if ("model" in sizes
                       and vocab % sizes["model"] == 0) else None
    return P(b_axes if b_axes else None, v_ax)


def _replicated_like(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def _state_struct(cfg: ModelConfig) -> TS.TrainState:
    return jax.eval_shape(
        lambda: TS.init_state(jax.random.PRNGKey(0), cfg))


def _cache_struct(cfg: ModelConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_len))


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def _train_problem(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                   layout_name: str) -> CellProblem:
    data_cfg = pipeline.DataConfig(seq_len=shape.seq_len,
                                   global_batch=shape.global_batch)
    batch_struct = pipeline.batch_spec(cfg, data_cfg)
    state_struct = _state_struct(cfg)

    step = TS.make_train_step(cfg, n_loss_chunks=DRYRUN_LOSS_CHUNKS)

    state_sh = elastic.state_shardings(state_struct, cfg, mesh,
                                       layout_name)
    batch_sh = _named(mesh, layout.batch_specs(batch_struct, mesh))
    out_struct = jax.eval_shape(step, state_struct, batch_struct)
    out_sh = (state_sh, _replicated_like(mesh, out_struct[1]))
    return CellProblem(
        arch=cfg.name, shape=shape.name, kind="train", fn=step,
        args=(state_struct, batch_struct),
        in_shardings=(state_sh, batch_sh), out_shardings=out_sh,
        donate_argnums=(0,),
        tokens=shape.global_batch * shape.seq_len, training=True,
        layout_name=layout_name)


def _prefill_problem(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                     layout_name: str) -> CellProblem:
    b, s = shape.global_batch, shape.seq_len
    data_cfg = pipeline.DataConfig(seq_len=s, global_batch=b)
    batch_struct = pipeline.batch_spec(cfg, data_cfg)
    batch_struct.pop("labels")
    cache_struct = _cache_struct(cfg, b, s)
    params_struct = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))

    def fn(params, batch, cache):
        return T.prefill(params, cfg, batch["tokens"], cache,
                         prefix_embeds=batch.get("prefix_embeds"),
                         frames=batch.get("frames"))

    params_sh = _named(mesh, layout.param_specs(params_struct, cfg, mesh,
                                                layout_name))
    batch_sh = _named(mesh, layout.batch_specs(batch_struct, mesh))
    cache_sh = _named(mesh, layout.cache_specs(cache_struct, mesh))
    out_cache_struct = jax.eval_shape(fn, params_struct, batch_struct,
                                      cache_struct)[1]
    out_cache_sh = _named(mesh, layout.cache_specs(out_cache_struct,
                                                   mesh))
    logits_sh = NamedSharding(mesh, _logits_spec(mesh, b, cfg.vocab))
    return CellProblem(
        arch=cfg.name, shape=shape.name, kind="prefill", fn=fn,
        args=(params_struct, batch_struct, cache_struct),
        in_shardings=(params_sh, batch_sh, cache_sh),
        out_shardings=(logits_sh, out_cache_sh),
        donate_argnums=(2,),
        tokens=b * s, training=False, layout_name=layout_name)


def _decode_problem(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                    layout_name: str) -> CellProblem:
    b, s = shape.global_batch, shape.seq_len
    cache_struct = _cache_struct(cfg, b, s)
    params_struct = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    tok_struct = jax.ShapeDtypeStruct((b, 1), jnp.int32)

    def fn(params, tok, cache):
        return T.decode_step(params, cfg, tok, cache)

    params_sh = _named(mesh, layout.param_specs(params_struct, cfg, mesh,
                                                layout_name))
    tok_sh = _named(mesh, layout.batch_specs(tok_struct, mesh))
    cache_sh = _named(mesh, layout.cache_specs(cache_struct, mesh))
    logits_sh = NamedSharding(mesh, _logits_spec(mesh, b, cfg.vocab))
    return CellProblem(
        arch=cfg.name, shape=shape.name, kind="decode", fn=fn,
        args=(params_struct, tok_struct, cache_struct),
        in_shardings=(params_sh, tok_sh, cache_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(2,),
        tokens=b, training=False, layout_name=layout_name)


def build_problem(arch: str, shape_name: str, mesh: Mesh,
                  layout_name: Optional[str] = None) -> CellProblem:
    """The (arch × shape) cell's lowering problem on ``mesh``.

    Raises ``ValueError`` for cells the task sheet skips (long_500k on
    pure full-attention archs) — callers record the reason instead.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = skip_reason(cfg, shape)
    if skip is not None:
        raise ValueError(f"cell skipped: {skip}")
    layout_name = layout_name or layout.choose_layout(
        cfg, dict(zip(mesh.axis_names, mesh.devices.shape)))
    builder = {"train": _train_problem, "prefill": _prefill_problem,
               "decode": _decode_problem}[shape.kind]
    return builder(cfg, shape, mesh, layout_name)


def lower_problem(p: CellProblem):
    """``jax.jit(...).lower(...)`` for a cell (call under ``use_mesh``)."""
    jitted = jax.jit(p.fn, in_shardings=p.in_shardings,
                     out_shardings=p.out_shardings,
                     donate_argnums=p.donate_argnums)
    return jitted.lower(*p.args)
