"""Production serving driver: sharded continuous-batching decode.

Builds the mesh + layout-engine shardings, places (randomly initialized
or checkpointed) params, and serves generation requests through
:class:`repro.serve.engine.DecodeEngine` — either a fixed batch
(``--batch``) or a Poisson-arrival request trace (``--trace N``) that
exercises the continuous scheduler end-to-end and reports throughput
plus mean/p99 request latency.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --steps 16

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --trace 16 --rate 4 --slots 2 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import telemetry
from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config, get_smoke_config
from repro.dist import layout, sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine, Request

#: prompt lengths a trace draws from — bucketed so the slot-prefill jit
#: compiles once per bucket instead of once per request
TRACE_PROMPT_BUCKETS = (4, 8, 16, 32)


def load_params(cfg, mesh, ckpt_dir=None, seed: int = 0,
                int8: bool = False):
    with shd.use_mesh(mesh):
        struct = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(seed), cfg))
        sh = layout.param_shardings(struct, cfg, mesh)
        if ckpt_dir:
            params = Checkpointer(ckpt_dir).restore(struct, shardings=sh)
        else:
            init = jax.jit(lambda k: T.init_params(k, cfg),
                           out_shardings=sh)
            params = init(jax.random.PRNGKey(seed))
        if int8:                    # paper-precision serving mode
            from repro import quant
            before = quant.param_bytes(params)
            params, n = quant.quantize_params(params)
            print(f"[serve] int8-quantized {n} weight banks: "
                  f"{before/2**20:.0f} -> "
                  f"{quant.param_bytes(params)/2**20:.0f} MiB")
        return params


def make_trace(cfg, n_requests: int, rate: float, max_steps: int,
               temperature: float, seed: int = 0) -> list:
    """Poisson-arrival workload: exponential inter-arrival gaps at
    ``rate`` req/s, prompt lengths from TRACE_PROMPT_BUCKETS, max_tokens
    uniform in [2, max_steps]."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    arrivals -= arrivals[0]                  # first request at t=0
    reqs = []
    for t in arrivals:
        plen = int(rng.choice(TRACE_PROMPT_BUCKETS))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab, (plen,)).astype(np.int32),
            max_tokens=int(rng.integers(2, max(max_steps, 2) + 1)),
            temperature=temperature, arrival=float(t)))
    return reqs


def _warmup(engine: DecodeEngine, cfg, prompt_lens,
            temperature: float = 0.0) -> None:
    """Compile the slot-prefill for every prompt-length bucket plus the
    decode step AND the sampling path the trace will use (greedy vs
    temperature) before any timed work, so reported tokens/sec excludes
    jit compilation."""
    rng = np.random.default_rng(1234)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (int(p),))
                    .astype(np.int32), max_tokens=2,
                    temperature=temperature)
            for p in sorted(set(int(p) for p in prompt_lens))]
    engine.run(reqs)
    engine.reset_metrics()
    # warm-up traced every prefill/decode GEMM through the planned
    # GemmSpec API; the cache now holds one resolved plan per unique
    # (spec, shape) — steady-state serving adds no DSE work
    from repro import ops
    info = ops.plan_cache_info()
    print(f"[serve] gemm plan cache after warm-up: {info.entries} "
          f"plans ({info.hits} hits / {info.misses} misses)")
    _print_tune_info()


def _print_tune_info() -> None:
    """Tuning-cache state after warm-up (only when autotuning is on):
    entries, hit/measure counters, and how many live plans took the
    measured winner vs the analytic answer."""
    from repro import ops
    from repro.tune import autotune, cache_path, tuning_cache_info
    if not autotune.is_enabled():
        return
    ti = tuning_cache_info()
    plans = ops.plans()
    tuned = sum(1 for p in plans if p.source == "tuned")
    print(f"[serve] tuning cache {cache_path()}: {ti.entries} "
          f"entries ({ti.hits} hits / {ti.measurements} measured); "
          f"{tuned}/{len(plans)} plans tuned")


def run_trace(engine: DecodeEngine, cfg, args) -> None:
    reqs = make_trace(cfg, args.trace, args.rate, args.steps,
                      args.temperature, seed=args.seed)
    _warmup(engine, cfg, [r.prompt.shape[0] for r in reqs],
            temperature=args.temperature)
    t0 = time.perf_counter()
    results = engine.run(reqs,
                         now_fn=lambda: time.perf_counter() - t0)
    dt = time.perf_counter() - t0
    lat = np.asarray([r.finished_time - r.arrival for r in results])
    ttft = np.asarray([r.ttft for r in results])
    qwait = np.asarray([r.queue_wait for r in results])
    gen = sum(r.n_tokens for r in results)
    m = engine.metrics
    print(f"[serve] trace: {len(results)}/{args.trace} requests, "
          f"{gen} tokens in {dt:.2f}s "
          f"({gen / dt:.1f} tok/s end-to-end, "
          f"{engine.tokens_per_sec():.1f} tok/s decode)")
    print(f"[serve] latency: mean {lat.mean()*1e3:.0f} ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.0f} ms; "
          f"slot occupancy {engine.occupancy():.2f} "
          f"({m['decode_steps']} steps x {engine.n_slots} slots, "
          f"{m['prefill_tokens']} prompt tokens)")
    print(f"[serve] ttft: mean {ttft.mean()*1e3:.0f} ms, "
          f"p99 {np.percentile(ttft, 99)*1e3:.0f} ms; "
          f"queue wait: mean {qwait.mean()*1e3:.0f} ms, "
          f"p99 {np.percentile(qwait, 99)*1e3:.0f} ms")
    if engine.paged:
        print(f"[serve] paged KV: {m['prefill_chunks']} prefill "
              f"chunks, max decode stall "
              f"{m['max_prefill_stall_tokens']} prompt tokens; "
              f"prefix cache {m['prefix_hits']} hits / "
              f"{m['prefix_misses']} misses "
              f"({m['shared_prompt_tokens']} prompt tokens shared)")
        dense = m["modeled_kv_bytes_dense_rows"]
        if dense:
            print(f"[serve] modeled decode KV stream "
                  f"{m['modeled_kv_bytes'] / 2**20:.2f} MiB at true "
                  f"positions vs {dense / 2**20:.2f} MiB at dense "
                  f"max_len rows "
                  f"({m['modeled_kv_bytes'] / dense:.2f}x)")


def run_batch(engine: DecodeEngine, cfg, args) -> None:
    rng = np.random.default_rng(0)
    prompts = jax.numpy.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jax.numpy.int32)
    frames = None
    if cfg.family == "audio":
        frames = jax.numpy.asarray(
            rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                dtype=np.float32), cfg.dtype)

    # timing fix: one throwaway generation compiles prefill + step +
    # sampling, so the timed run (and its tokens/sec) excludes the jit
    # compile; engine bursts block_until_ready before reading the clock.
    # max_tokens=2 so at least one decode burst actually runs (a
    # 1-token request completes at admission without touching _step)
    engine.generate(prompts, min(2, args.steps + 1), frames=frames)
    engine.reset_metrics()
    _print_tune_info()
    t0 = time.perf_counter()
    result = engine.generate(prompts, args.steps, frames=frames)
    dt = time.perf_counter() - t0
    tok_s = args.batch * result.steps / dt
    print(f"[serve] generated {result.steps} steps x {args.batch} seqs "
          f"in {dt:.2f}s ({tok_s:.1f} tok/s, "
          f"{engine.tokens_per_sec():.1f} tok/s decode-only)")
    print("[serve] first sequence:", result.tokens[0][:16], "...")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--trace", type=int, default=0,
                    help="serve N Poisson-arrival requests through the "
                         "continuous-batching scheduler")
    ap.add_argument("--rate", type=float, default=4.0,
                    help="trace arrival rate (requests/sec)")
    ap.add_argument("--slots", type=int, default=None,
                    help="cache slots for --trace (default --batch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="record spans/counters for the whole run and "
                         "write PATH.jsonl + PATH.trace.json (the "
                         "latter loads in chrome://tracing or "
                         "ui.perfetto.dev)")
    ap.add_argument("--autotune", nargs="?", const=True, default=None,
                    metavar="K",
                    help="measured top-K tile search for every GEMM the "
                         "warm-up plans; winners persist to the tuning "
                         "cache so a later serve re-plans with zero "
                         "re-measurement")
    ap.add_argument("--page-size", type=int, default=None,
                    help="block-paged KV cache with this page size "
                         "(tokens); max_len rounds up to a page "
                         "multiple")
    ap.add_argument("--pages", type=int, default=None,
                    help="KV pool size in pages incl. the sink page "
                         "(default: dense-equivalent capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="split paged admissions into chunks of this "
                         "many prompt tokens, interleaved with decode "
                         "bursts")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-hash prefix sharing of "
                         "paged prompt pages")
    ap.add_argument("--int8", action="store_true",
                    help="fused int8 weights, bf16 activations (W8A16)")
    ap.add_argument("--w8a8", action="store_true",
                    help="int8 weights + dynamic int8 activations "
                         "(the paper's int8 x int8 / int32-accumulate "
                         "scheme); implies --int8")
    args = ap.parse_args()
    if args.telemetry:
        telemetry.enable()
    if args.autotune:
        from repro import tune
        tune.enable(None if args.autotune is True
                    else int(args.autotune))
    if args.w8a8:
        args.int8 = True
        from repro import quant
        quant.set_activation_mode("w8a8")

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    mesh = make_host_mesh(data=len(jax.devices()))
    params = load_params(cfg, mesh, args.ckpt_dir, int8=args.int8)
    n_slots = args.slots or args.batch
    if args.max_len:
        max_len = args.max_len
    elif args.trace:
        # trace prompts come from the buckets; +1 slack for warm-up
        max_len = max(TRACE_PROMPT_BUCKETS) + max(args.steps, 2)
    else:
        max_len = args.prompt_len + args.steps

    with shd.use_mesh(mesh):
        engine = DecodeEngine(params, cfg, batch=n_slots,
                              max_len=max_len,
                              temperature=args.temperature,
                              page_size=args.page_size,
                              n_pages=args.pages,
                              prefill_chunk=args.prefill_chunk,
                              prefix_cache=not args.no_prefix_cache)
        if engine.paged:
            print(f"[serve] paged KV: {engine.kv.pool.n_pages - 1} "
                  f"pages x {engine.page_size} tokens (+1 sink), "
                  f"{engine.kv.max_pages} pages/slot"
                  + (f", prefill chunk {engine.prefill_chunk}"
                     if engine.prefill_chunk else ""))
        bpt = engine.modeled_bytes_per_token()
        mode = "w8a8" if args.w8a8 else \
            ("w8a16" if args.int8 else "bf16")
        print(f"[serve] {mode}: modeled GEMM weight stream "
              f"{bpt / 2**20:.1f} MiB/step "
              f"({bpt / n_slots / 2**20:.2f} MiB per seq-token "
              f"at {n_slots} slots)")
        if args.trace:
            run_trace(engine, cfg, args)
        else:
            run_batch(engine, cfg, args)
    if args.telemetry:
        snap = telemetry.snapshot()
        paths = telemetry.export(args.telemetry)
        print(f"[serve] telemetry: {snap['n_events']} events, "
              f"plan cache {snap['plan_cache']}; wrote "
              f"{paths[0]} and {paths[1]}")
        routed = snap["counters"].get("moe.group_sizes")
        if routed is not None:
            dropped = snap["counters"].get("moe.dropped_tokens", 0)
            total = routed + dropped
            print(f"[serve] moe: {int(routed)} rows through grouped "
                  f"expert GEMMs, {int(dropped)} capacity-dropped "
                  f"({dropped / max(total, 1):.1%} of assignments)")


if __name__ == "__main__":
    main()
