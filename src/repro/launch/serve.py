"""Production serving driver: sharded batched decode.

Builds the mesh + layout-engine shardings, places (randomly initialized
or checkpointed) params, and serves batched generation requests through
:class:`repro.serve.engine.DecodeEngine`.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --smoke --batch 4 --prompt-len 32 --steps 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_config, get_smoke_config
from repro.dist import layout, sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


def load_params(cfg, mesh, ckpt_dir=None, seed: int = 0,
                int8: bool = False):
    with shd.use_mesh(mesh):
        struct = jax.eval_shape(
            lambda: T.init_params(jax.random.PRNGKey(seed), cfg))
        sh = layout.param_shardings(struct, cfg, mesh)
        if ckpt_dir:
            params = Checkpointer(ckpt_dir).restore(struct, shardings=sh)
        else:
            init = jax.jit(lambda k: T.init_params(k, cfg),
                           out_shardings=sh)
            params = init(jax.random.PRNGKey(seed))
        if int8:                    # paper-precision serving mode
            from repro import quant
            before = quant.param_bytes(params)
            params, n = quant.quantize_params(params)
            print(f"[serve] int8-quantized {n} weight banks: "
                  f"{before/2**20:.0f} -> "
                  f"{quant.param_bytes(params)/2**20:.0f} MiB")
        return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--int8", action="store_true",
                    help="fused int8 weights, bf16 activations (W8A16)")
    ap.add_argument("--w8a8", action="store_true",
                    help="int8 weights + dynamic int8 activations "
                         "(the paper's int8 x int8 / int32-accumulate "
                         "scheme); implies --int8")
    args = ap.parse_args()
    if args.w8a8:
        args.int8 = True
        from repro import quant
        quant.set_activation_mode("w8a8")

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    mesh = make_host_mesh(data=len(jax.devices()))
    params = load_params(cfg, mesh, args.ckpt_dir, int8=args.int8)
    max_len = args.max_len or (args.prompt_len + args.steps)

    rng = np.random.default_rng(0)
    prompts = jax.numpy.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
        jax.numpy.int32)
    frames = None
    if cfg.family == "audio":
        frames = jax.numpy.asarray(
            rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model),
                dtype=np.float32), cfg.dtype)

    with shd.use_mesh(mesh):
        engine = DecodeEngine(params, cfg, batch=args.batch,
                              max_len=max_len,
                              temperature=args.temperature)
        bpt = engine.modeled_bytes_per_token()
        mode = "w8a8" if args.w8a8 else \
            ("w8a16" if args.int8 else "bf16")
        print(f"[serve] {mode}: modeled GEMM weight stream "
              f"{bpt / 2**20:.1f} MiB/step "
              f"({bpt / args.batch / 2**20:.2f} MiB per seq-token "
              f"at batch {args.batch})")
        t0 = time.time()
        result = engine.generate(prompts, args.steps, frames=frames)
        dt = time.time() - t0
    tok_s = args.batch * result.steps / dt
    print(f"[serve] generated {result.steps} steps x {args.batch} seqs "
          f"in {dt:.2f}s ({tok_s:.1f} tok/s)")
    print("[serve] first sequence:", result.tokens[0][:16], "...")


if __name__ == "__main__":
    main()
