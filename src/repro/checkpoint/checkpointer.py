"""Sharded-manifest checkpointing with atomic commits and async saves.

Layout on disk:

    <dir>/step_<N>/manifest.json     tree structure, shapes, dtypes
    <dir>/step_<N>/arrays.npz        leaf arrays keyed by tree path
    <dir>/step_<N>/COMMITTED         written last -> crash-safe marker

Restore targets any mesh: arrays are stored logically (unsharded) and
``device_put`` with the target sharding re-shards on load, which is what
the elastic re-mesh test exercises (train on mesh A, restore onto mesh
B).  At real multi-host scale each host would write only its addressable
shards with an index into the manifest; the manifest/commit protocol here
is the same.

Async: ``save(..., blocking=False)`` snapshots to host memory
synchronously (so training can donate/overwrite buffers) and writes the
files on a background thread; ``wait()`` joins.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]

    def pstr(path):
        out = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                out.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                out.append(str(p.idx))
            else:
                out.append(str(p))
        return "/".join(out)

    return [(pstr(path), leaf) for path, leaf in flat]


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree, blocking: bool = True) -> None:
        # Snapshot to host memory NOW (donation-safe), write maybe later.
        flat = [(k, np.asarray(v)) for k, v in _flatten(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        self.wait()                 # never two writers at once
        if blocking:
            self._write(step, flat, treedef)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, treedef), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat, treedef) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = f"{final}.tmp{os.getpid()}_{threading.get_ident()}"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        # ml_dtypes arrays (bf16/fp8, numpy kind 'V') don't survive
        # npz round-trips — store their raw bytes; restore views them
        # back through the manifest dtype
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: (np.atleast_1d(v).view(np.uint8)
                        if v.dtype.kind == "V" else v)
                    for k, v in flat})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": [{"key": k, "shape": list(v.shape),
                        "dtype": str(v.dtype)} for k, v in flat],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(tmp, "COMMITTED"), "w") as f:
            f.write("ok")
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        import re
        for name in sorted(os.listdir(self.directory)):
            full = os.path.join(self.directory, name)
            if re.fullmatch(r"step_\d{8}", name) \
                    and os.path.exists(os.path.join(full, "COMMITTED")):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        shardings for elastic re-mesh placement."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        data = np.load(os.path.join(d, "arrays.npz"))
        flat_t = _flatten(target)
        treedef = jax.tree_util.tree_structure(target)
        flat_s = _flatten(shardings)if shardings is not None else None
        leaves = []
        for i, (key, tgt) in enumerate(flat_t):
            arr = data[key]
            want = np.dtype(tgt.dtype)
            if arr.dtype != want and want.kind == "V":
                arr = arr.view(want).reshape(tgt.shape)  # bytes -> ml_dtypes
            assert tuple(arr.shape) == tuple(tgt.shape), \
                (key, arr.shape, tgt.shape)
            if arr.dtype != want:
                arr = arr.astype(want)
            if flat_s is not None:
                arr = jax.device_put(arr, flat_s[i][1])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(treedef, leaves)
