"""Deterministic synthetic LM data pipeline, shard-aware.

Real multi-pod training streams tokenized shards; here the substrate is a
deterministic generator (seeded per (step, host-shard)) with the same
interface, so restarts are bit-reproducible (the checkpoint/restart test
relies on this) and every host generates only its slice of the global
batch.

Batches carry next-token-prediction pairs plus the per-family stub
modality inputs (audio frames / vision patch embeddings).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    # host sharding: this host materializes rows [row_start, row_start+rows)
    row_start: int = 0
    rows: Optional[int] = None      # None = full global batch


def _tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    """Markov-ish synthetic stream: mixture of a random walk and uniform
    resets, so the LM loss is learnable (used by convergence tests)."""
    walk = rng.integers(0, vocab, size=shape, dtype=np.int64)
    out = np.cumsum(walk, axis=-1) % vocab
    resets = rng.random(shape) < 0.1
    out = np.where(resets, walk, out)
    return out.astype(np.int32)


def make_batch(cfg: ModelConfig, data: DataConfig, step: int
               ) -> Dict[str, jax.Array]:
    """Deterministic batch for ``step`` (this host's rows only)."""
    rows = data.rows if data.rows is not None else data.global_batch
    rng = np.random.default_rng(
        np.random.SeedSequence([data.seed, step, data.row_start]))
    s = data.seq_len
    text_len = s - (cfg.prefix_tokens or 0)
    stream = _tokens(rng, (rows, text_len + 1), cfg.vocab)
    batch: Dict[str, jax.Array] = {
        "tokens": jnp.asarray(stream[:, :-1]),
        "labels": jnp.asarray(stream[:, 1:]),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((rows, cfg.encoder_seq, cfg.d_model),
                                dtype=np.float32), dtype=cfg.dtype)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jnp.asarray(
            rng.standard_normal((rows, cfg.prefix_tokens, cfg.d_model),
                                dtype=np.float32), dtype=cfg.dtype)
    return batch


def iterate(cfg: ModelConfig, data: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, jax.Array]]:
    step = start_step
    while True:
        yield make_batch(cfg, data, step)
        step += 1


def batch_spec(cfg: ModelConfig, data: DataConfig) -> Dict:
    """ShapeDtypeStructs matching :func:`make_batch` (dry-run inputs)."""
    rows = data.rows if data.rows is not None else data.global_batch
    s = data.seq_len
    text_len = s - (cfg.prefix_tokens or 0)
    spec = {
        "tokens": jax.ShapeDtypeStruct((rows, text_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((rows, text_len), jnp.int32),
    }
    if cfg.family == "audio":
        spec["frames"] = jax.ShapeDtypeStruct(
            (rows, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        spec["prefix_embeds"] = jax.ShapeDtypeStruct(
            (rows, cfg.prefix_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    return spec
