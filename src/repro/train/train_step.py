"""Donated, microbatched train step.

Structure (per 1000+-node posture):

* grads accumulated over microbatches with ``lax.scan`` (sequential, so
  peak activation memory is one microbatch);
* loss/grads in fp32 accumulators, params in model dtype;
* optimizer selected per model size (AdamW; Adafactor >= ~100B params);
* global grad-norm clipping;
* optional int8 error-feedback compression hook for the cross-pod
  reduction (wired in the shard_map variant; under pjit/GSPMD the 'pod'
  reduction is fused into the same all-reduce, so compression is exposed
  as an opt-in shard_map path — see repro.optim.compression).

The returned function is pure; callers jit it with donated params/opt
state and sharded inputs (see repro.launch.train / dryrun).
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim import adafactor, adamw, schedule as sched

ADAFACTOR_THRESHOLD = 100e9


class TrainState(NamedTuple):
    params: dict
    opt: tuple
    step: jax.Array


def select_optimizer(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.param_count() >= ADAFACTOR_THRESHOLD \
        else "adamw"


def init_state(key, cfg: ModelConfig, optimizer: Optional[str] = None
               ) -> TrainState:
    params = T.init_params(key, cfg)
    optimizer = optimizer or select_optimizer(cfg)
    opt = adamw.init(params) if optimizer == "adamw" \
        else adafactor.init(params)
    return TrainState(params=params, opt=opt,
                      step=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), gn


def make_train_step(cfg: ModelConfig, *, optimizer: Optional[str] = None,
                    peak_lr: float = 3e-4, warmup_steps: int = 100,
                    total_steps: int = 10_000, weight_decay: float = 0.1,
                    grad_clip: float = 1.0, microbatches: int = 1,
                    remat: bool = True, n_loss_chunks: int = 8
                    ) -> Callable:
    """Build the (params-donatable) train step for an architecture."""
    optimizer = optimizer or select_optimizer(cfg)
    opt_update = adamw.update if optimizer == "adamw" \
        else adafactor.update

    def loss_of(params, batch):
        loss, metrics = T.loss_fn(params, cfg, batch,
                                  n_chunks=n_loss_chunks, remat=remat)
        return loss, metrics

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads

        def split(x):
            b = x.shape[0]
            return x.reshape((microbatches, b // microbatches)
                             + x.shape[1:])

        micro = jax.tree.map(split, batch)
        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def body(acc, mb):
            g_acc, l_acc = acc
            (loss, _), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
            return (g_acc, l_acc + loss), None

        (g_sum, l_sum), _ = jax.lax.scan(body, (zero, 0.0), micro)
        grads = jax.tree.map(lambda g: g / microbatches, g_sum)
        return l_sum / microbatches, {}, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        loss, metrics, grads = grads_of(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        lr = sched.warmup_cosine(state.step, peak_lr=peak_lr,
                                 warmup_steps=warmup_steps,
                                 total_steps=total_steps)
        if optimizer == "adamw":
            params, opt = opt_update(grads, state.opt, state.params,
                                     lr=lr, weight_decay=weight_decay)
        else:
            params, opt = opt_update(grads, state.opt, state.params,
                                     lr=lr, weight_decay=weight_decay)
        new_state = TrainState(params=params, opt=opt,
                               step=state.step + 1)
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out_metrics.update({k: v for k, v in (metrics or {}).items()})
        return new_state, out_metrics

    return train_step
