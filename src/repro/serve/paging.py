"""Block-paged KV memory for the serve loop.

Pure-host bookkeeping — nothing in here touches jax.  Three layers:

``PagePool``
    refcounted allocator over a fixed pool of KV pages.  Page 0 is
    reserved as the *sink*: free slots and slots still mid-prefill keep
    their device page-table rows pointed at it, so the junk K/V writes a
    decode burst makes through those rows land somewhere harmless.

``PrefixCache``
    content-hash prefix cache.  Each cached entry maps the hash of a
    prompt's *leading i pages worth of tokens* to the physical page that
    holds positions ``[i*ps, (i+1)*ps)``.  Keys are cumulative (the key
    for page i hashes tokens ``[0, min((i+1)*ps, plen))``), so a match is
    a chain walk from page 0 and two different histories can never alias
    a page.  Partial tail pages are cached too — an identical re-prompt
    shares them copy-on-write.

``PagedKV``
    per-slot page tables on top of the pool + cache: admission planning
    (how many fresh pages, which shared pages, which copy-on-write),
    release, and the masked int32 table rows the device cache consumes.

The same property-test discipline as ``SlotScheduler`` applies: every
invariant here (no double-allocation, freed pages return, referenced
shared pages never reclaimed) is asserted in ``tests/test_paging.py``.
"""

from __future__ import annotations

import bisect
import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: physical page 0 is never allocated; masked page-table rows point here
SINK_PAGE = 0


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Refcounted fixed-size page allocator (host-side, deterministic).

    Pages are handed out lowest-index-first so repeated runs produce
    identical tables.  ``alloc`` gives refcount 1; ``ref`` pins a page a
    second consumer (a prefix-cache entry, a sharing slot) also holds;
    ``free`` drops one reference and returns the page to the free list
    only when nobody holds it.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is the "
                             f"reserved sink), got {n_pages}")
        self.n_pages = int(n_pages)
        self._ref = [0] * self.n_pages
        self._free: List[int] = list(range(1, self.n_pages))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - 1 - len(self._free)

    def refcount(self, page: int) -> int:
        return self._ref[page]

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("PagePool exhausted")
        page = self._free.pop(0)
        self._ref[page] = 1
        return page

    def ref(self, page: int) -> None:
        if page == SINK_PAGE or self._ref[page] <= 0:
            raise ValueError(f"ref of unallocated page {page}")
        self._ref[page] += 1

    def free(self, page: int) -> None:
        if page == SINK_PAGE or self._ref[page] <= 0:
            raise ValueError(f"free of unallocated page {page}")
        self._ref[page] -= 1
        if self._ref[page] == 0:
            bisect.insort(self._free, page)


class PrefixCache:
    """Content-hash map from prompt prefixes to shared physical pages.

    ``match`` walks the chain page by page and returns the longest run
    of cached pages whose cumulative token hash agrees with the new
    prompt.  ``register`` inserts a finished prompt's pages (bumping
    their refcount so slot release can't reclaim them).  ``evict``
    drops least-recently-used entries whose page nobody else references
    — deepest pages first, so a chain never loses a shallow link while a
    deeper link stays cached (an entry whose chain head is gone can
    never match again, yet would keep its page refcounted forever).

    Every key touched by one match/register walk gets the SAME lru
    stamp: a walk always starts at the chain head, so within a chain a
    deeper entry is never newer than a shallower one, and the
    deepest-first (``-tokens``) tie-break decides eviction order inside
    a walk.
    """

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self._page: Dict[bytes, int] = {}       # key -> physical page
        self._tokens: Dict[bytes, int] = {}     # key -> tokens covered
        self._used: Dict[bytes, int] = {}       # key -> lru clock
        self._clock = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._page)

    def _key(self, tokens: np.ndarray, n: int) -> bytes:
        return hashlib.blake2b(
            np.ascontiguousarray(tokens[:n], dtype=np.int32).tobytes(),
            digest_size=16).digest()

    def match(self, tokens: Sequence[int],
              peek: bool = False) -> Tuple[List[int], int]:
        """Longest cached prefix of ``tokens``: (pages, tokens covered).

        ``peek`` skips the hit/miss counters and LRU touch (used by
        admission-feasibility checks that may run before the real
        admit)."""
        toks = np.asarray(tokens, dtype=np.int32)
        plen = len(toks)
        ps = self.page_size
        pages: List[int] = []
        covered = 0
        if not peek:
            self._clock += 1            # one stamp for the whole walk
        for i in range(_ceil_div(plen, ps)):
            n = min((i + 1) * ps, plen)
            key = self._key(toks, n)
            if key not in self._page:
                break
            pages.append(self._page[key])
            covered = n
            if not peek:
                self._used[key] = self._clock
        if not peek:
            if covered > 0:
                self.hits += 1
            else:
                self.misses += 1
        return pages, covered

    def register(self, tokens: Sequence[int], pages: Sequence[int],
                 pool: PagePool) -> int:
        """Cache ``pages`` as the prefix chain for ``tokens``; returns
        how many new entries were inserted (already-cached prefixes are
        left alone, so a re-registered prompt is a no-op — but the whole
        chain is LRU-stamped, so extending a chain never leaves its head
        older than the new deeper links)."""
        toks = np.asarray(tokens, dtype=np.int32)
        plen = len(toks)
        ps = self.page_size
        added = 0
        self._clock += 1                # one stamp for the whole walk
        for i, page in enumerate(pages):
            n = min((i + 1) * ps, plen)
            key = self._key(toks, n)
            if key not in self._page:
                pool.ref(page)
                self._page[key] = page
                self._tokens[key] = n
                added += 1
            self._used[key] = self._clock
        return added

    def evict(self, pool: PagePool, n_pages: int) -> int:
        """Drop up to ``n_pages`` cache-only entries (page refcount 1 —
        no slot maps them), oldest first and deepest-chain first within
        an age; returns how many pages were actually freed."""
        victims = sorted(
            (key for key, page in self._page.items()
             if pool.refcount(page) == 1),
            key=lambda k: (self._used[k], -self._tokens[k]))
        freed = 0
        for key in victims:
            if freed >= n_pages:
                break
            pool.free(self._page.pop(key))
            self._tokens.pop(key)
            self._used.pop(key)
            freed += 1
        return freed

    def drop_all(self, pool: PagePool) -> int:
        """Release every entry (shutdown / reset path)."""
        n = 0
        for key, page in list(self._page.items()):
            pool.free(page)
            del self._page[key], self._tokens[key], self._used[key]
            n += 1
        return n


@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """What an admission decided: how much of the prompt rides on shared
    pages, and which page must be copy-on-write duplicated because the
    slot will write into it (the recomputed last prompt token or the
    first divergent append lands mid-page)."""
    shared_tokens: int            # prompt positions served from cache
    cow_src: Tuple[int, ...]      # pages to copy from ...
    cow_dst: Tuple[int, ...]      # ... into these freshly-owned pages
    n_pages: int                  # total pages mapped for the slot
    prefix_hit: bool


class PagedKV:
    """Slot-granular view over one PagePool: page tables + admission.

    The engine owns one of these per cache.  All methods are host-only;
    the device sees the tables through :meth:`table_row` /
    :meth:`masked_tables`.
    """

    def __init__(self, n_slots: int, n_pages: int, page_size: int,
                 max_pages: int, prefix_cache: bool = True):
        self.n_slots = int(n_slots)
        self.page_size = int(page_size)
        self.max_pages = int(max_pages)
        self.pool = PagePool(n_pages)
        self.prefix: Optional[PrefixCache] = \
            PrefixCache(page_size) if prefix_cache else None
        self.tables: List[List[int]] = [[] for _ in range(self.n_slots)]

    # -- capacity -----------------------------------------------------
    def total_pages(self, need_tokens: int) -> int:
        return _ceil_div(need_tokens, self.page_size)

    def pages_needed(self, tokens: Sequence[int],
                     need_tokens: int) -> int:
        """Fresh pages an admission would pull from the pool (shared
        full pages ride on the prefix cache; a copy-on-write dst counts
        as fresh)."""
        total = self.total_pages(need_tokens)
        if self.prefix is None or len(tokens) <= 1:
            return total
        _, matched = self.prefix.match(tokens, peek=True)
        shared = min(matched, len(tokens) - 1)
        return total - shared // self.page_size

    def can_admit(self, tokens: Sequence[int],
                  need_tokens: int) -> bool:
        return self.pages_needed(tokens, need_tokens) <= self.pool.n_free

    def try_reclaim(self, tokens: Sequence[int],
                    need_tokens: int) -> bool:
        """Evict cache-only prefix pages until the admission fits;
        returns whether it now fits."""
        if self.prefix is not None:
            short = self.pages_needed(tokens, need_tokens) \
                - self.pool.n_free
            if short > 0:
                self.prefix.evict(self.pool, short)
        return self.can_admit(tokens, need_tokens)

    # -- admission / release ------------------------------------------
    def admit(self, slot: int, tokens: Sequence[int],
              need_tokens: int) -> AdmitPlan:
        """Map pages for a request needing ``need_tokens`` cache rows.

        Shared full prefix pages are referenced in place; if the first
        position this slot will write falls inside a cached page, that
        page is duplicated (COW) so the shared copy stays read-only.
        The caller must have checked :meth:`can_admit`."""
        if self.tables[slot]:
            raise ValueError(f"slot {slot} already mapped")
        ps = self.page_size
        total = self.total_pages(need_tokens)
        if total > self.max_pages:
            raise ValueError(f"request needs {total} pages > max_pages "
                             f"{self.max_pages}")
        shared = 0
        mapped: List[int] = []
        cow_src: List[int] = []
        cow_dst: List[int] = []
        hit = False
        if self.prefix is not None and len(tokens) > 1:
            pages, matched = self.prefix.match(tokens)
            # always recompute >=1 prompt token so admission still
            # produces the first-token logits
            shared = min(matched, len(tokens) - 1)
            hit = shared > 0
            n_full = shared // ps
            for page in pages[:n_full]:
                self.pool.ref(page)
                mapped.append(page)
            if shared % ps:
                # position `shared` lands mid-page: duplicate the cached
                # page so this slot's writes don't touch the shared copy
                src = pages[n_full]
                dst = self.pool.alloc()
                cow_src.append(src)
                cow_dst.append(dst)
                mapped.append(dst)
        while len(mapped) < total:
            mapped.append(self.pool.alloc())
        self.tables[slot] = mapped
        return AdmitPlan(shared_tokens=shared, cow_src=tuple(cow_src),
                         cow_dst=tuple(cow_dst), n_pages=total,
                         prefix_hit=hit)

    def register_prefix(self, slot: int, tokens: Sequence[int]) -> int:
        """After a slot's prompt is fully written, publish its pages
        (including a partial tail page) to the prefix cache."""
        if self.prefix is None:
            return 0
        n = _ceil_div(len(tokens), self.page_size)
        return self.prefix.register(tokens, self.tables[slot][:n],
                                    self.pool)

    def release(self, slot: int) -> None:
        for page in self.tables[slot]:
            self.pool.free(page)
        self.tables[slot] = []

    # -- device view --------------------------------------------------
    def table_row(self, slot: int) -> np.ndarray:
        """This slot's true table, sink-padded to ``max_pages``."""
        row = np.full((self.max_pages,), SINK_PAGE, dtype=np.int32)
        pages = self.tables[slot]
        row[:len(pages)] = pages
        return row

    def masked_tables(self, live_slots: Sequence[int]) -> np.ndarray:
        """(n_slots, max_pages) device tables: rows for slots not in
        ``live_slots`` are all-sink, so decode writes through them land
        in the sink page instead of someone's real KV."""
        out = np.full((self.n_slots, self.max_pages), SINK_PAGE,
                      dtype=np.int32)
        for slot in live_slots:
            out[slot] = self.table_row(slot)
        return out
