"""Batched decode engine: continuous batched requests over a shared KV
cache, greedy or temperature sampling.

The serving counterpart of the trainer: jitted prefill + decode_step with
cache donation; per-sequence completion masking so a batch of requests
with different prompt/target lengths decodes together (the 'batched
requests' end-to-end driver the task sheet asks for).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray            # (b, steps) generated ids
    steps: int


# EOS completion is checked on the host only every this-many steps:
# a per-token ``bool(jnp.all(done))`` would force a device->host sync
# every decode step and serialize the jitted step stream.  Generated
# tokens and ``done`` both stay on device between checks; the trade is
# up to EOS_CHECK_EVERY-1 extra (masked-out) steps after the last
# sequence finishes.
EOS_CHECK_EVERY = 8


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch: int,
                 max_len: int, temperature: float = 0.0,
                 eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, toks, cache, frames: T.prefill(
                p, cfg, toks, cache, frames=frames))
        self._step = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache),
            donate_argnums=(2,))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature)[:, None].astype(jnp.int32)

    def generate(self, prompts: jax.Array, n_steps: int,
                 frames: Optional[jax.Array] = None,
                 seed: int = 0) -> GenerationResult:
        """prompts: (b, s) int32.  Returns n_steps generated tokens."""
        b = prompts.shape[0]
        assert b == self.batch
        cache = T.init_cache(self.cfg, b, self.max_len)
        logits, cache = self._prefill(self.params, prompts, cache, frames)
        key = jax.random.PRNGKey(seed)
        out = []                  # device-resident (b,) token slices
        done = jnp.zeros((b,), bool)
        tok = self._sample(logits, key)
        for i in range(n_steps):
            out.append(tok[:, 0])
            if self.eos_id is not None:
                done = done | (tok[:, 0] == self.eos_id)
                if (i + 1) % EOS_CHECK_EVERY == 0 \
                        and bool(jnp.all(done)):
                    break
            logits, cache = self._step(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return GenerationResult(
            tokens=np.asarray(jnp.stack(out, axis=1)), steps=len(out))

    def modeled_bytes_per_token(self) -> int:
        """Modeled HBM weight traffic of ONE batched decode step (the
        whole batch shares it): every GEMM projection leaf streams
        through VMEM once per step, at its storage width — one
        byte/element + scale vector for fused-int8 weights, two for
        bf16.  This is the term the mixed-precision path halves."""
        from repro import quant
        return quant.gemm_weight_bytes(self.params)
