"""Continuous-batching decode engine: per-slot KV positions from the
scheduler down to the flash-decode kernel.

The old engine was a lockstep static batch — one scalar ``cache["pos"]``
shared by every sequence, so a single long request held its whole batch
hostage and short prompts padded to the longest.  This engine is a
scheduler over a fixed pool of cache *slots*:

* a request queue feeds a :class:`SlotScheduler` (pure-host allocator,
  property-tested in isolation);
* admission prefills ONE request into a free slot of the live cache
  (:func:`repro.models.transformer.prefill_into_slot` — resident slots
  are untouched, ``jax.lax.dynamic_update_*`` on every cache leaf);
* every batched ``decode_step`` advances all slots at their own
  positions (the ``(b,)`` ``cache["pos"]`` contract, masked per-row all
  the way down to the flash-decode kernel);
* per-slot sampling params (temperature / eos / max_tokens), per-slot
  completion + eviction, and rolling tokens/sec + slot-occupancy
  metrics.

Host syncs are amortized: decode runs in bursts of up to
``EOS_CHECK_EVERY`` steps (bounded by the tightest remaining
``max_tokens``, so length-based completions are exact); EOS is detected
at burst boundaries and any tokens sampled after it are masked before a
result is returned.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.configs.base import ModelConfig
from repro.core import bandwidth
from repro.models import transformer as T
from repro.serve import paging


# EOS completion is checked on the host only every this-many steps: a
# per-token ``bool(done)`` would force a device->host sync every decode
# step and serialize the jitted step stream.  Bursts are additionally
# capped by the smallest remaining max_tokens among active slots, so
# length-based completions (and the admissions they unblock) land on
# the exact step; the trade is up to EOS_CHECK_EVERY-1 wasted (masked)
# steps after an EOS.
EOS_CHECK_EVERY = 8


#: the ragged acceptance trace — (prompt_len, max_tokens) pairs — that
#: tests/test_serve.py and benchmarks/serve_bench.py both pin: every
#: request must decode bit-identically to a solo batch-1 greedy run
ACCEPTANCE_TRACE = ((4, 8), (16, 32), (8, 16), (32, 4))


def acceptance_requests(vocab: int, seed: int = 0) -> List["Request"]:
    """Materialize the acceptance trace as requests (shared by
    tests/test_serve.py and benchmarks/serve_bench.py so both always
    exercise the same trace)."""
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, vocab, (p,))
                    .astype(np.int32), max_tokens=mt)
            for p, mt in ACCEPTANCE_TRACE]


def solo_greedy(params, cfg: ModelConfig, prompt: np.ndarray,
                max_tokens: int, max_len: int) -> np.ndarray:
    """The parity oracle: one request alone at batch 1, greedy —
    prefill then token-by-token decode.  The continuous engine must
    reproduce this bit-for-bit for greedy requests."""
    cache = T.init_cache(cfg, 1, max_len)
    logits, cache = T.prefill(params, cfg,
                              jnp.asarray(prompt[None], jnp.int32),
                              cache)
    step = jax.jit(lambda t, c: T.decode_step(params, cfg, t, c))
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(max_tokens):
        toks.append(int(tok[0, 0]))
        logits, cache = step(tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    return np.asarray(toks, np.int32)


@dataclasses.dataclass
class Request:
    """One generation request (host-side)."""
    prompt: np.ndarray                   # (s,) int32 prompt token ids
    max_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    arrival: float = 0.0                 # seconds since trace start
    frames: Optional[np.ndarray] = None  # (F, d) audio stub frames
    rid: int = -1                        # assigned by submit()


@dataclasses.dataclass
class RequestResult:
    rid: int
    prompt_len: int
    tokens: np.ndarray                   # generated ids (EOS-terminated)
    admitted_step: int                   # engine decode-step counters
    finished_step: int
    arrival: float                       # request arrival (trace clock)
    admitted_time: float                 # same clock as arrival when the
    finished_time: float                 # ... trace supplies one
    queue_wait: float = 0.0              # arrival -> admission seconds
    ttft: float = 0.0                    # arrival -> first sampled token
    prefill_chunks: int = 1              # chunked-prefill admissions > 1

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.shape[0])


@dataclasses.dataclass
class GenerationResult:
    """Compat result for :meth:`DecodeEngine.generate`."""
    tokens: np.ndarray                   # (b, steps) generated ids
    steps: int


class SlotScheduler:
    """Pure-host slot allocator: FIFO request queue over ``n_slots``
    cache slots.  No device state — the invariants (every queued request
    is admitted exactly once, a slot never serves two live requests) are
    property-tested in isolation (tests/test_serve.py)."""

    def __init__(self, n_slots: int):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.queue: Deque[int] = collections.deque()
        self.slot_rid: List[Optional[int]] = [None] * n_slots
        self._free: List[int] = list(range(n_slots))

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    @property
    def active_slots(self) -> List[int]:
        return [s for s, r in enumerate(self.slot_rid) if r is not None]

    def has_work(self) -> bool:
        return bool(self.queue) or self.n_active > 0

    def submit(self, rid: int) -> None:
        self.queue.append(rid)

    def admit(self) -> Optional[tuple]:
        """Pop (slot, rid) when a slot is free and a request is queued;
        None otherwise.  Lowest free slot first (deterministic)."""
        if not self.queue or not self._free:
            return None
        slot = min(self._free)
        self._free.remove(slot)
        rid = self.queue.popleft()
        assert self.slot_rid[slot] is None, "slot double-booked"
        self.slot_rid[slot] = rid
        return slot, rid

    def release(self, slot: int) -> int:
        rid = self.slot_rid[slot]
        assert rid is not None, "releasing a free slot"
        self.slot_rid[slot] = None
        self._free.append(slot)
        return rid


@dataclasses.dataclass
class _SlotState:
    """Host-side decode state of one occupied slot."""
    req: Request
    gen: List[int]                       # synced generated token ids
    first_dev: Optional[jax.Array]       # prefill-sampled token (device)
    remaining: int                       # decode steps left (max_tokens-1
    admitted_step: int                   # ... minus steps already run)
    admitted_time: float
    queue_wait: float = 0.0              # arrival -> admission seconds
    first_token_time: float = 0.0        # first token ready (run clock)
    admitted_abs: float = 0.0            # perf_counter absolutes for the
    first_abs: float = 0.0               # ... telemetry lifecycle spans
    prefill_chunks: int = 1              # admission chunks (paged mode)
    pos: int = 0                         # cache position (KV billing)


@dataclasses.dataclass
class _PrefillState:
    """A paged slot mid-admission: its prompt lands in fixed-size chunks
    interleaved with decode bursts, and the slot only joins the decode
    batch (device page-table row unmasked, first token sampled) after
    the last chunk."""
    req: Request
    row: np.ndarray                      # true (max_pages,) page table
    next_pos: int                        # prompt positions written so far
    chunks: int
    admitted_time: float
    admitted_abs: float
    queue_wait: float


class DecodeEngine:
    """Continuous-batching serving engine.

    ``batch`` is the slot-pool size (kept under its legacy name — each
    slot is one resident sequence of the live cache); ``temperature`` /
    ``eos_id`` are engine-level defaults that per-request values
    override.

    ``page_size`` switches the KV cache from dense per-slot rows to a
    block-paged pool (``max_len`` rounds up to a page multiple):
    ``n_pages`` sizes the pool (default: dense-equivalent capacity,
    ``slots * max_len / page_size`` plus the reserved sink page),
    ``prefill_chunk`` splits admissions into fixed-token chunks
    interleaved with decode bursts, and ``prefix_cache`` enables
    content-hash prefix sharing (shared prompts prefill once;
    copy-on-write on the first divergent mid-page append).
    """

    def __init__(self, params, cfg: ModelConfig, *, batch: int,
                 max_len: int, temperature: float = 0.0,
                 eos_id: Optional[int] = None,
                 page_size: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = True):
        self.params = params
        self.cfg = cfg
        self.n_slots = self.batch = batch
        self.temperature = temperature
        self.eos_id = eos_id
        self.paged = page_size is not None
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        if self.paged:
            # only attn-family stacks page: recurrent state has no
            # page-table indirection, so chunked prefill would reuse
            # stale slot state, decode bursts would mutate mid-prefill
            # recurrence (only attention writes are sink-masked), and
            # prefix sharing can't skip tokens through a recurrence
            bad = sorted({k for k in cfg.all_kinds
                          if k in ("ssm", "rec")})
            if bad:
                raise ValueError(
                    f"paged engine: recurrent layer kinds {bad} "
                    f"unsupported (arch {cfg.name}); use the dense "
                    "engine")
            if cfg.encoder_layers:
                raise ValueError("paged engine: encoder-decoder archs "
                                 "unsupported")
            # gathered-table length == dense max_len keeps the paged
            # reductions operand-for-operand identical to the dense
            # layout (the bit-parity contract); round up, never down
            max_len = -(-max_len // page_size) * page_size
        self.max_len = max_len

        self._prefill_slot = jax.jit(
            lambda p, toks, cache, slot, frames: T.prefill_into_slot(
                p, cfg, toks, cache, slot, max_len=max_len,
                frames=frames),
            donate_argnums=(2,))
        self._step = jax.jit(
            lambda p, tok, cache: T.decode_step(p, cfg, tok, cache),
            donate_argnums=(2,))
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        self._sample_temp = jax.jit(self._sample_temp_impl)

        self.kv: Optional[paging.PagedKV] = None
        self._prefilling: Dict[int, _PrefillState] = {}
        if self.paged:
            max_pages = max_len // page_size
            if n_pages is None:
                n_pages = 1 + self.n_slots * max_pages
            self.kv = paging.PagedKV(self.n_slots, n_pages, page_size,
                                     max_pages,
                                     prefix_cache=prefix_cache)
            self._table_np = np.full((self.n_slots, max_pages),
                                     paging.SINK_PAGE, np.int32)
            # prompt chunks compile per (length, start): the static
            # start makes the chunk's page-scatter indices and its
            # exact-length history slice compile-time, which is what
            # keeps chunked prefill bit-identical to a whole-prompt one
            self._prefill_chunk_fn = jax.jit(
                lambda p, toks, cache, slot, row, start:
                    T.prefill_paged_chunk(p, cfg, toks, cache, slot,
                                          row, start),
                static_argnums=(5,), donate_argnums=(2,))
            self._copy_pages = jax.jit(
                lambda cache, src, dst: T.copy_kv_pages(cache, src, dst),
                donate_argnums=(0,))

        self._requests: Dict[int, Request] = {}
        self._sched = SlotScheduler(self.n_slots)
        self._state: Dict[int, _SlotState] = {}      # slot -> state
        self._next_rid = 0
        self._cache = None
        self._tok = jnp.zeros((self.n_slots, 1), jnp.int32)
        self._temps = np.zeros((self.n_slots,), np.float32)
        self._key = jax.random.PRNGKey(0)
        self.reset_metrics()

    # ------------------------------------------------------------ sampling

    @staticmethod
    def _sample_temp_impl(logits, key, temps):
        """Per-slot sampling: greedy rows where temperature == 0,
        categorical at ``logits / temp`` elsewhere — one batched op."""
        greedy = jnp.argmax(logits, axis=-1)
        scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
        sampled = jax.random.categorical(key, scaled, axis=-1)
        return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> jax.Array:
        """logits: (n, V) -> (n,) int32 tokens."""
        if not (temps > 0).any():
            return self._argmax(logits)
        self._key, sub = jax.random.split(self._key)
        return self._sample_temp(logits, sub, jnp.asarray(temps))

    # ------------------------------------------------------------- metrics

    def reset_metrics(self) -> None:
        self.metrics = {
            "decode_steps": 0,           # batched decode_step calls
            "useful_slot_steps": 0,      # sum over steps of active slots
            "prefill_tokens": 0,         # exact prompt tokens prefilled
            "generated_tokens": 0,       # tokens in returned results
            "completed": 0,
            "decode_time": 0.0,          # wall seconds inside bursts
            "prefill_chunks": 0,         # admission chunks across reqs
            # longest run of prompt tokens prefilled while >= 1
            # decode-ready slot sat waiting — the stall chunking bounds
            "max_prefill_stall_tokens": 0,
            "prefix_hits": 0,
            "prefix_misses": 0,
            "shared_prompt_tokens": 0,   # prompt tokens never prefilled
            # decode KV traffic billed at true per-row positions
            # (page-rounded when paged) vs what dense max_len rows
            # stream — the honest-accounting satellite
            "modeled_kv_bytes": 0,
            "modeled_kv_bytes_dense_rows": 0,
        }
        self._stall_run = 0

    def occupancy(self) -> float:
        """Mean fraction of slots serving a live request per decode
        step — the utilization the lockstep engine wasted."""
        steps = self.metrics["decode_steps"]
        if steps == 0:
            return 0.0
        return self.metrics["useful_slot_steps"] / (steps * self.n_slots)

    def tokens_per_sec(self) -> float:
        """Rolling decode throughput (generated tokens over wall time
        spent in decode bursts; prefill + jit compile excluded)."""
        t = self.metrics["decode_time"]
        return self.metrics["generated_tokens"] / t if t > 0 else 0.0

    # ----------------------------------------------------------- lifecycle

    def submit(self, req: Request) -> int:
        """Queue a request; returns its rid (admission order = FIFO)."""
        # the last generated token is sampled but never written back, so
        # a request occupies cache positions 0..prompt+max_tokens-2;
        # past max_len the per-row write clamps (silently overwriting
        # the last slot) while the mask keeps admitting the whole cache
        # — reject instead of decoding garbage
        need = int(req.prompt.shape[0]) + req.max_tokens - 1
        if need > self.max_len:
            raise ValueError(
                f"request needs {need} cache positions "
                f"(prompt {int(req.prompt.shape[0])} + max_tokens "
                f"{req.max_tokens} - 1) but the engine was built with "
                f"max_len={self.max_len}")
        if self.paged:
            if req.frames is not None:
                # reject here, not at admission inside the serve loop —
                # a bad request must not crash a mid-trace run
                raise ValueError("paged engine: audio/enc-dec requests "
                                 "unsupported")
            total = self.kv.total_pages(need)
            cap = self.kv.pool.n_pages - 1
            if total > cap:
                raise ValueError(
                    f"request needs {total} pages but the pool only has "
                    f"{cap} allocatable pages")
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid
        self._requests[rid] = req
        self._sched.submit(rid)
        telemetry.event("serve.request.queued", rid=rid,
                        prompt_len=int(req.prompt.shape[0]),
                        max_tokens=req.max_tokens, arrival=req.arrival)
        return rid

    def _ensure_cache(self) -> None:
        if self._cache is None:
            if self.paged:
                self._cache = T.init_paged_cache(
                    self.cfg, self.n_slots, self.kv.pool.n_pages,
                    self.page_size, self.kv.max_pages)
            else:
                self._cache = T.init_cache(self.cfg, self.n_slots,
                                           self.max_len)

    def _update_page_gauges(self) -> None:
        telemetry.gauge("serve.kv_pages_used").set(self.kv.pool.n_used)
        telemetry.gauge("serve.kv_pages_free").set(self.kv.pool.n_free)

    def _note_prefill_stall(self, n_tokens: int) -> None:
        """Account ``n_tokens`` of prefill work done while at least one
        decode-ready slot sat waiting (the stall chunked prefill
        bounds); a decode burst resets the running stall."""
        if self._state:
            self._stall_run += n_tokens
            self.metrics["max_prefill_stall_tokens"] = max(
                self.metrics["max_prefill_stall_tokens"],
                self._stall_run)

    def _admit(self, slot: int, req: Request,
               clock: Callable[[], float]) -> None:
        """Prefill the request into ``slot`` of the live cache and seed
        its first sampled token.  The first token is synced here —
        admission IS the time-to-first-token boundary, so its timestamp
        must not drift into the next decode burst."""
        plen = int(req.prompt.shape[0])
        adm_time = clock()
        adm_abs = time.perf_counter()
        queue_wait = max(adm_time - req.arrival, 0.0)
        toks = jnp.asarray(req.prompt[None, :], jnp.int32)
        frames = None if req.frames is None \
            else jnp.asarray(req.frames[None])
        with telemetry.span("serve.prefill", rid=req.rid, slot=slot,
                            prompt_len=plen) as sp:
            logits, self._cache = self._prefill_slot(
                self.params, toks, self._cache,
                jnp.asarray(slot, jnp.int32), frames)
            temp = np.float32(req.temperature)
            first = self._sample(logits, temp[None])     # (1,)
            sp.sync(first)
        jax.block_until_ready(first)
        first_time = clock()
        self._tok = self._tok.at[slot, 0].set(first[0])
        self._temps[slot] = temp
        self.metrics["prefill_tokens"] += plen
        self.metrics["prefill_chunks"] += 1
        self._note_prefill_stall(plen)
        telemetry.counter("serve.prefill_tokens").add(plen)
        telemetry.event("serve.request.admitted", rid=req.rid, slot=slot,
                        queue_wait=queue_wait,
                        step=self.metrics["decode_steps"])
        self._state[slot] = _SlotState(
            req=req, gen=[], first_dev=first[0],
            remaining=req.max_tokens - 1,
            admitted_step=self.metrics["decode_steps"],
            admitted_time=adm_time, queue_wait=queue_wait,
            first_token_time=first_time, admitted_abs=adm_abs,
            first_abs=time.perf_counter(), pos=plen)

    def _admit_paged(self, slot: int, req: Request,
                     clock: Callable[[], float]) -> None:
        """Map pages for the request and stage its prompt for chunked
        prefill.  Nothing is computed here beyond a possible
        copy-on-write page duplication; the slot joins the decode batch
        when :meth:`_run_prefill_chunk` lands its last chunk."""
        if req.frames is not None:
            raise ValueError("paged engine: audio/enc-dec requests "
                             "unsupported")
        plen = int(req.prompt.shape[0])
        adm_time = clock()
        adm_abs = time.perf_counter()
        queue_wait = max(adm_time - req.arrival, 0.0)
        need = plen + req.max_tokens - 1
        plan = self.kv.admit(slot, req.prompt, need)
        if plan.cow_src:
            self._cache = self._copy_pages(
                self._cache, jnp.asarray(plan.cow_src, jnp.int32),
                jnp.asarray(plan.cow_dst, jnp.int32))
        if self.kv.prefix is not None:
            if plan.prefix_hit:
                self.metrics["prefix_hits"] += 1
                telemetry.counter("serve.prefix_cache.hits").add(1)
            else:
                self.metrics["prefix_misses"] += 1
                telemetry.counter("serve.prefix_cache.misses").add(1)
            self.metrics["shared_prompt_tokens"] += plan.shared_tokens
        self._update_page_gauges()
        telemetry.event("serve.request.admitted", rid=req.rid, slot=slot,
                        queue_wait=queue_wait,
                        step=self.metrics["decode_steps"],
                        pages=plan.n_pages,
                        shared_tokens=plan.shared_tokens)
        self._prefilling[slot] = _PrefillState(
            req=req, row=self.kv.table_row(slot),
            next_pos=plan.shared_tokens, chunks=0,
            admitted_time=adm_time, admitted_abs=adm_abs,
            queue_wait=queue_wait)

    def _run_prefill_chunk(self, clock: Callable[[], float]
                           ) -> Optional[RequestResult]:
        """Land ONE prompt chunk for the oldest mid-prefill slot.  On
        the final chunk: sample the first token (the TTFT boundary),
        unmask the slot's device page-table row, publish its prompt
        pages to the prefix cache, and promote it to the decode batch.
        Returns a result only for max_tokens <= 1 requests, which
        finish at promotion."""
        slot = next(iter(self._prefilling))
        st = self._prefilling[slot]
        req = st.req
        plen = int(req.prompt.shape[0])
        csize = self.prefill_chunk or (plen - st.next_pos)
        chunk = req.prompt[st.next_pos:st.next_pos + csize]
        s = int(chunk.shape[0])
        with telemetry.span("serve.prefill_chunk", rid=req.rid,
                            slot=slot, start=st.next_pos, tokens=s):
            logits, self._cache = self._prefill_chunk_fn(
                self.params, jnp.asarray(chunk[None, :], jnp.int32),
                self._cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(st.row), int(st.next_pos))
        st.next_pos += s
        st.chunks += 1
        self.metrics["prefill_tokens"] += s
        telemetry.counter("serve.prefill_tokens").add(s)
        self._note_prefill_stall(s)
        if st.next_pos < plen:
            return None

        temp = np.float32(req.temperature)
        first = self._sample(logits, temp[None])
        jax.block_until_ready(first)
        first_time = clock()
        del self._prefilling[slot]
        self._tok = self._tok.at[slot, 0].set(first[0])
        self._temps[slot] = temp
        self._table_np[slot] = st.row
        self._cache["page_table"] = jnp.asarray(self._table_np)
        self.kv.register_prefix(slot, req.prompt)
        self.metrics["prefill_chunks"] += st.chunks
        self._update_page_gauges()
        self._state[slot] = _SlotState(
            req=req, gen=[], first_dev=first[0],
            remaining=req.max_tokens - 1,
            admitted_step=self.metrics["decode_steps"],
            admitted_time=st.admitted_time, queue_wait=st.queue_wait,
            first_token_time=first_time, admitted_abs=st.admitted_abs,
            first_abs=time.perf_counter(), prefill_chunks=st.chunks,
            pos=plen)
        if req.max_tokens <= 1:
            self._sync_slot(slot, None, None)
            return self._finish(slot, clock())
        return None

    def _finish(self, slot: int, now: float) -> RequestResult:
        """Truncate at EOS / max_tokens, emit the result, free the slot
        (and drop the engine's reference to the request — a long-lived
        engine must not accumulate served prompts/results).

        Tokens sampled after EOS (a slot keeps stepping until the burst
        boundary) are dropped here — a result never contains post-EOS
        garbage."""
        st = self._state.pop(slot)
        req = st.req
        toks = st.gen[:req.max_tokens]
        eos = req.eos_id
        if eos is not None and eos in toks:
            toks = toks[:toks.index(eos) + 1]
        self._temps[slot] = 0.0
        self._sched.release(slot)
        if self.paged:
            self.kv.release(slot)
            self._table_np[slot] = paging.SINK_PAGE
            self._cache["page_table"] = jnp.asarray(self._table_np)
            self._update_page_gauges()
        self._requests.pop(req.rid, None)
        self.metrics["generated_tokens"] += len(toks)
        self.metrics["completed"] += 1
        ttft = max(st.first_token_time - req.arrival, 0.0)
        if telemetry.enabled():
            fin_abs = time.perf_counter()
            arr_abs = st.admitted_abs - st.queue_wait
            common = dict(tid=req.rid, rid=req.rid)
            telemetry.complete_span("serve.request", arr_abs, fin_abs,
                                    prompt_len=int(req.prompt.shape[0]),
                                    n_tokens=len(toks), ttft=ttft,
                                    queue_wait=st.queue_wait, **common)
            telemetry.complete_span("serve.request.queued", arr_abs,
                                    st.admitted_abs, **common)
            telemetry.complete_span("serve.request.prefill",
                                    st.admitted_abs, st.first_abs,
                                    **common)
            telemetry.complete_span("serve.request.decode", st.first_abs,
                                    fin_abs, tokens=len(toks),
                                    attn_plan=self._attn_plan_key(),
                                    **common)
            telemetry.event("serve.request.finished", rid=req.rid,
                            n_tokens=len(toks), ttft=ttft,
                            queue_wait=st.queue_wait,
                            e2e=max(now - req.arrival, 0.0))
            telemetry.counter("serve.generated_tokens").add(len(toks))
            telemetry.counter("serve.completed").add(1)
        return RequestResult(
            rid=req.rid, prompt_len=int(req.prompt.shape[0]),
            tokens=np.asarray(toks, np.int32),
            admitted_step=st.admitted_step,
            finished_step=self.metrics["decode_steps"],
            arrival=req.arrival,
            admitted_time=st.admitted_time, finished_time=now,
            queue_wait=st.queue_wait, ttft=ttft,
            prefill_chunks=st.prefill_chunks)

    def _sync_slot(self, slot: int, burst_host: Optional[np.ndarray],
                   col: Optional[int]) -> None:
        """Pull this burst's tokens for one slot into host state."""
        st = self._state[slot]
        if st.first_dev is not None:
            st.gen.append(int(st.first_dev))
            st.first_dev = None
        if burst_host is not None:
            st.gen.extend(int(t) for t in burst_host[:, col])

    def _slot_done(self, slot: int) -> bool:
        st = self._state[slot]
        if len(st.gen) >= st.req.max_tokens:
            return True
        eos = st.req.eos_id
        return eos is not None and eos in st.gen

    def run(self, requests: Optional[List[Request]] = None, *,
            now_fn: Optional[Callable[[], float]] = None,
            poll: float = 0.001) -> List[RequestResult]:
        """Drain the queue (plus ``requests``, submitted first) through
        the slot pool; returns results in completion order.

        ``now_fn`` is the trace clock (seconds since trace start) gating
        admissions by ``Request.arrival``; without it every queued
        request is immediately admittable.  ``poll`` is the idle sleep
        while all slots are free and the next arrival is in the future.
        """
        for req in requests or ():
            self.submit(req)
        self._ensure_cache()
        now = now_fn or (lambda: float("inf"))
        t_run0 = time.perf_counter()
        # result/telemetry timestamps share the arrival clock when the
        # trace supplies one, so queue-wait / TTFT / latency subtract
        # consistent quantities; admission gating keeps the legacy
        # semantics (no now_fn -> every queued request is admittable)
        clock = now_fn or (lambda: time.perf_counter() - t_run0)
        done: List[RequestResult] = []

        while self._sched.has_work():
            # ---- admissions: fill every free slot with an arrived req
            while self._sched.queue and self._sched._free and \
                    self._requests[self._sched.queue[0]].arrival <= now():
                req = self._requests[self._sched.queue[0]]
                if self.paged:
                    # one admission in flight at a time: the next
                    # request's prefix match must see this prompt's
                    # pages, which only publish when its last chunk
                    # lands — identical prompts arriving together
                    # still share
                    if self._prefilling:
                        break
                    need = int(req.prompt.shape[0]) + req.max_tokens - 1
                    if not self.kv.can_admit(req.prompt, need) and \
                            not self.kv.try_reclaim(req.prompt, need):
                        break   # head-of-line waits for freed pages
                slot, rid = self._sched.admit()
                if self.paged:
                    self._admit_paged(slot, req, clock)
                    continue    # finishes (if ever) at promotion
                self._admit(slot, req, clock)
                if req.max_tokens <= 1:
                    self._sync_slot(slot, None, None)
                    done.append(self._finish(slot, clock()))

            # ---- chunked prefill: one chunk of the oldest admission,
            #      interleaved with the decode bursts below
            if self._prefilling:
                r = self._run_prefill_chunk(clock)
                if r is not None:
                    done.append(r)

            active = [s for s in self._sched.active_slots
                      if s in self._state]
            telemetry.gauge("serve.slots_active").set(len(active))
            if not active:
                if self._sched.queue and not self._prefilling:
                    time.sleep(poll)       # waiting on the next arrival
                continue

            # ---- decode burst: exact to the tightest max_tokens,
            #      EOS checked at the boundary
            k = min([EOS_CHECK_EVERY]
                    + [self._state[s].remaining for s in active])
            burst: List[jax.Array] = []
            with telemetry.span("serve.decode_burst", steps=max(k, 1),
                                active=len(active),
                                attn_plan=self._attn_plan_key()):
                t_burst0 = time.perf_counter()
                for _ in range(max(k, 1)):
                    logits, self._cache = self._step(
                        self.params, self._tok, self._cache)
                    samp = self._sample(logits, self._temps)
                    self._tok = samp[:, None]
                    burst.append(samp)
                jax.block_until_ready(self._tok)
            self.metrics["decode_time"] += time.perf_counter() - t_burst0
            self.metrics["decode_steps"] += len(burst)
            self.metrics["useful_slot_steps"] += len(burst) * len(active)
            telemetry.counter("serve.decode_steps").add(len(burst))
            self._stall_run = 0            # decode ran; stall over
            for j in range(len(burst)):    # KV billed at true positions
                self.metrics["modeled_kv_bytes"] += \
                    self.modeled_kv_bytes_per_step(
                        [self._state[s].pos + j for s in active])
            self.metrics["modeled_kv_bytes_dense_rows"] += \
                len(burst) * self._dense_rows_kv_bytes_per_step()
            for s in active:
                self._state[s].remaining -= len(burst)
                self._state[s].pos += len(burst)

            # ---- sync + completions
            host = np.asarray(jnp.stack(burst, axis=0))   # (k, n_slots)
            for s in active:
                self._sync_slot(s, host, s)
                if self._slot_done(s):
                    done.append(self._finish(s, clock()))
            telemetry.gauge("serve.slots_active").set(
                self._sched.n_active)

        return done

    # -------------------------------------------------- compat interface

    def generate(self, prompts: jax.Array, n_steps: int,
                 frames: Optional[jax.Array] = None,
                 seed: int = 0) -> GenerationResult:
        """Lockstep-compatible front end: prompts (b, s) int32, up to
        ``n_steps`` tokens each, returned as a dense (b, steps) array.
        Rows that finish early (EOS) are padded with ``eos_id`` —
        post-EOS samples never leak into the result.

        Each row admits through the per-request batch-1 slot prefill
        (b small dispatches instead of the old single (b, s) batched
        prefill) — the deliberate trade for a cache that requests can
        enter and leave independently; decode runs fully batched."""
        self._key = jax.random.PRNGKey(seed)
        prompts_np = np.asarray(prompts)
        frames_np = None if frames is None else np.asarray(frames)
        reqs = [Request(prompt=prompts_np[i], max_tokens=n_steps,
                        temperature=self.temperature, eos_id=self.eos_id,
                        frames=None if frames_np is None
                        else frames_np[i])
                for i in range(prompts_np.shape[0])]
        results = {r.rid: r for r in self.run(reqs)}
        ordered = [results[req.rid] for req in reqs]
        steps = max(r.n_tokens for r in ordered)
        fill = self.eos_id if self.eos_id is not None else 0
        out = np.full((len(ordered), steps), fill, np.int32)
        for i, r in enumerate(ordered):
            out[i, :r.n_tokens] = r.tokens
        return GenerationResult(tokens=out, steps=steps)

    # ------------------------------------------------------- cost model

    def _attn_layer_windows(self) -> List[tuple]:
        """(window, layer_count) per attn-family layer kind in the
        stack — the layers that stream KV cache every decode step."""
        cfg = self.cfg
        out = []
        for kind in cfg.layer_pattern:
            if kind in ("attn", "moe"):
                out.append((cfg.window, cfg.repeats))
            elif kind == "local":
                out.append((cfg.local_window, cfg.repeats))
        for kind in cfg.tail_pattern:
            if kind in ("attn", "moe"):
                out.append((cfg.window, 1))
            elif kind == "local":
                out.append((cfg.local_window, 1))
        return out

    def _attn_plan_key(self) -> Optional[str]:
        """The decode-mode attention plan this engine's steps resolve to
        (``spec key @ shape -> kernel``) — attached to decode-burst and
        per-request decode spans so Perfetto traces attribute the time
        to a specific plan.  ``None`` until the first decode traces."""
        from repro import ops as rops
        for pl in reversed(rops.attn_plans()):
            if pl.spec.mode in ("decode", "decode_paged"):
                return f"{pl.spec.key}@{pl.shape_key}->{pl.kernel}"
        return None

    def modeled_kv_bytes_per_step(self, positions) -> int:
        """Modeled KV-cache HBM bytes one batched decode step streams,
        billed at the given true per-row positions (window-clamped when
        dense; whole history pages when paged — the paged kernel masks
        windows in-VMEM, so windowed layers still move every page)."""
        cfg = self.cfg
        total = 0
        for window, count in self._attn_layer_windows():
            total += count * bandwidth.decode_kv_bytes(
                positions, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                dtype=cfg.dtype, window=window,
                page_size=self.page_size)
        return total

    def _dense_rows_kv_bytes_per_step(self) -> int:
        """What dense per-slot rows stream per step: every slot's full
        ``max_len`` allocation (window-clamped for ring layers),
        regardless of true positions — the overstatement the paged
        billing corrects."""
        cfg = self.cfg
        positions = [self.max_len - 1] * self.n_slots
        total = 0
        for window, count in self._attn_layer_windows():
            total += count * bandwidth.decode_kv_bytes(
                positions, n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                dtype=cfg.dtype, window=window)
        return total

    def modeled_bytes_per_token(self, positions=None) -> int:
        """Modeled HBM traffic of ONE batched decode step (the whole
        slot pool shares it): the GEMM weight stream (every projection
        leaf through VMEM once, at storage width — the term the
        mixed-precision path halves) plus the KV-cache stream billed at
        true per-row positions (live slots by default; pages touched,
        not ``max_len`` rows)."""
        from repro import quant
        total = quant.gemm_weight_bytes(self.params)
        if positions is None:
            positions = [self._state[s].pos
                         for s in sorted(self._state)]
        if positions:
            total += self.modeled_kv_bytes_per_step(positions)
        return total
