"""AdamW with decoupled weight decay — plain-pytree implementation.

States are fp32 regardless of param dtype (bf16-safe training); the
optimizer state pytree mirrors the param tree, so whatever sharding the
layout engine assigns to a parameter applies verbatim to its moments
(ZeRO-style state sharding falls out of 2D weight sharding for free).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def state_specs(param_specs, params) -> AdamWState:
    """PartitionSpec pytree mirroring :func:`init` (moments inherit the
    param spec verbatim)."""
    from jax.sharding import PartitionSpec as P
    is_spec = lambda x: isinstance(x, P)            # noqa: E731
    copy = lambda: jax.tree.map(lambda s: s, param_specs,   # noqa: E731
                                is_leaf=is_spec)
    return AdamWState(step=P(), mu=copy(), nu=copy())


def update(grads, state: AdamWState, params, *, lr: float | jax.Array,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1) -> Tuple[dict, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:                      # no decay on norms/biases
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
            m_new, v_new

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
