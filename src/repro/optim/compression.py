"""int8 error-feedback gradient compression for cross-pod reduction.

The 'pod' mesh axis rides DCN (~25 GB/s/chip vs ~50+ GB/s ICI links), so
the cross-pod gradient all-reduce is the distributed-optimization
bottleneck at multi-pod scale.  This module quantizes gradients to int8
(per-tensor symmetric scale) before the 'pod' reduction and carries the
quantization residual into the next step (error feedback), which keeps
SGD-style convergence unbiased in practice.

Usage (inside the donated train_step):

    grads = psum_scaled(grads, ('data',))            # intra-pod, full prec
    grads, err = compress_psum(grads, err, 'pod')    # cross-pod, int8

The convergence effect is validated in tests/test_substrates.py; the
bytes saving shows up in the multi-pod dry-run's collective table (4x on
the 'pod'-axis all-reduce).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_psum(grads, err, axis_name: str):
    """Quantize (grads + carried error), psum int8 payloads over
    ``axis_name``, dequantize, and return (mean_grads, new_err).

    Must run inside shard_map/pmap context where ``axis_name`` is bound.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = q.astype(jnp.float32) * scale
        new_e = gf - deq
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        # scales differ per pod: psum the dequantized contribution scale
        # by exchanging the max scale (cheap scalar reduction)
        scale_sum = jax.lax.psum(scale, axis_name)
        # unbiased-ish: use mean scale for the summed int payload
        mean = total.astype(jnp.float32) * (scale_sum / n) / n
        return mean.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, err)
    is_t = lambda x: isinstance(x, tuple)       # noqa: E731
    new_grads = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    new_err = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    return new_grads, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
