"""Adafactor (factored second moments) — the >=235B-param optimizer.

For a (r, c) matrix the second-moment estimate is stored as a length-r
row statistic + length-c column statistic instead of r*c, so optimizer
state for kimi-k2's 1T parameters is ~1/3500th of AdamW's.  Follows
Shazeer & Stern 2018 (beta2 schedule, RMS update clipping); momentum-free.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: dict          # row stats  (matrices) / full stats (vectors)
    vc: dict          # col stats  (matrices) / empty (vectors)


EPS1 = 1e-30
CLIP = 1.0


def _factored(p) -> bool:
    return p.ndim >= 2


def init(params) -> AdafactorState:
    def vr_init(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
            else jnp.zeros(p.shape, jnp.float32)

    def vc_init(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if _factored(p) else jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr_init, params),
                          vc=jax.tree.map(vc_init, params))


def state_specs(param_specs, params) -> AdafactorState:
    """PartitionSpec pytree mirroring :func:`init`: row stats drop the
    param spec's last entry, col stats its second-to-last — so factored
    moments stay sharded exactly like the dims they summarize (a 1T-param
    model cannot afford replicated row/col stats)."""
    from jax.sharding import PartitionSpec as P
    is_spec = lambda x: isinstance(x, P)            # noqa: E731

    def vr_spec(s, p):
        return P(*s[:-1]) if _factored(p) else P(*s)

    def vc_spec(s, p):
        return P(*(tuple(s[:-2]) + (s[-1],))) if _factored(p) else P(None)

    vr = jax.tree.map(vr_spec, param_specs, params, is_leaf=is_spec)
    vc = jax.tree.map(vc_spec, param_specs, params, is_leaf=is_spec)
    return AdafactorState(step=P(), vr=vr, vc=vc)


def update(grads, state: AdafactorState, params, *,
           lr: float | jax.Array, weight_decay: float = 0.0,
           ) -> Tuple[dict, AdafactorState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8

    def upd(g, vr, vc, p):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + EPS1
        if _factored(p):
            vr_new = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc_new = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
            r = vr_new / jnp.maximum(
                jnp.mean(vr_new, axis=-1, keepdims=True), EPS1)
            u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc_new)[..., None, :]
                      + EPS1)
        else:
            vr_new = beta2 * vr + (1 - beta2) * g2
            vc_new = vc
            u = gf / (jnp.sqrt(vr_new) + EPS1)
        # RMS clip
        rms = jnp.sqrt(jnp.mean(u * u) + EPS1)
        u = u / jnp.maximum(1.0, rms / CLIP)
        if p.ndim >= 2 and weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), \
            vr_new, vc_new

    out = jax.tree.map(upd, grads, state.vr, state.vc, params)
    is_t = lambda x: isinstance(x, tuple)       # noqa: E731
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=is_t)
    vr = jax.tree.map(lambda o: o[1], out, is_leaf=is_t)
    vc = jax.tree.map(lambda o: o[2], out, is_leaf=is_t)
    return new_params, AdafactorState(step=step, vr=vr, vc=vc)
