"""Name-pattern partition-spec engine + layout DSE (the *policy* half of
``repro.dist``).

The paper picks one GEMM tiling per architecture by exhaustively scoring
the design space against a memory model (Tables III/IV); this module is
the same methodology one level up the hierarchy: for a whole model on a
whole mesh, enumerate the candidate *sharding strategies*, score each by
per-device bytes + collective traffic, and emit the concrete
``PartitionSpec`` for every parameter / cache / batch leaf under the
winner.

Strategies (over mesh axes ``('pod',) 'data', 'model'``):

* ``dp``      — pure data parallel: params replicated.
* ``tp``      — Megatron-style tensor parallel over ``'model'``:
  column-parallel projections shard their output dim, row-parallel
  their input dim; MoE expert banks shard the expert dim (EP).
* ``fsdp``    — parameters sharded over the batch-like axes
  (``('pod', 'data')``), gathered per layer.
* ``fsdp_tp`` — both: ``tp`` sharding over ``'model'`` plus FSDP of
  what remains over ``('pod', 'data')``.

Every placement is divisibility-checked: a dim that does not divide its
mesh axes **relaxes to replicated** instead of erroring, so published
odd shapes (a 950-wide projection on a 16-way axis) and tiny smoke
configs flow through the same engine (tests/test_layout.py pins this).

Specs are *full-rank* (one entry per dim) and derived from parameter
*names*, so they survive structural rewrites of the leaves — notably
the int8 ``{"q", "scale"}`` structs from :mod:`repro.quant`, which
inherit the parent weight's placement (the per-channel scale relaxes
on its broadcast dim automatically).
"""

from __future__ import annotations

import math
import re
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding

STRATEGIES = ("dp", "tp", "fsdp", "fsdp_tp")

# ---------------------------------------------------------------------------
# Name patterns -> trailing-dim roles
#
# Roles name the *parallelism direction* of each trailing dim; leading
# (stacked scan / vmap) dims are always replicated.  'fsdp' dims shard
# over the batch-like axes, 'tp' dims over 'model', 'expert' dims over
# 'model' (expert parallelism), 'rep' dims stay replicated.
# ---------------------------------------------------------------------------

_PATTERNS: Tuple[Tuple[re.Pattern, Tuple[str, ...]], ...] = tuple(
    (re.compile(pat), roles) for pat, roles in (
        # expert banks keep the expert-dim sharding under the grouped
        # ragged GEMM path: the kernel consumes the same stacked
        # (E, k, n) leaves, so EP placement is unchanged (quantized
        # {"q","scale"} structs inherit it below as everywhere else)
        (r"moe/router$", ("rep", "rep")),
        (r"moe/w_(gate|up)$", ("expert", "fsdp", "tp")),
        (r"moe/w_down$", ("expert", "tp", "fsdp")),
        (r"(attn|cross)/w[qkv]$", ("fsdp", "tp")),      # column-parallel
        (r"(attn|cross)/wo$", ("tp", "fsdp")),          # row-parallel
        (r"mlp/w_(gate|up|in)$", ("fsdp", "tp")),
        (r"mlp/w_(down|out)$", ("tp", "fsdp")),
        (r"(mixer|rec)/in_proj$", ("fsdp", "tp")),
        (r"(mixer|rec)/out_proj$", ("tp", "fsdp")),
        (r"rec/w_[ri]$", ("fsdp", "tp")),
        (r"lm_head$", ("fsdp", "tp")),
        (r"embed$", ("fsdp", "tp")),
    ))

#: quantized-struct leaf names that inherit the parent weight's pattern
_QUANT_SUFFIX = re.compile(r"/(q|scale)$")

#: role resolution priority — 'expert' claims 'model' before 'tp' can
_ROLE_ORDER = ("expert", "tp", "fsdp")


def _prod(xs) -> int:
    return int(math.prod(xs)) if xs else 1


def _fsdp_candidates(axis_sizes: Dict[str, int]) -> Tuple[Tuple[str, ...], ...]:
    """Batch-like axis combinations to try for an 'fsdp' dim, widest
    first: ('pod','data') -> ('data',) -> ('pod',)."""
    present = tuple(a for a in sharding.DATA_AXES if a in axis_sizes)
    cands = []
    if len(present) > 1:
        cands.append(present)
    for a in reversed(present):
        cands.append((a,))
    return tuple(cands)


def _axis_for_role(role: str, dim: int, strategy: str,
                   axis_sizes: Dict[str, int], used: set):
    """Mesh axis (or axes tuple) for one (role, dim) under ``strategy``,
    or None (inactive role / no divisible placement)."""
    if role in ("rep", None) or strategy == "dp":
        return None
    if role == "expert" or (role == "tp" and strategy in ("tp", "fsdp_tp")):
        m = axis_sizes.get("model", 1)
        if "model" not in used and m > 0 and dim % m == 0 \
                and "model" in axis_sizes:
            return "model"
        return None
    if role == "fsdp" and strategy in ("fsdp", "fsdp_tp"):
        for cand in _fsdp_candidates(axis_sizes):
            if any(a in used for a in cand):
                continue
            if dim % _prod([axis_sizes[a] for a in cand]) == 0:
                return cand if len(cand) > 1 else cand[0]
        return None
    return None


def spec_for(name: str, shape: Sequence[int], strategy: str,
             axis_sizes: Dict[str, int]) -> P:
    """Full-rank PartitionSpec for one named parameter leaf.

    ``name`` is the '/'-joined tree path (e.g. ``layers/u0/attn/wq`` or
    the quantized ``layers/u0/attn/wq/q``); ``axis_sizes`` maps mesh
    axis names to sizes.  Unknown names are replicated.
    """
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown layout strategy {strategy!r}; want one of "
            f"{STRATEGIES}")
    base = _QUANT_SUFFIX.sub("", name)
    roles: Optional[Tuple[str, ...]] = None
    for pat, r in _PATTERNS:
        if pat.search(base):
            roles = r
            break
    rank = len(shape)
    entries: list = [None] * rank
    if roles is None:
        return P(*entries)
    roles = roles[-rank:]
    offset = rank - len(roles)
    used: set = set()
    for want in _ROLE_ORDER:
        for i, role in enumerate(roles):
            if role != want:
                continue
            ax = _axis_for_role(role, int(shape[offset + i]), strategy,
                                axis_sizes, used)
            if ax is not None:
                entries[offset + i] = ax
                used.update(ax if isinstance(ax, tuple) else (ax,))
    return P(*entries)


# ---------------------------------------------------------------------------
# Tree-level spec derivation
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params, cfg, mesh, strategy: Optional[str] = None):
    """PartitionSpec pytree mirroring ``params`` (full-rank leaves).

    ``mesh`` only contributes axis names/sizes, so duck-typed meshes
    work; ``strategy`` defaults to :func:`choose_layout` scored against
    *this* mesh's axes.
    """
    sizes = sharding.axis_sizes(mesh)
    strategy = strategy or choose_layout(cfg, sizes)

    def one(path, leaf):
        return spec_for(_path_str(path), leaf.shape, strategy, sizes)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, cfg, mesh, strategy: Optional[str] = None):
    """NamedShardings for ``params`` on a *concrete* mesh."""
    specs = param_specs(params, cfg, mesh, strategy)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _data_axes(mesh, rows: int):
    """Batch-like mesh axes that divide ``rows`` (see
    :func:`repro.dist.sharding.data_axes_for`)."""
    return sharding.data_axes_for(int(rows), sharding.axis_sizes(mesh))


def batch_specs(batch, mesh):
    """Row-shard every batch leaf over the batch-like axes (dim 0); all
    other dims replicated.  Rows that don't divide (batch=1 long-context
    cells) replicate rather than fail."""

    def one(leaf):
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        return P(_data_axes(mesh, int(leaf.shape[0])),
                 *([None] * (rank - 1)))

    return jax.tree.map(one, batch)


def cache_specs(cache, mesh):
    """Decode/prefill cache specs.

    Scanned caches under ``layers``/``cross`` are stacked
    ``(repeats, batch, ...)`` — batch at dim 1; unstacked ``tail``
    caches carry batch at dim 0, as does the per-slot ``pos`` vector
    ((batch,) int32 — continuous batching gives every slot its own
    decode position, so ``pos`` row-shards with the slots it indexes).
    KV tensors additionally shard their sequence dim over ``'model'``
    (sequence-sharded cache reads are the decode-side analogue of the
    paper's operand-reuse tiling: each device keeps 1/|model| of the
    window resident).  Everything else (conv states, SSM states) shards
    batch only.

    Block-paged caches (``"page_table"`` present) have no batch dim on
    their k/v leaves — the page pool is shared by every slot, and any
    page may serve any sequence — so the pool shards its *kv-head* dim
    over ``'model'`` instead (head-parallel decode keeps each device's
    table gathers local); ``pos``/``page_table`` row-shard with the
    slots they index and non-attention layer states keep the dense
    batch rule.
    """
    sizes = sharding.axis_sizes(mesh)
    model_ok = "model" in sizes
    paged = isinstance(cache, dict) and "page_table" in cache

    def one(path, leaf):
        rank = len(leaf.shape)
        if rank == 0:
            return P()
        keys = [str(p.key) for p in path
                if isinstance(p, jax.tree_util.DictKey)]
        if paged and keys and keys[-1] in ("k", "v"):
            # (units?, n_pages, page_size, hkv, hd): shard kv heads
            entries = [None] * rank
            hdim = rank - 2
            if model_ok and int(leaf.shape[hdim]) % sizes["model"] == 0:
                entries[hdim] = "model"
            return P(*entries)
        stacked = bool(keys) and keys[0] in ("layers", "cross")
        bdim = 1 if stacked and rank >= 2 else 0
        entries: list = [None] * rank
        entries[bdim] = _data_axes(mesh, int(leaf.shape[bdim]))
        sdim = bdim + 1
        if keys and keys[-1] in ("k", "v") and sdim < rank and model_ok \
                and int(leaf.shape[sdim]) % sizes["model"] == 0:
            entries[sdim] = "model"
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache)


# ---------------------------------------------------------------------------
# Layout DSE — choose_layout
# ---------------------------------------------------------------------------

#: per-collective latency/launch overhead, expressed in byte-equivalents
#: (what ~1 ms of ICI time moves); penalizes FSDP's per-layer gathers
#: for models small enough that replication is free
LATENCY_EQUIV_BYTES = 32 * 2 ** 20

#: HBM feasibility headroom — fragmentation + temp buffers
HBM_FIT_FRACTION = 0.9

#: optimizer switch mirrors repro.train.train_step.ADAFACTOR_THRESHOLD
#: (not imported: layout must stay import-cycle-free below the models)
_ADAFACTOR_THRESHOLD = 100e9

_DEFAULT_AXES = {"data": 16, "model": 16}       # production single pod


def _train_bytes_per_param(cfg) -> float:
    """bf16 params + fp32 grads + optimizer state (AdamW m,v fp32; the
    >=100B regime uses Adafactor whose factored stats are ~free)."""
    opt = 8.0 if cfg.param_count() < _ADAFACTOR_THRESHOLD else 0.5
    return 2.0 + 4.0 + opt


def score_layouts(cfg, axis_sizes: Optional[Dict[str, int]] = None, *,
                  hbm_bytes: Optional[int] = None) -> Dict[str, dict]:
    """Score every strategy for ``cfg`` on a mesh of ``axis_sizes``.

    The cost model (the Table III/IV analogue): per-device resident
    bytes, param-collective wire bytes per step, and a per-collective
    latency charge.  Returns ``{strategy: {mem_bytes_per_device,
    collective_bytes_per_device, n_collectives, feasible, score}}``.
    """
    sizes = dict(axis_sizes or _DEFAULT_AXES)
    model = max(1, sizes.get("model", 1))
    dataprod = _prod([sizes[a] for a in sharding.DATA_AXES if a in sizes])
    dataprod = max(1, dataprod)
    if hbm_bytes is None:
        from repro.core.hardware import TPU_V5E
        hbm_bytes = TPU_V5E.hbm_bytes

    n_params = cfg.param_count()
    train_bytes = n_params * _train_bytes_per_param(cfg)
    grad_wire = 2.0 * n_params                  # bf16 grads on the wire
    n_layers = cfg.n_layers

    shard_factor = {"dp": 1, "tp": model, "fsdp": dataprod,
                    "fsdp_tp": dataprod * model}
    # (wire bytes per device per step, collective count per step):
    # dp/tp sync grads once; fsdp adds per-layer gathers fwd+bwd plus
    # the grad reduce-scatter (~3x param wire bytes, 3L+1 launches)
    collectives = {
        "dp": (2.0 * grad_wire, 1),
        "tp": (2.0 * grad_wire / model, 1),
        "fsdp": (3.0 * grad_wire, 3 * n_layers + 1),
        "fsdp_tp": (3.0 * grad_wire / model, 3 * n_layers + 1),
    }
    out = {}
    for s in STRATEGIES:
        mem = train_bytes / shard_factor[s]
        wire, n_coll = collectives[s]
        out[s] = {
            "mem_bytes_per_device": mem,
            "collective_bytes_per_device": wire,
            "n_collectives": n_coll,
            "feasible": mem <= HBM_FIT_FRACTION * hbm_bytes,
            "score": mem + wire + n_coll * LATENCY_EQUIV_BYTES,
        }
    return out


def choose_layout(cfg, axis_sizes: Optional[Dict[str, int]] = None, *,
                  hbm_bytes: Optional[int] = None) -> str:
    """Cheapest feasible strategy for ``cfg``; when nothing fits (the
    1T-param tier even at full sharding) fall back to the min-memory
    strategy so the dry-run still characterizes the closest layout."""
    scored = score_layouts(cfg, axis_sizes, hbm_bytes=hbm_bytes)
    feasible = {s: v for s, v in scored.items() if v["feasible"]}
    if feasible:
        return min(feasible, key=lambda s: feasible[s]["score"])
    return min(scored, key=lambda s: scored[s]["mem_bytes_per_device"])
