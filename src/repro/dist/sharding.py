"""Mesh lifecycle + activation sharding constraints (the *mechanism* half
of ``repro.dist``).

Model code never imports jax.sharding directly: it calls
``act(x, ("batch", "seq", None))`` with *logical* axis names and this
module resolves them against whatever mesh is active — or does nothing
at all when no mesh is installed, so the exact same forward runs on a
single-host CPU test and a 512-chip multi-pod dry-run.

Logical axes:

* ``"batch"``  — the data-parallel direction; resolves to every
  batch-like mesh axis present (``('pod', 'data')`` on multi-pod
  meshes, ``'data'`` on single-pod ones).
* ``"seq"``    — sequence parallelism; resolves to ``'model'`` when
  enabled (``REPRO_SEQ_SHARD != '0'``), so the stored remat carry is
  1/|model| per device, else to ``None``.
* ``"expert"`` — expert parallelism; resolves to ``'model'``.
* ``"model"`` / ``"data"`` / ``"pod"`` — pass through to the mesh axis
  of the same name.
* ``None``     — dim left unconstrained-replicated.

Every resolution is divisibility-checked against the actual dim size:
a dim that does not divide its mesh axes falls back to replicated
instead of failing, mirroring the layout engine's relaxation rule.

The module also hosts the version-compat wrappers :func:`make_mesh` and
:func:`shard_map` — the repo targets the jax_pallas toolchain baked into
the image, whose mesh/shard_map signatures drifted across releases
(``axis_types=`` and ``check_vma=`` exist only on newer jax).
"""

from __future__ import annotations

import contextlib
import os
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Mesh lifecycle
# ---------------------------------------------------------------------------

_MESH_STACK: list = []


def current_mesh():
    """The innermost active mesh, or ``None`` outside any ``use_mesh``."""
    return _MESH_STACK[-1] if _MESH_STACK else None


@contextlib.contextmanager
def use_mesh(mesh):
    """Install ``mesh`` as the ambient mesh for the dynamic extent.

    Nestable and exception-safe: the previous mesh (or no-mesh state) is
    restored on exit.  ``mesh`` may be any object exposing
    ``axis_names`` + ``devices`` (a real ``jax.sharding.Mesh``, or a
    duck-typed stand-in in spec-level tests).
    """
    _MESH_STACK.append(mesh)
    try:
        yield mesh
    finally:
        _MESH_STACK.pop()


def axis_sizes(mesh) -> Dict[str, int]:
    """``{axis_name: size}`` for a (possibly duck-typed) mesh."""
    if mesh is None:
        return {}
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def mesh_devices(mesh) -> int:
    return int(mesh.devices.size) if mesh is not None else 1


# ---------------------------------------------------------------------------
# Logical-axis resolution
# ---------------------------------------------------------------------------

#: batch-like mesh axes, outermost first — "batch" binds to all present
DATA_AXES: Tuple[str, ...] = ("pod", "data")


def seq_shard_enabled() -> bool:
    return os.environ.get("REPRO_SEQ_SHARD", "1") != "0"


def _divides(dim: int, sizes: Dict[str, int], axes) -> bool:
    total = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        total *= sizes.get(a, 1)
    return total > 0 and dim % total == 0


def data_axes_for(dim: int, sizes: Dict[str, int]):
    """Batch-like mesh axes that divide ``dim``: the widest suffix of
    ``DATA_AXES`` whose product divides, else None (replicate).  Shared
    by the 'batch' logical axis here and the layout engine's batch/cache
    row sharding."""
    present = tuple(a for a in DATA_AXES if a in sizes)
    for start in range(len(present)):
        cand = present[start:]
        if _divides(dim, sizes, cand):
            return cand if len(cand) > 1 else cand[0]
    return None


def resolve_axis(logical: Optional[str], dim: int,
                 sizes: Dict[str, int]):
    """One logical axis -> mesh axis (or axes tuple), divisibility-checked.

    Returns ``None`` when the logical axis has no mesh backing or the
    dim does not divide it (relax-to-replicated).
    """
    if logical is None:
        return None
    if logical == "batch":
        return data_axes_for(dim, sizes)
    if logical == "seq":
        if not seq_shard_enabled():
            return None
        logical = "model"
    if logical == "expert":
        logical = "model"
    if logical in sizes and _divides(dim, sizes, logical):
        return logical
    return None


def logical_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 sizes: Dict[str, int]) -> P:
    """Full-rank PartitionSpec for ``shape`` from logical axis names,
    dropping any axis claimed twice (a mesh axis can shard one dim)."""
    assert len(shape) == len(axes), (tuple(shape), tuple(axes))
    used: set = set()
    out = []
    for dim, name in zip(shape, axes):
        r = resolve_axis(name, int(dim), sizes)
        flat = r if isinstance(r, tuple) else (r,) if r else ()
        if any(a in used for a in flat):
            r = None
            flat = ()
        used.update(flat)
        out.append(r)
    return P(*out)


def act(x: jax.Array, *axes) -> jax.Array:
    """Constrain activation ``x`` to the logical ``axes`` layout.

    Accepts either ``act(x, ("batch", None, "model"))`` or
    ``act(x, "batch", None, "model")``.  A no-op when no mesh is active,
    when the active mesh is trivial (single device), or when the mesh is
    a duck-typed spec-level stand-in — so model code is unconditionally
    safe to run un-meshed.
    """
    if len(axes) == 1 and isinstance(axes[0], (tuple, list)):
        axes = tuple(axes[0])
    mesh = current_mesh()
    if mesh is None or not isinstance(mesh, Mesh) or mesh_devices(mesh) <= 1:
        return x
    if len(axes) != x.ndim:          # rank drift (e.g. squeezed decode)
        return x
    spec = logical_spec(x.shape, axes, axis_sizes(mesh))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# jax version compat
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None) -> Mesh:
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types=(AxisType.Auto, ...)`` for
    meshes used with GSPMD auto partitioning; older jax predates the
    kwarg (and Auto is the only behavior).  Try rich -> plain.
    """
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
                **kwargs)
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across jax versions (``check_vma`` vs ``check_rep``)."""
    top = getattr(jax, "shard_map", None)
    if top is not None:
        for kw in ({"check_vma": check}, {"check_rep": check}, {}):
            try:
                return top(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)
            except TypeError:
                continue
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check)
