"""``repro.dist`` — the sharding + layout subsystem.

Two layers, mirroring the paper's split between *mechanism* (how a GEMM
is tiled onto an array) and *policy* (which tiling the DSE picks):

* :mod:`repro.dist.sharding` — mechanism.  Mesh lifecycle
  (``use_mesh`` / ``current_mesh``), the ``act`` activation-sharding
  constraint (a no-op off-mesh, so the same model code runs on a laptop
  CPU and a 512-chip pod), and version-tolerant wrappers over the jax
  mesh / shard_map APIs.
* :mod:`repro.dist.layout` — policy.  The name-pattern partition-spec
  engine (``spec_for`` and the tree-level ``param_specs`` /
  ``cache_specs`` / ``batch_specs``) plus ``choose_layout``, the
  mesh-scale analogue of the paper's Table III/IV tile search: score
  candidate strategies by per-device bytes + collective traffic, pick
  the cheapest feasible one.
"""

from repro.dist import layout, sharding  # noqa: F401
