"""Faithful analytical models of the paper's two GEMM frameworks.

This module reproduces, in code, the analytical machinery of

    *Efficient Approaches for GEMM Acceleration on Leading AI-Optimized
    FPGAs* (Taka, Gourounas, Gerstlauer, Marculescu, Arora, 2024)

for both devices:

* **Versal VC1902** (SS IV-A): the MaxEVA AIE solutions, the PL buffer
  geometry (eq. 1-3), the BRAM/URAM block-count model (eq. 4-5), the
  depth constraint (eq. 6), the resource constraints (eq. 7-8 over all
  mapping permutations), the reuse-maximizing U,V,W IP/DSE, the HLS-AUTO
  failure mode (Table II), the worst-case DDR bandwidth model, the RAM
  *efficiency* metric, and a calibrated throughput model.

* **Stratix 10 NX 2100** (SS IV-B): the TB layout algebra (compute GEMM
  size), the M20K block-count model (eq. 9-14), the IP solver maximizing
  ``M'*K'*N'`` under eq. 15-16, throughput, bandwidth and RAM efficiency.

Everything here is validated against the paper's published rows in
:mod:`repro.core.paper_tables` (see ``tests/test_paper_model.py`` and
``benchmarks/table*``).

Calibrated constants (documented, derived from the paper's own measured
data — the paper measures these effects in hardware emulation/ModelSim and
attributes them to AIE memory-conflict stalls resp. control overhead):

* ``AIE_ARRAY_STALL``: per-placement-pattern array-level efficiency on top
  of the 95% single-kernel efficiency.  Calibrated on the two 300 MHz
  designs; reproduces all ten Table III throughputs within 0.9%.
* ``TB_DRAIN_FACTOR``: 0.995 cascade drain/control overhead; reproduces all
  ten Table IV throughputs within 0.3%.

Units note: the paper's printed "BW (GB/s)" columns are bytes/2**30 per
second.  ``*_bw_gibps`` functions return that printed unit; ``*_bw_bytes``
return SI bytes/s.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Tuple

from repro.core.hardware import (
    AIE_FREQ_HZ,
    AIE_KERNEL_EFFICIENCY,
    AIE_MACS_PER_CYCLE,
    STRATIX_NX2100,
    TB_CHAIN,
    TB_DOT,
    TB_LANES,
    TB_LOAD_CYCLES,
    VERSAL_VC1902,
    FPGADevice,
)

# ---------------------------------------------------------------------------
# Versal ACAP (SS IV-A)
# ---------------------------------------------------------------------------

BRAM_BITS = 36 * 1024            # 36Kb BRAM
URAM_BITS = 288 * 1024           # 288Kb URAM
M20K_BITS = 20 * 1024            # Stratix M20K
PLIO_BITS = 128                  # PLIO width (SS IV-A3)

# Array-level stall factors, calibrated once per placement pattern from the
# paper's 300 MHz designs (Table III rows 1 and 6).  The paper attributes
# the gap to AIE memory-conflict stalls and the non-computing Add kernels
# (SS V-C3); MaxEVA measures it, we carry it as a constant.
AIE_ARRAY_STALL = {"P1": 0.81194, "P2": 0.83421}

# Table III implementation BRAM counts exceed the buffer model by 6-12
# blocks (FIFOs etc.); Table II (the model-estimate table) matches exactly.
BRAM_IMPL_OVERHEAD_TOL = 12


@dataclasses.dataclass(frozen=True)
class AIESolution:
    """A MaxEVA AIE-array solution (X,Y,Z placement, M,K,N kernel)."""

    pattern: str
    x: int
    y: int
    z: int
    m: int = 32
    k: int = 128
    n: int = 32

    @property
    def matmul_cores(self) -> int:
        return self.x * self.y * self.z

    @property
    def add_cores(self) -> int:
        # One AIE core runs each group's (Y-1)-kernel adder tree (SS IV-A2).
        return self.x * self.z if self.y > 1 else 0

    @property
    def aie_cores(self) -> int:
        return self.matmul_cores + self.add_cores

    @property
    def compute_gemm(self) -> Tuple[int, int, int]:
        return (self.x * self.m, self.y * self.k, self.z * self.n)

    def native_buffer(self, u: int, v: int, w: int) -> Tuple[int, int, int]:
        cm, ck, cn = self.compute_gemm
        return (u * cm, v * ck, w * cn)


MAXEVA_P1 = AIESolution("P1", 13, 4, 6)      # highest-throughput solution
MAXEVA_P2 = AIESolution("P2", 10, 3, 10)     # highest-efficiency solution


@dataclasses.dataclass(frozen=True)
class BufferGeometry:
    """Partition factors and depths of the PL buffers (eq. 1-3)."""

    a_part: int
    a_depth: int
    b_part: int
    b_depth: int
    c_part: int
    c_depth: int

    def parts(self) -> Tuple[int, int, int]:
        return (self.a_part, self.b_part, self.c_part)

    def depths(self) -> Tuple[int, int, int]:
        return (self.a_depth, self.b_depth, self.c_depth)


def versal_buffer_geometry(sol: AIESolution, u: int, v: int, w: int
                           ) -> BufferGeometry:
    """Eq. 1-3: partition factor x2 for double buffering; depth /16 (A,B:
    16 int8 lanes per 128-bit beat) resp. /4 (C: 4 int32 per beat)."""
    return BufferGeometry(
        a_part=2 * sol.x * sol.y,
        a_depth=u * v * sol.m * sol.k // 16,
        b_part=2 * sol.y * sol.z,
        b_depth=v * w * sol.k * sol.n // 16,
        c_part=2 * sol.x * sol.z,
        c_depth=u * w * sol.m * sol.n // 4,
    )


def f_bram(depth: int) -> Optional[float]:
    """Eq. 4: 36K-BRAM blocks needed for one 128-bit-wide partition."""
    if depth <= 512:
        return 2.0
    if depth <= 1024:
        return 4.0
    if depth <= 2048:
        return 7.5          # 2Kx18 + the 2Kx2-on-1Kx18 packing trick
    if depth <= 4096:
        return 15.0
    return None


def f_uram(depth: int) -> Optional[float]:
    """Eq. 5: URAMs (4Kx72) needed for one 128-bit-wide partition."""
    return 2.0 if depth <= 4096 else None


MAX_DEPTH = 4096   # eq. 6


def _block_count(kind: str, depth: int) -> Optional[float]:
    return f_bram(depth) if kind == "B" else f_uram(depth)


def versal_mapping_cost(geom: BufferGeometry, mapping: Tuple[str, str, str]
                        ) -> Optional[Tuple[float, float]]:
    """(BRAMs, URAMs) used by a {A,B,C}->{B,U} mapping, or None if a depth
    is unsupported by the assigned resource."""
    brams = urams = 0.0
    for kind, part, depth in zip(mapping, geom.parts(), geom.depths()):
        f = _block_count(kind, depth)
        if f is None:
            return None
        if kind == "B":
            brams += part * f
        else:
            urams += part * f
    return brams, urams


def versal_best_mapping(geom: BufferGeometry,
                        device: FPGADevice = VERSAL_VC1902
                        ) -> Optional[Tuple[Tuple[str, str, str], float, float]]:
    """Search all 8 mapping permutations (eq. 7-8 'for all permutations');
    return the feasible one using the fewest blocks (ties: fewest URAMs)."""
    best = None
    for mapping in itertools.product("BU", repeat=3):
        cost = versal_mapping_cost(geom, mapping)  # type: ignore[arg-type]
        if cost is None:
            continue
        brams, urams = cost
        if brams > device.bram_36k or urams > device.uram_288k:
            continue
        key = (brams + urams, urams)
        if best is None or key < best[0]:
            best = (key, mapping, brams, urams)
    if best is None:
        return None
    return tuple(best[1]), best[2], best[3]  # type: ignore[return-value]


def versal_hls_auto_mapping(geom: BufferGeometry,
                            device: FPGADevice = VERSAL_VC1902
                            ) -> Tuple[Tuple[str, str, str], float, float, bool]:
    """The HLS-AUTO behaviour reverse-engineered from Table II: buffers with
    depth > 1024 go to URAM, others to BRAM.  Returns (mapping, brams,
    urams, fails) where *fails* flags over-capacity (the paper's PnR
    failure on 5/10 designs)."""
    mapping = tuple("U" if d > 1024 else "B" for d in geom.depths())
    cost = versal_mapping_cost(geom, mapping)
    assert cost is not None
    brams, urams = cost
    fails = brams > device.bram_36k or urams > device.uram_288k
    return mapping, brams, urams, fails  # type: ignore[return-value]


def versal_raw_aie_ops(sol: AIESolution) -> float:
    """Peak int8 ops/s of the MatMul cores at 95% kernel efficiency."""
    per_core = 2 * AIE_MACS_PER_CYCLE * AIE_FREQ_HZ   # 256 ops/cycle
    return sol.matmul_cores * per_core * AIE_KERNEL_EFFICIENCY


def versal_pl_stream_ops(sol: AIESolution, pl_freq_hz: float) -> float:
    """PL-side streaming bound: each PLIO port needs max(M*K/16, K*N/16,
    M*N/4) beats per compute-GEMM iteration (SS IV-A3 rate matching)."""
    beats = max(sol.m * sol.k // 16, sol.k * sol.n // 16, sol.m * sol.n // 4)
    cm, ck, cn = sol.compute_gemm
    ops_per_iter = 2.0 * cm * ck * cn
    return ops_per_iter * pl_freq_hz / beats


def versal_throughput_ops(sol: AIESolution, pl_freq_hz: float) -> float:
    """min(AIE-bound, PL-streaming-bound); reproduces Table III and the
    Fig. 7a frequency sweep (flat >=250 MHz, ~16% drop at 200 MHz)."""
    aie = versal_raw_aie_ops(sol) * AIE_ARRAY_STALL[sol.pattern]
    return min(aie, versal_pl_stream_ops(sol, pl_freq_hz))


def versal_bw_bytes(sol: AIESolution, u: int, v: int, w: int,
                    throughput_ops: float) -> float:
    """Worst-case DDR bytes/s: concurrent A+B loads and C store (all int8,
    'due to quantization in DL') per native-buffer GEMM."""
    nm, nk, nn = sol.native_buffer(u, v, w)
    bytes_per_native = nm * nk + nk * nn + nm * nn
    t_native = 2.0 * nm * nk * nn / throughput_ops
    return bytes_per_native / t_native


def bytes_to_gibps(bw_bytes: float) -> float:
    return bw_bytes / 2**30


def versal_ram_efficiency(geom: BufferGeometry,
                          mapping: Tuple[str, str, str]) -> float:
    """Logical bits / physical bits of all blocks used (SS IV-A4)."""
    logical = physical = 0.0
    for kind, part, depth in zip(mapping, geom.parts(), geom.depths()):
        f = _block_count(kind, depth)
        assert f is not None
        logical += part * depth * PLIO_BITS
        physical += part * f * (BRAM_BITS if kind == "B" else URAM_BITS)
    return logical / physical


@dataclasses.dataclass(frozen=True)
class VersalDesign:
    """One evaluated point of the Versal U,V,W DSE."""

    sol: AIESolution
    u: int
    v: int
    w: int
    mapping: Tuple[str, str, str]
    brams: float
    urams: float
    reuse: int                       # U*V*W — the DSE objective
    native_buffer: Tuple[int, int, int]
    ram_eff: float

    def throughput_ops(self, pl_freq_hz: float) -> float:
        return versal_throughput_ops(self.sol, pl_freq_hz)

    def bw_gibps(self, pl_freq_hz: float) -> float:
        thr = self.throughput_ops(pl_freq_hz)
        return bytes_to_gibps(versal_bw_bytes(self.sol, self.u, self.v,
                                              self.w, thr))


def versal_dse(sol: AIESolution, device: FPGADevice = VERSAL_VC1902,
               max_param: int = 16) -> List[VersalDesign]:
    """Exhaustive IP solve (SS IV-A4): maximize reuse U*V*W subject to
    eq. 6 (depth <= 4K) and eq. 7-8 (capacity under the best feasible
    mapping).  Returns designs sorted by (reuse desc, BW asc)."""
    designs: List[VersalDesign] = []
    for u, v, w in itertools.product(range(1, max_param + 1), repeat=3):
        geom = versal_buffer_geometry(sol, u, v, w)
        if max(geom.depths()) > MAX_DEPTH:
            continue
        found = versal_best_mapping(geom, device)
        if found is None:
            continue
        mapping, brams, urams = found
        designs.append(VersalDesign(
            sol=sol, u=u, v=v, w=w, mapping=mapping, brams=brams,
            urams=urams, reuse=u * v * w,
            native_buffer=sol.native_buffer(u, v, w),
            ram_eff=versal_ram_efficiency(geom, mapping)))
    # Rank: maximize reuse; tie-break on lower worst-case bandwidth (the
    # paper's DDR-feasibility consideration), then larger native buffer.
    ref_freq = 300e6
    designs.sort(key=lambda d: (-d.reuse, d.bw_gibps(ref_freq)))
    return designs


# ---------------------------------------------------------------------------
# Stratix 10 NX (SS IV-B)
# ---------------------------------------------------------------------------

# Cascade drain / control overhead calibrated against Table IV (<=0.3% err).
TB_DRAIN_FACTOR = 0.995


@dataclasses.dataclass(frozen=True)
class TBLayout:
    """The four TB architecture parameters (SS IV-B1)."""

    tb_len: int
    kp: int
    np_: int
    mp: int

    def __post_init__(self):
        if TB_CHAIN % self.tb_len != 0:
            raise ValueError(
                f"TB_len={self.tb_len} must divide the chain length "
                f"{TB_CHAIN} (SS IV-B3a)")

    @property
    def tbs(self) -> int:
        return self.tb_len * self.kp * self.np_ * self.mp

    @property
    def useful_tbs(self) -> int:
        # First TB of each array is a loading port only.
        return (self.tb_len - 1) * self.kp * self.np_ * self.mp

    @property
    def compute_gemm(self) -> Tuple[int, int, int]:
        """(D_M', D_K', D_N') = (Mp*3, (TBlen-1)*Kp*10, Np)."""
        return (self.mp * TB_LANES,
                (self.tb_len - 1) * self.kp * TB_DOT,
                self.np_)

    @property
    def min_nprime(self) -> int:
        """Eq. 16: N' >= TBlen*3*Np hides the cascade loading latency."""
        return self.tb_len * TB_LOAD_CYCLES * self.np_


def f_m80(depth: int) -> int:
    """Eq. 12: M20Ks for an 80-bit-wide buffer partition."""
    return 2 * math.ceil(depth / 512)


def f_m32(depth: int) -> int:
    """Eq. 14: M20Ks for a 32-bit-wide C partition."""
    return math.ceil(depth / 512)


@dataclasses.dataclass(frozen=True)
class StratixGeometry:
    a_part: int
    a_depth: int
    b_part: int
    b_depth: int
    c_part: int
    c_depth: int

    @property
    def m20ks(self) -> int:
        return (self.a_part * f_m80(self.a_depth)
                + self.b_part * f_m80(self.b_depth)
                + self.c_part * f_m32(self.c_depth))


def stratix_geometry(lay: TBLayout, mprime: int, kprime: int, nprime: int
                     ) -> StratixGeometry:
    """Eq. 9-14 (x2 factors are double buffering; /10 converts bytes to
    80-bit words)."""
    b_part = (lay.tb_len - 1) * lay.kp * lay.np_
    a_part = lay.mp * lay.kp
    c_part = lay.mp * lay.np_ * TB_LANES * 2
    return StratixGeometry(
        a_part=a_part,
        a_depth=math.ceil(2 * mprime * kprime / (a_part * TB_DOT)),
        b_part=b_part,
        b_depth=math.ceil(2 * kprime * nprime / (b_part * TB_DOT)),
        c_part=c_part,
        c_depth=math.ceil(mprime * nprime * 2 / c_part),
    )


def stratix_throughput_ops(lay: TBLayout, freq_hz: float) -> float:
    """useful_TBs * 3 dot-10 engines * 20 ops/engine/cycle * f."""
    return lay.useful_tbs * TB_LANES * 2 * TB_DOT * freq_hz * TB_DRAIN_FACTOR


def stratix_bw_bytes(mprime: int, kprime: int, nprime: int,
                     throughput_ops: float) -> float:
    bytes_per_native = mprime * kprime + kprime * nprime + mprime * nprime
    t_native = 2.0 * mprime * kprime * nprime / throughput_ops
    return bytes_per_native / t_native


def stratix_ram_efficiency(geom: StratixGeometry,
                           m20ks: Optional[int] = None) -> float:
    """Logical bits (incl. double buffering, already inside the depths) over
    physical M20K bits.  ``m20ks`` overrides the eq. 12/14 model count with
    an implementation count (the paper's printed efficiencies use the
    implemented block count, which exceeds the model on 3/10 rows)."""
    logical = ((geom.a_part * geom.a_depth + geom.b_part * geom.b_depth) * 80
               + geom.c_part * geom.c_depth * 32)
    return logical / ((m20ks or geom.m20ks) * M20K_BITS)


@dataclasses.dataclass(frozen=True)
class StratixDesign:
    layout: TBLayout
    mprime: int
    kprime: int
    nprime: int
    geom: StratixGeometry
    reuse: int

    @property
    def native_buffer(self) -> Tuple[int, int, int]:
        return (self.mprime, self.kprime, self.nprime)

    def throughput_ops(self, freq_hz: float) -> float:
        return stratix_throughput_ops(self.layout, freq_hz)

    def bw_gibps(self, freq_hz: float) -> float:
        thr = self.throughput_ops(freq_hz)
        return bytes_to_gibps(
            stratix_bw_bytes(self.mprime, self.kprime, self.nprime, thr))


def stratix_ip_solve(lay: TBLayout, device: FPGADevice = STRATIX_NX2100
                     ) -> StratixDesign:
    """SS IV-B5: maximize M'*K'*N' subject to the M20K capacity (eq. 15)
    and latency-hiding (eq. 16) constraints; dims are multiples of the
    compute GEMM size.  Exhaustive over the multiple grid (the block-count
    functions are monotone in each dim, so each inner loop breaks at the
    first infeasible point)."""
    dm, dk, dn = lay.compute_gemm
    best: Optional[StratixDesign] = None
    l_min = max(1, math.ceil(lay.min_nprime / dn))

    def feasible(m: int, k: int, n: int) -> Optional[StratixGeometry]:
        geom = stratix_geometry(lay, m, k, n)
        return geom if geom.m20ks <= device.bram_36k else None

    j = 1
    while feasible(dm, j * dk, l_min * dn) is not None:
        kprime = j * dk
        i = 1
        while True:
            mprime = i * dm
            geom = feasible(mprime, kprime, l_min * dn)
            if geom is None:
                break
            l = l_min
            while True:
                nprime = l * dn
                g = feasible(mprime, kprime, nprime)
                if g is None:
                    break
                reuse = mprime * kprime * nprime
                if best is None or reuse > best.reuse:
                    best = StratixDesign(lay, mprime, kprime, nprime, g,
                                         reuse)
                l += 1
            i += 1
        j += 1
    if best is None:
        raise ValueError(f"no feasible native buffer for layout {lay}")
    return best


def stratix_check_design(lay: TBLayout, native: Tuple[int, int, int],
                         device: FPGADevice = STRATIX_NX2100
                         ) -> StratixGeometry:
    """Validate a (paper) native-buffer choice against eq. 15-16 and return
    its geometry (used to reproduce the Table IV M20K column).

    Note: two published rows (18x16x3x4 and 18x8x3x8) have native dims that
    are *not* multiples of the compute GEMM size; the paper zero-pads
    partial tiles (SS V-C2), so non-multiples are accepted here.
    """
    mprime, kprime, nprime = native
    if nprime < lay.min_nprime:
        raise ValueError(f"N'={nprime} < eq.16 minimum {lay.min_nprime}")
    geom = stratix_geometry(lay, mprime, kprime, nprime)
    if geom.m20ks > device.bram_36k:
        raise ValueError(f"{geom.m20ks} M20Ks exceed {device.bram_36k}")
    return geom


def stratix_dse(device: FPGADevice = STRATIX_NX2100,
                freq_model_hz: float = 340e6) -> List[StratixDesign]:
    """Enumerate TB layouts (TBlen a factor of 36, SS IV-B3a) that use most
    of the device's TBs, IP-solve each for its native buffer, and rank by
    modeled throughput (at a nominal frequency) then reuse."""
    designs: List[StratixDesign] = []
    for tb_len in (36, 18, 12, 9):
        for kp in (4, 8, 16):
            for np_ in range(2, 12):
                for mp in range(2, 12):
                    lay = TBLayout(tb_len, kp, np_, mp)
                    if not 0.75 * device.compute_units <= lay.tbs \
                            <= device.compute_units:
                        continue
                    try:
                        designs.append(stratix_ip_solve(lay, device))
                    except ValueError:
                        continue
    designs.sort(key=lambda d: (-d.throughput_ops(freq_model_hz), -d.reuse))
    return designs
