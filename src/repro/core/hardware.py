"""Hardware constant sheets.

Three devices appear in this framework:

* ``TPU_V5E`` — the *target* device for the adapted framework (kernels,
  sharding, roofline). Constants match the task sheet: 197 TFLOP/s bf16,
  819 GB/s HBM, ~50 GB/s per ICI link.
* ``VERSAL_VC1902`` and ``STRATIX_NX2100`` — the paper's devices (Table I),
  used by :mod:`repro.core.paper_model` to reproduce the paper's analytical
  results (Tables II–IV) faithfully.
"""

from __future__ import annotations

import dataclasses

KiB = 1024
MiB = 1024 * KiB
GiB = 1024 * MiB


@dataclasses.dataclass(frozen=True)
class TPUChip:
    """A TPU chip model used for roofline + DSE constraints."""

    name: str
    peak_bf16_flops: float          # FLOP/s
    peak_int8_ops: float            # OP/s (2x bf16 on v5e MXU)
    hbm_bytes: int                  # HBM capacity per chip
    hbm_bw: float                   # bytes/s
    vmem_bytes: int                 # VMEM scratchpad per core
    ici_link_bw: float              # bytes/s per link, per direction
    ici_links: int                  # torus links per chip
    dcn_bw: float                   # bytes/s per chip for pod-to-pod traffic
    mxu_dim: int = 128              # systolic array edge
    sublanes: int = 8               # fp32 sublane count; bf16=16, int8=32
    lane: int = 128

    def sublane(self, dtype_bytes: int) -> int:
        """Minimum tile in the second-to-last dim for a dtype."""
        return self.sublanes * max(1, 4 // dtype_bytes)

    @property
    def peak_flops(self) -> float:
        return self.peak_bf16_flops


TPU_V5E = TPUChip(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    peak_int8_ops=394e12,
    hbm_bytes=16 * GiB,
    hbm_bw=819e9,
    vmem_bytes=128 * MiB,
    ici_link_bw=50e9,
    ici_links=4,            # 2D torus on v5e: 4 links
    dcn_bw=25e9,            # conservative per-chip share of pod-to-pod DCN
)


@dataclasses.dataclass(frozen=True)
class FPGADevice:
    """Paper Table I rows (only the fields the analytical models consume)."""

    name: str
    bram_36k: int            # Versal: 36Kb BRAM count; Stratix: M20K count
    uram_288k: int           # Versal only (0 for Stratix)
    onchip_mem_bytes: float
    peak_tops_int8: float
    peak_dram_bw: float      # bytes/s
    peak_power_w: float
    compute_units: int       # AIE cores (Versal) / Tensor Blocks (Stratix)


# Versal VC1902: 967 36Kb BRAMs + 463 URAMs (AM007); paper quotes utilization
# percentages that imply B36K=967 and U288K=463: e.g. Table II: 780/81%≈963,
# 408/88%≈464, 912/94%≈970, 400/86%≈465 -> (967, 463) matches all rows.
VERSAL_VC1902 = FPGADevice(
    name="versal_vc1902",
    bram_36k=967,
    uram_288k=463,
    onchip_mem_bytes=20.5e6 + 12.5e6,     # PL + AIE memory (Table I)
    peak_tops_int8=135e12,
    peak_dram_bw=102.4e9,
    peak_power_w=165.0,
    compute_units=400,                    # AIE cores
)

# Stratix 10 NX 2100: 6847 M20Ks (paper percentages: 6304/92%≈6852,
# 5840/85%≈6871, 6464/94%≈6877 -> 6847 is the published device count).
STRATIX_NX2100 = FPGADevice(
    name="stratix_nx2100",
    bram_36k=6847,                        # M20K blocks
    uram_288k=0,
    onchip_mem_bytes=16.75e6,
    peak_tops_int8=143e12,
    peak_dram_bw=512e9,
    peak_power_w=125.0,
    compute_units=3960,                   # Tensor Blocks
)


# Versal AIE single-kernel shape used by all MaxEVA solutions in the paper.
AIE_KERNEL_M, AIE_KERNEL_K, AIE_KERNEL_N = 32, 128, 32
AIE_FREQ_HZ = 1.25e9
AIE_KERNEL_EFFICIENCY = 0.95              # paper §V-A: 95% MatMul efficiency
AIE_MACS_PER_CYCLE = 128                  # int8 MACs/cycle/core (128 ops=2*128)

# Stratix TB constants (paper §III-B).
TB_CHAIN = 36                             # TBs per physical chain
TB_DOT = 10                               # dot-product width
TB_LANES = 3                              # parallel dot engines / TB
TB_LOAD_CYCLES = 3                        # cascade loading cycles per TB
TB_CASCADE_CYCLES = 2                     # dot+cascade latency per TB
