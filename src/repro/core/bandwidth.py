"""HBM traffic model per tiling — the Table III/IV 'BW' column analogue.

The paper computes, per design, the worst-case off-chip bytes needed to
sustain the accelerator's native throughput and *gates* the DSE on the
device's DRAM bandwidth.  Here the 'off-chip' level is HBM and the gate is
the roofline: a tiling whose HBM traffic pushes the memory term above the
compute term is memory-bound and ranked accordingly.

The roofline rates default to the chip's datasheet constants, but a
measured :class:`Calibration` (fitted by :mod:`repro.tune.calibrate`
from the tuning cache's samples) can override them process-wide via
:func:`set_calibration` — then every ``estimate()`` (and through it the
DSE ranking and ``roofline.analyze``) prices designs at the *effective*
rates this host actually achieves.  ``calibration_version()`` increments
on every change so downstream caches (``dse._solve_cached``) key on it
instead of serving pre-calibration answers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.hardware import TPU_V5E, TPUChip
from repro.core.tiling import (
    GemmProblem,
    TileConfig,
    dtype_bytes,
    grouped_instances,
)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured effective rates overriding a chip's datasheet constants
    (``None`` fields keep the chip value)."""

    hbm_bw: Optional[float] = None           # bytes/s
    peak_bf16_flops: Optional[float] = None  # flop/s
    peak_int8_ops: Optional[float] = None    # op/s
    source: str = ""


_calibration: Optional[Calibration] = None
_cal_version: int = 0


def set_calibration(cal: Optional[Calibration]) -> None:
    """Install (or, with ``None``, drop) measured effective constants.
    Explicit opt-in only — callers that cache anything priced by
    ``estimate()`` must key on :func:`calibration_version`."""
    global _calibration, _cal_version
    _calibration = cal
    _cal_version += 1


def clear_calibration() -> None:
    set_calibration(None)


def get_calibration() -> Optional[Calibration]:
    return _calibration


def calibration_version() -> int:
    return _cal_version


def effective_rates(chip: TPUChip, int8: bool) -> tuple:
    """(peak flop/s, HBM bytes/s) after any installed calibration."""
    peak = chip.peak_int8_ops if int8 else chip.peak_bf16_flops
    bw = chip.hbm_bw
    cal = _calibration
    if cal is not None:
        over = cal.peak_int8_ops if int8 else cal.peak_bf16_flops
        peak = over or peak
        bw = cal.hbm_bw or bw
    return peak, bw


@dataclasses.dataclass(frozen=True)
class TrafficEstimate:
    """Modeled HBM traffic and roofline terms for one (tile, problem)."""

    hbm_bytes: float          # total HBM bytes moved
    flops: float              # padded (executed) flops
    t_compute: float          # s
    t_memory: float           # s
    arithmetic_intensity: float

    @property
    def t_model(self) -> float:
        """Roofline execution-time estimate (perfect overlap)."""
        return max(self.t_compute, self.t_memory)

    @property
    def bound(self) -> str:
        return "compute" if self.t_compute >= self.t_memory else "memory"


def hbm_traffic_bytes(tile: TileConfig, p: GemmProblem) -> float:
    """Worst-case HBM bytes for one GEMM under a tiling.

    * ``aie`` (output-stationary, grid m,n,k): every A panel is re-read
      once per n-block column, every B panel once per m-block row, C is
      written once.  (A reused gn times from VMEM's perspective — the
      paper's 'A reused W times'.)
    * ``tb`` (A-stationary, grid m,k,n): A is read once; B re-read per
      m-block row; C is read+written once per k step (PL-accumulator
      pattern).

    Operands are billed at their *own* dtype widths (A at a-bytes, B at
    b-bytes — the per-operand-precision accounting the Versal follow-up
    uses for its energy model), and quantized int8 operands additionally
    move their fp32 scale vectors: a (1, n) per-output-channel vector
    rides with every B panel read, a (m, 1) per-row vector with every A
    panel read.

    Fused extensions: the dual-B gated kernel (``p.n_b_operands == 2``)
    bills *both* B streams (and both scale vectors) while A still moves
    once per n-column — this is exactly the traffic credit of fusing
    SwiGLU's gate/up GEMMs: one A stream instead of two, and zero HBM
    bytes for the (m, n) gate/up intermediates the unfused composition
    writes and re-reads.  A fused epilogue bills its own operands: the
    (1, n) f32 bias vector rides with every m-row of B panels, the
    (m, n) residual is read once.

    Grouped ragged GEMMs (``p.n_groups > 0``, output-stationary only):
    A is charged at the *true* routed rows — ``p.m`` is sum(group_sizes),
    not the dense E*capacity — with each of the worst-case
    ``gm + E - 1`` straddling tile instances re-reading its (bm, pk)
    A rows once per n-block column.  B is charged one (pk, pn) expert
    panel per *instance* (an expert active over several m-tiles streams
    its panel once per tile it owns — never the full (E, k, n) bank),
    the per-expert (1, n) dequant-scale/bias vectors ride per instance,
    and C is written once per unique output tile.  Inactive experts
    (empty groups) cost nothing; the model's static worst case assumes
    all E groups are live.
    """
    from repro.kernels.epilogue import Epilogue
    ep = Epilogue.parse(p.epilogue)
    gm, gn, gk = tile.grid(p)
    pm_, pk, pn = tile.padded_dims(p)
    a_b = dtype_bytes(p.a_dtype)
    b_b = dtype_bytes(p.b_dtype)
    out_b = dtype_bytes(p.out_dtype)
    acc_b = dtype_bytes(p.acc_dtype)
    a_bytes = pm_ * pk * a_b
    b_bytes = pk * pn * b_b * p.n_b_operands
    c_bytes = pm_ * pn * out_b
    a_scale = pm_ * 4 if p.a_dtype == "int8" else 0
    b_scale = pn * 4 * p.n_b_operands if p.b_dtype == "int8" else 0
    bias_bytes = pn * 4 * gm if ep.bias else 0
    res_bytes = pm_ * pn * out_b if ep.residual else 0
    if p.n_groups:
        inst = grouped_instances(tile, p)
        a_inst = inst * tile.bm * pk * a_b
        a_s_inst = inst * tile.bm * 4 if p.a_dtype == "int8" else 0
        b_inst = inst * pk * pn * b_b
        b_s_inst = inst * pn * 4 if p.b_dtype == "int8" else 0
        bias_inst = inst * pn * 4 if ep.bias else 0
        return ((a_inst + a_s_inst) * gn + b_inst + b_s_inst
                + c_bytes + bias_inst)
    if tile.strategy == "aie":
        return ((a_bytes + a_scale) * gn + (b_bytes + b_scale) * gm
                + c_bytes + bias_bytes + res_bytes)
    # 'tb'
    c_rmw = pm_ * pn * acc_b
    return (a_bytes + a_scale) + (b_bytes + b_scale) * gm \
        + c_rmw * (2 * gk - 1) + c_bytes + bias_bytes + res_bytes


def decode_kv_bytes(positions, *, n_kv_heads: int, head_dim: int,
                    dtype="bfloat16", window: int = 0,
                    page_size: Optional[int] = None) -> int:
    """Modeled HBM bytes ONE attention layer streams from its KV cache
    for one decode step, billed at *true per-row positions* — not the
    dense ``max_len`` rows the pre-paged cache allocated.

    A row at position ``p`` reads its ``p + 1``-entry causal history (k
    and v each, at storage dtype); a sliding window clamps that to the
    last ``window`` entries.  A block-paged cache bills whole pages
    ``[0, ceil((p + 1) / page_size))`` and IGNORES the window: the
    paged kernel has no ring buffer — windowed layers page at full
    length and mask in-VMEM, so every history page moves regardless of
    the window span.  ``positions``: iterable of per-row cache
    positions (the engine's live slots).
    """
    per_tok = 2 * n_kv_heads * head_dim * dtype_bytes(dtype)
    tokens = 0
    for p in positions:
        hi = int(p) + 1                      # rows [0, hi) are live
        if page_size:
            tokens += -(-hi // page_size) * page_size
        else:
            lo = max(0, hi - window) if window > 0 else 0
            tokens += hi - lo
    return tokens * per_tok


def estimate(tile: TileConfig, p: GemmProblem, chip: TPUChip = TPU_V5E
             ) -> TrafficEstimate:
    pm_, pk, pn = tile.padded_dims(p)
    flops = 2.0 * pm_ * pk * pn * p.n_b_operands
    if p.n_groups:
        # executed flops: every straddling instance recomputes its full
        # (bm, pk, pn) block — the DSE's pressure toward small bm
        flops = 2.0 * grouped_instances(tile, p) * tile.bm * pk * pn
    # int8 MXU rate needs *both* operands at 8 bits; W8A16 dequantizes
    # in-register and multiplies at the bf16 rate.
    int8 = dtype_bytes(p.a_dtype) == 1 and dtype_bytes(p.b_dtype) == 1
    peak, hbm_bw = effective_rates(chip, int8)
    hbm = hbm_traffic_bytes(tile, p)
    return TrafficEstimate(
        hbm_bytes=hbm,
        flops=flops,
        t_compute=flops / peak,
        t_memory=hbm / hbm_bw,
        arithmetic_intensity=flops / hbm,
    )
