"""Loop-corrected cost accounting over compiled (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, regardless of trip count (verified empirically — a 5-iteration
scan of a matmul reports 1 matmul of FLOPs).  Every model in this
framework lowers scan-over-layers (plus xent-chunk maps, microbatch
scans, blocked-attention loops), so the raw numbers under-count by the
product of the enclosing trip counts.  This module re-derives the three
roofline inputs from the HLO text with per-computation *loop
multipliers*:

* **flops** — 2·numel(out)·prod(contracting dims) per ``dot`` (plus a
  kernel-numel estimate per ``convolution``; dots dominate ≥95% in these
  models), counted inside fusions too, scaled by the multiplier of the
  computation they live in.
* **bytes** — per-instruction boundary traffic (operands + result) for
  instructions in *non-fusion* computations (fusion internals are
  on-chip by construction; XLA's own bytes-accessed uses the same
  boundary convention), scaled by multipliers.  View-only ops
  (bitcast/tuple/gte/parameter/constant) are free.
* **collectives** — operand bytes per collective type (the §Roofline
  numerator), scaled by multipliers.

Trip counts are recovered from each while's condition computation (the
largest s32/u32 constant — scan/fori conditions compare the induction
variable against the trip count).  The parser is validated against
``cost_analysis()`` on fully-unrolled modules, where XLA's numbers are
exact (tests/test_hlo_cost.py).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# one result/operand type like  f32[3,256,256]{2,1,0:T(8,128)}  or  s32[]
_TYPE_RE = re.compile(r"(\w+)\[([0-9,]*)\](?:\{[^}]*\})?")
# an instruction definition:  %name = <type-or-tuple> opcode(...)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}:()#*]+?)\s+"
    r"([\w\-]+)\(")
# computation header:  %name (args) -> type {   /   ENTRY %name ...
# (args may contain '=' inside /*index=N*/ comments — only match the name)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_ATTR_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_ATTR_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_ATTR_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT_RE = re.compile(r"[su]32\[\]\s+constant\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

# ops whose "execution" moves no bytes (views / bookkeeping)
_FREE_OPS = frozenset((
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "domain",
    "get-dimension-size",
))


def _shape_numel_bytes(type_str: str) -> Tuple[int, int]:
    """(numel, bytes) of an HLO type string; tuples summed."""
    numel_total, bytes_total = 0, 0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        numel_total += numel
        bytes_total += numel * _DTYPE_BYTES[dtype]
    return numel_total, bytes_total


def _shape_dims(type_str: str) -> Optional[List[int]]:
    """Dims of a single (non-tuple) array type, else None."""
    m = _TYPE_RE.search(type_str)
    if not m or type_str.lstrip().startswith("("):
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str
    comp: str


@dataclasses.dataclass
class Module:
    computations: Dict[str, List[Instruction]]
    entry: str
    by_name: Dict[str, Instruction]


def parse(text: str) -> Module:
    comps: Dict[str, List[Instruction]] = {}
    by_name: Dict[str, Instruction] = {}
    entry = ""
    current = ""
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("#"):
            continue
        # computation headers start at column 0 and end with '{';
        # instructions are indented (param lists may contain '=' inside
        # /*index=N*/ comments, so header detection must not test that)
        if (not line.startswith(" ") and s.endswith("{")
                and ("->" in s or s.startswith("ENTRY"))):
            m = _COMP_RE.match(s)
            if m:
                current = m.group(1)
                comps[current] = []
                if s.startswith("ENTRY"):
                    entry = current
                continue
        m = _INSTR_RE.match(line)
        if m is None or not current:
            continue
        name, type_str, opcode = m.group(1), m.group(2), m.group(3)
        # operand names: inside the first (...) after the opcode
        rest = line[m.end():]
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operands = _OPERAND_RE.findall(rest[:i])
        instr = Instruction(name=name, type_str=type_str, opcode=opcode,
                            operands=operands, line=line, comp=current)
        comps[current].append(instr)
        by_name[name] = instr
    if not entry and comps:
        entry = next(iter(comps))
    return Module(computations=comps, entry=entry, by_name=by_name)


def _trip_count(mod: Module, cond_name: str) -> int:
    """Largest integer constant in a while condition (scan/fori compare
    the induction variable against the trip count).  Falls back to 1."""
    best = 1
    seen = set()
    stack = [cond_name]
    while stack:
        cname = stack.pop()
        if cname in seen or cname not in mod.computations:
            continue
        seen.add(cname)
        for ins in mod.computations[cname]:
            for v in _CONST_INT_RE.findall(ins.line):
                best = max(best, int(v))
            m = _ATTR_CALLS_RE.search(ins.line)
            if m:
                stack.append(m.group(1))
    return best


def _while_trips(mod: Module, ins: Instruction) -> int:
    """Trip count of a while op: XLA's known_trip_count backend_config
    when present, else the condition-constant heuristic."""
    m = _TRIP_CFG_RE.search(ins.line)
    if m:
        return int(m.group(1))
    cond = _ATTR_COND_RE.search(ins.line)
    return _trip_count(mod, cond.group(1)) if cond else 1


def multipliers(mod: Module) -> Dict[str, float]:
    """Execution-count multiplier per computation (ENTRY = 1; while
    bodies multiply by their trip count; fusions/calls inherit)."""
    mult: Dict[str, float] = {c: 0.0 for c in mod.computations}
    if mod.entry not in mult:
        return mult
    mult[mod.entry] = 1.0
    # propagate in topological-ish passes (call graphs here are shallow;
    # iterate until fixed point with a bound)
    for _ in range(64):
        changed = False
        for cname, instrs in mod.computations.items():
            base = mult.get(cname, 0.0)
            if base == 0.0:
                continue
            for ins in instrs:
                targets: List[Tuple[str, float]] = []
                if ins.opcode == "while":
                    body = _ATTR_BODY_RE.search(ins.line)
                    cond = _ATTR_COND_RE.search(ins.line)
                    if body and cond:
                        trips = _while_trips(mod, ins)
                        targets.append((body.group(1), base * trips))
                        targets.append((cond.group(1), base * (trips + 1)))
                elif ins.opcode == "conditional":
                    mb = _BRANCHES_RE.search(ins.line)
                    if mb:
                        for b in mb.group(1).split(","):
                            targets.append((b.strip().lstrip("%"), base))
                else:
                    m = _ATTR_CALLS_RE.search(ins.line)
                    if m is None and ins.opcode == "call":
                        # some XLA versions wrap parallel fusions in
                        # call(...) to_apply=%fusion_comp
                        m = _ATTR_TO_APPLY_RE.search(ins.line)
                    if m:
                        targets.append((m.group(1), base))
                for tname, tmult in targets:
                    if tname in mult and tmult > mult[tname]:
                        mult[tname] = tmult
                        changed = True
        if not changed:
            break
    return mult


def _control_comps(mod: Module) -> set:
    """Computations reachable from ENTRY without passing through a fusion
    — the ones whose instruction boundaries correspond to real memory
    traffic (fusion internals stay on-chip)."""
    ok = {mod.entry}
    changed = True
    while changed:
        changed = False
        for cname in list(ok):
            for ins in mod.computations.get(cname, ()):
                tgts: List[str] = []
                if ins.opcode == "while":
                    for pat in (_ATTR_BODY_RE, _ATTR_COND_RE):
                        g = pat.search(ins.line)
                        if g:
                            tgts.append(g.group(1))
                elif ins.opcode == "conditional":
                    mb = _BRANCHES_RE.search(ins.line)
                    if mb:
                        tgts += [b.strip().lstrip("%")
                                 for b in mb.group(1).split(",")]
                elif ins.opcode == "call":
                    g = _ATTR_CALLS_RE.search(ins.line) \
                        or _ATTR_TO_APPLY_RE.search(ins.line)
                    if g:
                        tgts.append(g.group(1))
                # fusion targets intentionally not walked
                for t in tgts:
                    if t in mod.computations and t not in ok:
                        ok.add(t)
                        changed = True
    return ok


def _dot_flops(mod: Module, ins: Instruction) -> float:
    out_numel, _ = _shape_numel_bytes(ins.type_str)
    contract = 1
    m = _CONTRACT_RE.search(ins.line)
    if m and ins.operands:
        lhs = mod.by_name.get(ins.operands[0])
        lhs_dims = _shape_dims(lhs.type_str) if lhs else None
        if lhs_dims is not None and m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    contract *= lhs_dims[di]
    return 2.0 * out_numel * contract


def _conv_flops(mod: Module, ins: Instruction) -> float:
    """Rough conv estimate: 2·numel(out)·(kernel numel / out channels).
    Convs here are tiny causal depthwise frontends — noise vs the dots."""
    out_numel, _ = _shape_numel_bytes(ins.type_str)
    if len(ins.operands) >= 2:
        ker = mod.by_name.get(ins.operands[1])
        if ker is not None:
            k_numel, _ = _shape_numel_bytes(ker.type_str)
            dims = _shape_dims(ker.type_str) or [1]
            return 2.0 * out_numel * max(1, k_numel // max(dims[-1], 1))
    return 2.0 * out_numel


def _fusion_bytes(mod: Module, ins: Instruction) -> float:
    """Boundary bytes of a fusion, slice-aware.

    A kLoop fusion that dynamic-slices a stacked buffer (layer-scan
    weight reads) or dynamic-update-slices a carried buffer (KV-cache
    writes, scan output stores) only moves the *slice*, not the whole
    operand — charging the full buffer per loop iteration over-counts by
    the trip count.  Mirrors XLA's in-place fusion handling.

    TPU-target note: chains are followed through ``convert`` as well.
    XLA:CPU legalizes bf16 dots by inserting f32<->bf16 converts around
    loop-carried buffers (measured: a convert-rooted DUS fusion rewrites
    the full 95-layer KV-cache stack every decode layer because the
    convert blocks in-place aliasing).  On the TPU target bf16 dots are
    native and those converts do not exist, so the slice-aware charge is
    the faithful traffic model for §Roofline.
    """
    m = _ATTR_CALLS_RE.search(ins.line)
    called = mod.computations.get(m.group(1)) if m else None
    _, out_b = _shape_numel_bytes(ins.type_str)
    in_bytes: List[float] = []
    for oname in ins.operands:
        src = mod.by_name.get(oname)
        in_bytes.append(_shape_numel_bytes(src.type_str)[1]
                        if src is not None else 0)
    if called is None:
        return out_b + sum(in_bytes)

    # map fused-computation values back to parameter indices through
    # bitcast/reshape/copy chains
    param_of: Dict[str, int] = {}
    for fins in called:
        if fins.opcode == "parameter":
            mm = re.search(r"parameter\((\d+)\)", fins.line)
            if mm:
                param_of[fins.name] = int(mm.group(1))
        elif fins.opcode in ("bitcast", "reshape", "copy", "convert") \
                and fins.operands and fins.operands[0] in param_of:
            param_of[fins.name] = param_of[fins.operands[0]]

    sliced: Dict[int, float] = {}      # param idx -> slice bytes charged
    root_updates: Optional[float] = None
    root_name = called[-1].name if called else None
    for fins in called:
        if fins.line.lstrip().startswith("ROOT"):
            root_name = fins.name
    # find the root through bitcast chains
    root_src = {f.name: f for f in called}

    for fins in called:
        if fins.opcode == "dynamic-slice" and fins.operands:
            pi = param_of.get(fins.operands[0])
            if pi is not None:
                _, b = _shape_numel_bytes(fins.type_str)
                sliced[pi] = max(sliced.get(pi, 0.0), float(b))
        elif fins.opcode == "dynamic-update-slice" \
                and len(fins.operands) >= 2:
            pi = param_of.get(fins.operands[0])
            upd = root_src.get(fins.operands[1])
            ub = _shape_numel_bytes(upd.type_str)[1] if upd else 0
            if pi is not None:
                sliced[pi] = max(sliced.get(pi, 0.0), float(ub))
            # if the DUS (via bitcasts) is the fusion root, the output
            # write is also only the update slice
            name = root_name
            seen = set()
            while name in root_src and name not in seen:
                seen.add(name)
                r = root_src[name]
                if r.name == fins.name:
                    root_updates = float(ub)
                    break
                if r.opcode in ("bitcast", "reshape", "copy",
                                "convert") and r.operands:
                    name = r.operands[0]
                else:
                    break

    total = float(root_updates if root_updates is not None else out_b)
    for i, b in enumerate(in_bytes):
        total += sliced.get(i, float(b))
    return total


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_JIT_SCOPE_RE = re.compile(r"jit\(([\w\-]+)\)")


def _scope(line: str) -> str:
    """Innermost named jit scope of an instruction (from metadata) —
    lets the perf pass substitute a Pallas kernel's analytic traffic for
    the XLA reference lowering of the same region."""
    m = _OPNAME_RE.search(line)
    if not m:
        return "<none>"
    scopes = _JIT_SCOPE_RE.findall(m.group(1))
    return scopes[-1] if scopes else "<none>"


@dataclasses.dataclass
class HloCost:
    """Loop-corrected totals (per device, post-SPMD module)."""

    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    raw_flops_once: float           # without multipliers (diagnostic)
    n_while: int
    trip_counts: Dict[str, int]
    bytes_by_scope: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    flops_by_scope: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_text(text: str) -> HloCost:
    mod = parse(text)
    mult = multipliers(mod)
    control = _control_comps(mod)
    flops = 0.0
    flops_once = 0.0
    bytes_accessed = 0.0
    coll = {op: 0.0 for op in COLLECTIVE_OPS}
    n_while = 0
    trips: Dict[str, int] = {}
    bytes_by_scope: Dict[str, float] = {}
    flops_by_scope: Dict[str, float] = {}

    for cname, instrs in mod.computations.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        fusion_internal = cname not in control
        for ins in instrs:
            op = ins.opcode
            if op == "dot":
                f = _dot_flops(mod, ins)
                flops += m * f
                flops_once += f
                sc = _scope(ins.line)
                flops_by_scope[sc] = flops_by_scope.get(sc, 0.0) + m * f
            elif op == "convolution":
                f = _conv_flops(mod, ins)
                flops += m * f
                flops_once += f
                sc = _scope(ins.line)
                flops_by_scope[sc] = flops_by_scope.get(sc, 0.0) + m * f
            if op == "while":
                n_while += 1
                trips[ins.name] = _while_trips(mod, ins)
            if fusion_internal:
                continue
            # ---- boundary bytes (non-fusion computations only)
            # 'call' is structural: its callee's instructions are walked
            # with the same multiplier (charging the call boundary too
            # would bill a call-wrapped slicing fusion at full-operand
            # size per loop iteration)
            if op in _FREE_OPS or op in ("while", "conditional", "call"):
                continue
            if op == "fusion":
                b = m * _fusion_bytes(mod, ins)
                bytes_accessed += b
                sc = _scope(ins.line)
                bytes_by_scope[sc] = bytes_by_scope.get(sc, 0.0) + b
            elif op == "dynamic-update-slice":
                # in-place: charge the update slice, not the buffer
                ub = 0
                if len(ins.operands) >= 2:
                    upd = mod.by_name.get(ins.operands[1])
                    if upd is not None:
                        ub = _shape_numel_bytes(upd.type_str)[1]
                bytes_accessed += m * 2.0 * ub
                sc = _scope(ins.line)
                bytes_by_scope[sc] = bytes_by_scope.get(sc, 0.0) \
                    + m * 2.0 * ub
            else:
                _, out_b = _shape_numel_bytes(ins.type_str)
                in_b = 0
                for oname in ins.operands:
                    src = mod.by_name.get(oname)
                    if src is not None:
                        _, b = _shape_numel_bytes(src.type_str)
                        in_b += b
                bytes_accessed += m * (out_b + in_b)
                sc = _scope(ins.line)
                bytes_by_scope[sc] = bytes_by_scope.get(sc, 0.0) \
                    + m * (out_b + in_b)
            # ---- collectives
            for cop in COLLECTIVE_OPS:
                if op == cop or op == cop + "-start":
                    nbytes = out_b
                    if op.endswith("-start"):
                        nbytes = out_b / 2.0      # (in, out) tuple result
                    if cop == "reduce-scatter":
                        nbytes *= _group_size(ins.line)
                    coll[cop] += m * nbytes
                    break

    return HloCost(flops=flops, bytes_accessed=bytes_accessed,
                   collective_bytes=coll, raw_flops_once=flops_once,
                   n_while=n_while, trip_counts=trips,
                   bytes_by_scope=bytes_by_scope,
                   flops_by_scope=flops_by_scope)


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def xla_cost(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions
    (older jax returns a per-computation list of dicts, newer a single
    dict); always a dict, empty when the backend reports nothing."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)
