"""Multi-level GEMM tiling on the TPU memory hierarchy.

This is the Fig. 3 analogue.  The paper tiles GEMM across four levels
(AIE kernel -> AIE array -> PL buffers -> DDR); on TPU the levels are:

    level 1  MXU micro-tile        128x128x128 systolic pass (hardware)
    level 2  Pallas VMEM block     (bm, bk, bn)   <- this module
    level 3  per-chip HBM shard    set by the sharding layout (dist level)
    level 4  mesh                  ('data','model'[, 'pod']) partitioning

The paper's *compute GEMM size* maps to the VMEM block (bm,bk,bn); the
*native buffer size* maps to the per-chip working set; U,V,W reuse maps
to the grid trip counts along each block dimension.

Two dataflow *strategies* mirror the paper's two devices (SS IV):

* ``aie``  — output-stationary: grid (m,n,k), k innermost, partial sums
  held in a VMEM accumulator, written once (Versal: adder-tree reduction
  next to the compute, C leaves the array once).
* ``tb``   — A-stationary: grid (m,k,n), n innermost, the A block stays
  resident in VMEM while the B stream passes through; C is
  read-modified-written per k step (Stratix: A blocks pinned in TB
  ping-pong registers, B broadcast, accumulation cascaded outward).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

from repro.core.hardware import TPU_V5E, TPUChip

STRATEGIES = ("aie", "tb")


def dtype_bytes(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def min_sublane(dtype, chip: TPUChip = TPU_V5E) -> int:
    """Minimum second-to-last-dim tile for a dtype (8 fp32 / 16 bf16 /
    32 int8)."""
    return chip.sublanes * max(1, 4 // dtype_bytes(dtype))


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class GemmProblem:
    """A logical (M, K, N) GEMM with *per-operand* dtypes.

    Mixed precision is first-class: ``a_dtype`` is the activation stream,
    ``b_dtype`` the weight stream (the paper's int8 operands — and the
    W8A16 GEMM batched decode wants — bill B at one byte/element while A
    stays bf16).  ``b_dtype=None`` means "same as A", which keeps every
    uniform-precision call site unchanged, and ``in_dtype`` survives as a
    read-only compat property.  Quantized int8 operands carry fp32 scale
    vectors (per-row for A, per-output-channel for B) that the traffic
    model bills alongside the operand.

    Fused-epilogue GEMMs carry two more dimensions the DSE must see:

    * ``epilogue`` — the canonical :class:`repro.kernels.epilogue.Epilogue`
      key string (e.g. ``"bias+silu+res"``; ``""`` = none).  Bias and
      residual operands take VMEM blocks and HBM reads of their own.
    * ``n_b_operands`` — 2 for the dual-B gated kernel
      (``act(A B_gate) * (A B_up)``): both B streams and both VMEM
      accumulators are billed, while A is billed once.

    Grouped ragged GEMMs (the MoE expert sweep) set ``n_groups`` to the
    expert count E: ``m`` is then the *true* total routed rows (not the
    dense E*capacity), B is an (E, k, n) bank of which each m-tile
    instance streams one expert's panels, and the billing models charge
    the up-to-``gm + E - 1`` tile instances the straddling sweep
    actually executes.  ``n_groups == 0`` is a plain dense GEMM.
    """

    m: int
    k: int
    n: int
    a_dtype: str = "bfloat16"
    out_dtype: str = "bfloat16"
    acc_dtype: str = "float32"
    b_dtype: Optional[str] = None
    epilogue: str = ""
    n_b_operands: int = 1
    n_groups: int = 0

    def __post_init__(self):
        if self.b_dtype is None:
            object.__setattr__(self, "b_dtype", self.a_dtype)
        assert self.n_b_operands in (1, 2), self.n_b_operands
        assert self.n_groups >= 0, self.n_groups
        if self.n_groups:
            assert self.n_b_operands == 1, "grouped GEMM is single-B"

    @property
    def in_dtype(self) -> str:
        """Compat alias for the pre-mixed-precision API (A's dtype)."""
        return self.a_dtype

    @property
    def mixed(self) -> bool:
        return self.a_dtype != self.b_dtype

    @property
    def flops(self) -> float:
        return 2.0 * self.m * self.k * self.n * self.n_b_operands

    @property
    def a_bytes(self) -> int:
        return self.m * self.k * dtype_bytes(self.a_dtype)

    @property
    def b_bytes(self) -> int:
        """Bytes of ONE B operand (the gated kernel's second stream is
        billed by the traffic/footprint models via ``n_b_operands``)."""
        return self.k * self.n * dtype_bytes(self.b_dtype)

    @property
    def in_bytes(self) -> int:
        return self.a_bytes + self.b_bytes * self.n_b_operands

    @property
    def out_bytes(self) -> int:
        return self.m * self.n * dtype_bytes(self.out_dtype)

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / (self.in_bytes + self.out_bytes)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """A level-2 (VMEM) tiling choice — the paper's (U,V,W)+mapping
    analogue for one GEMM."""

    bm: int
    bk: int
    bn: int
    strategy: str = "aie"

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")

    def grid(self, p: GemmProblem) -> Tuple[int, int, int]:
        """Trip counts (gm, gn, gk) — the U,W,V reuse analogue."""
        return (cdiv(p.m, self.bm), cdiv(p.n, self.bn), cdiv(p.k, self.bk))

    def padded_dims(self, p: GemmProblem) -> Tuple[int, int, int]:
        gm, gn, gk = self.grid(p)
        return (gm * self.bm, gk * self.bk, gn * self.bn)

    def tile_efficiency(self, p: GemmProblem) -> float:
        """Useful fraction of the padded compute — the paper's zero-padding
        scalability effect (Fig. 7b / 8)."""
        pm_, pk, pn = self.padded_dims(p)
        return (p.m * p.k * p.n) / (pm_ * pk * pn)

    def mxu_aligned(self, chip: TPUChip = TPU_V5E) -> bool:
        """MXU-friendly: lane dims multiples of 128, sublane dim aligned."""
        return (self.bn % chip.lane == 0 and self.bk % chip.lane == 0
                and self.bm % chip.sublanes == 0)


def grouped_instances(tile: TileConfig, p: GemmProblem) -> int:
    """Static worst-case m-tile instances of a grouped sweep: every
    m-tile once, plus one revisit per group boundary that can land
    mid-tile (``gm + E - 1``).  This is what the traffic model bills —
    the runtime instance count (``kernels.gemm_grouped.group_metadata``)
    is at most this."""
    gm, _, _ = tile.grid(p)
    return gm + max(p.n_groups - 1, 0)


def compute_gemm_size(tile: TileConfig) -> Tuple[int, int, int]:
    """The paper's 'compute GEMM size' — one block-level multiply."""
    return (tile.bm, tile.bk, tile.bn)


def native_working_set(tile: TileConfig, p: GemmProblem) -> Tuple[int, int, int]:
    """The paper's 'native buffer size' — dims resident per chip."""
    return tile.padded_dims(p)
