"""VMEM footprint model — the eq. 4-5 / eq. 12-14 analogue for TPU.

The paper predicts physical BRAM/URAM/M20K block usage from logical buffer
geometry and *rejects* tilings that over-subscribe the device (the failure
HLS-AUTO hits).  On TPU the physical resource is VMEM: every Pallas block
is padded to (sublane, lane) tiles, the software pipeline double-buffers
HBM<->VMEM streams, and accumulators live in VMEM scratch.  This module
predicts those bytes exactly the same way the paper predicts block counts,
and the DSE (:mod:`repro.core.dse`) uses it as its capacity constraint.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.hardware import TPU_V5E, TPUChip
from repro.core.tiling import (
    GemmProblem,
    TileConfig,
    dtype_bytes,
    min_sublane,
    round_up,
)

# Pallas pipelines HBM->VMEM streams with two in-flight stages.
PIPELINE_STAGES = 2


def padded_tile_bytes(rows: int, cols: int, dtype, chip: TPUChip = TPU_V5E
                      ) -> int:
    """Physical VMEM bytes of one (rows, cols) block after (sublane, lane)
    padding — the f_B/f_U analogue: logical size -> physical size."""
    pr = round_up(rows, min_sublane(dtype, chip))
    pc = round_up(cols, chip.lane)
    return pr * pc * dtype_bytes(dtype)


@dataclasses.dataclass(frozen=True)
class VmemFootprint:
    """Per-buffer VMEM bytes for one kernel instance."""

    a_bytes: int
    b_bytes: int
    out_bytes: int
    acc_bytes: int
    scale_bytes: int = 0          # fused-dequant fp32 scale vector blocks
    bias_bytes: int = 0           # fused-epilogue (1, bn) f32 bias blocks
    residual_bytes: int = 0       # fused-epilogue (bm, bn) residual stream

    @property
    def total(self) -> int:
        return (self.a_bytes + self.b_bytes + self.out_bytes
                + self.acc_bytes + self.scale_bytes + self.bias_bytes
                + self.residual_bytes)

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self) | {"total": self.total}


def vmem_footprint(tile: TileConfig, p: GemmProblem,
                   chip: TPUChip = TPU_V5E) -> VmemFootprint:
    """Predict the kernel's VMEM working set.

    * ``aie`` (output-stationary): A and B blocks stream (x pipeline
      stages); the fp32/int32 accumulator is a persistent scratch; the out
      block streams.
    * ``tb`` (A-stationary): the A block is resident (single copy); B and
      the read-modify-written C stream (x pipeline stages each way).

    A and B are billed at *their own* dtype widths — an int8 B block costs
    one byte/element, which is exactly what lets the DSE roughly double
    the feasible ``bk`` for W8A16 GEMMs.  A quantized B additionally
    streams a (1, bn) fp32 per-output-channel scale block.

    Fused extensions: the gated dual-B kernel (``p.n_b_operands == 2``)
    doubles the B stream, the scale blocks and the accumulator scratch;
    a fused epilogue (``p.epilogue``) adds its (1, bn) f32 bias blocks
    and/or its (bm, bn) out-dtype residual stream.

    Grouped ragged GEMMs (``p.n_groups > 0``) have the ``aie`` working
    set exactly: each instance streams one (bm, bk) A block and one
    (bk, bn) slice of the expert bank — the per-expert scale/bias
    vectors are the same (1, bn) blocks, and the steering tables live in
    scalar memory, not VMEM — so no grouped-specific branch is needed.
    """
    from repro.kernels.epilogue import Epilogue
    ep = Epilogue.parse(p.epilogue)
    a = padded_tile_bytes(tile.bm, tile.bk, p.a_dtype, chip)
    b = p.n_b_operands * padded_tile_bytes(tile.bk, tile.bn, p.b_dtype,
                                           chip)
    o = padded_tile_bytes(tile.bm, tile.bn, p.out_dtype, chip)
    acc = p.n_b_operands * padded_tile_bytes(tile.bm, tile.bn, p.acc_dtype,
                                             chip)
    scale = 0
    if p.b_dtype == "int8":
        scale = p.n_b_operands * PIPELINE_STAGES * padded_tile_bytes(
            1, tile.bn, "float32", chip)
    bias = 0
    if ep.bias:
        bias = PIPELINE_STAGES * padded_tile_bytes(1, tile.bn, "float32",
                                                   chip)
    residual = 0
    if ep.residual:
        residual = PIPELINE_STAGES * padded_tile_bytes(
            tile.bm, tile.bn, p.out_dtype, chip)
    if tile.strategy == "aie":
        return VmemFootprint(
            a_bytes=PIPELINE_STAGES * a,
            b_bytes=PIPELINE_STAGES * b,
            out_bytes=PIPELINE_STAGES * o,
            acc_bytes=acc,
            scale_bytes=scale,
            bias_bytes=bias,
            residual_bytes=residual,
        )
    # 'tb': A resident; C is both input and output stream (read-modify-
    # write accumulation in the output buffer, like the paper's PL adders).
    return VmemFootprint(
        a_bytes=a,
        b_bytes=PIPELINE_STAGES * b,
        out_bytes=2 * PIPELINE_STAGES * padded_tile_bytes(
            tile.bm, tile.bn, p.acc_dtype, chip),
        acc_bytes=0,
        scale_bytes=scale,
        bias_bytes=bias,
        residual_bytes=residual,
    )


def vmem_efficiency(tile: TileConfig, p: GemmProblem,
                    chip: TPUChip = TPU_V5E) -> float:
    """Logical bytes / physical (padded) bytes — the paper's RAM
    *efficiency* metric carried to VMEM tiles."""
    logical = tile.bm * tile.bk * dtype_bytes(p.a_dtype) \
        + tile.bk * tile.bn * dtype_bytes(p.b_dtype) \
        + tile.bm * tile.bn * dtype_bytes(p.out_dtype)
    a = padded_tile_bytes(tile.bm, tile.bk, p.a_dtype, chip)
    b = padded_tile_bytes(tile.bk, tile.bn, p.b_dtype, chip)
    o = padded_tile_bytes(tile.bm, tile.bn, p.out_dtype, chip)
    return logical / (a + b + o)


def fits_vmem(tile: TileConfig, p: GemmProblem, chip: TPUChip = TPU_V5E,
              budget_fraction: float = 0.75) -> bool:
    """Capacity constraint (eq. 7-8/15 analogue).  ``budget_fraction``
    reserves headroom for the compiler's own VMEM needs."""
    return vmem_footprint(tile, p, chip).total \
        <= budget_fraction * chip.vmem_bytes
