"""Published reference data from the paper (Tables II, III, IV).

These rows are the ground truth the faithful analytical models in
:mod:`repro.core.paper_model` are validated against (tests +
``benchmarks/table*``).  Keeping them in one place lets both the test suite
and the benchmark harness consume identical reference data.

Units note (derived during reproduction, documented in EXPERIMENTS.md):
the paper's "BW (GB/s)" columns are bytes / 2**30 per second (GiB/s).  Our
models reproduce the printed numbers exactly under that convention.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class VersalRow:
    """One row of Table III (plus Table II where applicable)."""

    u: int
    v: int
    w: int
    pattern: str                  # 'P1' (13x4x6) or 'P2' (10x3x10)
    compute_gemm: Tuple[int, int, int]
    native_buffer: Tuple[int, int, int]
    luts: int                     # reference only (no analogue modeled)
    brams: int                    # implementation count (Table III)
    urams: int
    aie_cores: int
    pl_freq_mhz: float
    throughput_tops: float
    power_w: float
    energy_eff: float             # TOPs/W
    ram_eff: float                # fraction
    bw_gibps: float               # paper prints GB/s; actually bytes/2^30
    mapping: Optional[Tuple[str, str, str]] = None   # Table II {A,B,C} map


# Table III: 10 top-ranked GEMM designs on Versal VC1902 (AIE @ 1.25 GHz).
VERSAL_TABLE3 = [
    VersalRow(2, 8, 2, "P1", (416, 512, 192), (832, 4096, 384),
              85_000, 630, 304, 390, 300, 77.01, 78.6, 0.980, 0.889, 145.2,
              ("U", "U", "B")),
    VersalRow(2, 2, 8, "P1", (416, 512, 192), (832, 1024, 1536),
              0, 422, 408, 390, 290, 76.93, 82.0, 0.938, 0.889, 101.4,
              ("B", "U", "U")),
    VersalRow(3, 2, 5, "P1", (416, 512, 192), (1248, 1024, 960),
              94_000, 792, 408, 390, 278, 76.72, 82.7, 0.932, 0.757, 100.7,
              ("B", "U", "U")),
    VersalRow(4, 2, 4, "P1", (416, 512, 192), (1664, 1024, 768),
              90_000, 792, 408, 390, 278, 76.72, 82.3, 0.928, 0.816, 101.9,
              ("B", "U", "U")),
    VersalRow(2, 4, 4, "P1", (416, 512, 192), (832, 2048, 768),
              97_000, 792, 408, 390, 278, 76.72, 82.8, 0.927, 0.626, 106.9,
              ("B", "U", "U")),
    VersalRow(2, 8, 2, "P2", (320, 384, 320), (640, 3072, 640),
              92_000, 806, 240, 400, 300, 76.08, 78.3, 0.971, 0.889, 122.2,
              ("U", "U", "B")),
    VersalRow(2, 7, 2, "P2", (320, 384, 320), (640, 2688, 640),
              92_000, 806, 240, 400, 300, 76.08, 77.8, 0.977, 0.810, 123.9,
              ("U", "U", "B")),
    VersalRow(2, 6, 2, "P2", (320, 384, 320), (640, 2304, 640),
              91_000, 806, 240, 400, 300, 76.08, 77.5, 0.982, 0.732, 126.1,
              ("U", "U", "B")),
    VersalRow(4, 2, 4, "P2", (320, 384, 320), (1280, 768, 1280),
              100_000, 912, 400, 400, 275, 75.40, 82.8, 0.911, 0.902, 100.6,
              ("B", "B", "U")),
    VersalRow(4, 2, 3, "P2", (320, 384, 320), (1280, 768, 960),
              100_000, 912, 400, 400, 275, 75.40, 82.0, 0.919, 0.702, 109.7,
              ("B", "B", "U")),
]


@dataclasses.dataclass(frozen=True)
class Table2Row:
    """Table II: model estimate vs HLS AUTO mapping."""

    u: int
    v: int
    w: int
    pattern: str
    mapping: Tuple[str, str, str]       # model's {A,B,C} -> {B,U}
    model_brams: int
    model_urams: int
    auto_brams: int
    auto_urams: int
    auto_fails: bool                    # URAM over-capacity -> PnR failure


VERSAL_TABLE2 = [
    Table2Row(4, 2, 4, "P1", ("B", "U", "U"), 780, 408, 0, 616, True),
    Table2Row(4, 2, 4, "P2", ("B", "B", "U"), 900, 400, 0, 640, True),
    Table2Row(2, 2, 8, "P1", ("B", "U", "U"), 416, 408, 416, 408, False),
    Table2Row(2, 8, 2, "P2", ("U", "U", "B"), 800, 240, 800, 240, False),
]


@dataclasses.dataclass(frozen=True)
class StratixRow:
    """One row of Table IV."""

    tb_len: int
    kp: int
    np_: int
    mp: int
    compute_gemm: Tuple[int, int, int]
    native_buffer: Tuple[int, int, int]
    alms: int                     # reference only
    brams: int                    # M20K count
    tbs: int
    freq_mhz: float
    throughput_tops: float
    power_w: float
    energy_eff: float
    ram_eff: float
    bw_gibps: float


# Table IV: 10 top-ranked GEMM designs on Stratix 10 NX 2100.
STRATIX_TABLE4 = [
    StratixRow(18, 16, 4, 3, (9, 2720, 4), (639, 2720, 1008),
               124_000, 6304, 3456, 349, 68.00, 51.1, 1.331, 0.880, 92.6),
    StratixRow(18, 8, 8, 3, (9, 1360, 8), (675, 2720, 928),
               123_000, 6064, 3456, 345, 67.21, 50.2, 1.340, 0.877, 91.6),
    StratixRow(9, 16, 5, 5, (15, 1280, 5), (900, 1280, 1000),
               127_000, 5840, 3600, 350, 66.94, 52.5, 1.275, 0.812, 90.2),
    StratixRow(12, 8, 6, 6, (18, 880, 6), (1152, 1760, 756),
               100_000, 6144, 3456, 338, 64.00, 48.6, 1.317, 0.867, 82.2),
    StratixRow(18, 16, 3, 4, (12, 2720, 3), (850, 2720, 750),
               108_000, 6272, 3456, 327, 63.71, 47.3, 1.347, 0.859, 85.4),
    StratixRow(9, 16, 6, 4, (12, 1280, 6), (912, 2560, 756),
               131_000, 6464, 3456, 342, 62.88, 50.7, 1.241, 0.851, 82.3),
    StratixRow(18, 8, 3, 8, (24, 1360, 3), (1600, 1360, 550),
               81_000, 6064, 3456, 321, 62.40, 46.5, 1.342, 0.831, 92.4),
    StratixRow(9, 8, 10, 5, (15, 640, 10), (900, 1280, 1000),
               124_000, 5840, 3600, 320, 61.21, 48.7, 1.257, 0.812, 82.4),
    StratixRow(18, 8, 5, 5, (15, 1360, 5), (1020, 2720, 630),
               101_000, 6150, 3600, 301, 61.08, 45.4, 1.346, 0.900, 83.5),
    StratixRow(18, 4, 8, 6, (18, 680, 8), (1152, 1360, 832),
               91_000, 6080, 3456, 312, 60.69, 46.2, 1.315, 0.843, 79.3),
]

# Paper headline claims (abstract / SS V).
VERSAL_PEAK_TOPS_CLAIM = 77.01
STRATIX_PEAK_TOPS_CLAIM = 68.00
VERSAL_BEST_EFF_CLAIM = 0.94       # TOPs/W ("up to 0.94")
STRATIX_BEST_EFF_CLAIM = 1.35
VERSAL_PEAK_FRACTION_CLAIM = (0.589, 0.601)   # 58.9-60.1% of 128 TOPs (AIE)
STRATIX_PEAK_FRACTION_CLAIM = 0.476           # 47.6% of 143 TOPs
VERSAL_DDR_LIMIT_GIBPS = 102.4     # gate used on the printed BW column
