"""Three-term roofline extraction from compiled XLA artifacts.

Per the task sheet:

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective operand bytes / link_bw

``compiled.cost_analysis()`` on a partitioned module reports *per-device*
FLOPs and bytes — but counts every ``while`` body ONCE regardless of trip
count (verified empirically), which under-counts any scanned model by
~n_layers×.  The three terms therefore come from
:mod:`repro.core.hlo_cost`, a loop-corrected accounting over the
post-SPMD HLO text (dot/conv FLOPs, boundary bytes, collective operand
bytes — each scaled by the enclosing loops' trip counts).  The raw XLA
numbers are kept in the report as diagnostics.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.core import hlo_cost
from repro.core.hardware import TPU_V5E, TPUChip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|\S+)\s+([\w\-]+)"
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type ('f32[12,34]', tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device operand bytes per collective type, from compiled HLO."""
    out: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m is None:
            continue
        type_str, opname = m.group(2), m.group(3)
        for coll in COLLECTIVE_OPS:
            if opname == coll or opname.startswith(coll + "-start"):
                nbytes = shape_bytes(type_str)
                if coll == "reduce-scatter":
                    nbytes *= _group_size(line)
                out[coll] += nbytes
                break
    return out


@dataclasses.dataclass
class RooflineReport:
    """The per-(arch x shape x mesh) record for EXPERIMENTS.md SSRoofline."""

    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    per_collective: Dict[str, float]
    t_compute: float
    t_memory: float
    t_collective: float
    peak_flops: float
    model_flops_per_device: Optional[float] = None
    xla_flops_raw: Optional[float] = None     # cost_analysis (loops x1)
    xla_bytes_raw: Optional[float] = None
    n_while: int = 0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's compute roofline this step achieves,
        assuming perfect overlap: t_compute / max(all terms)."""
        return self.t_compute / self.t_bound if self.t_bound else 0.0

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        if self.model_flops_per_device is None or not self.flops_per_device:
            return None
        return self.model_flops_per_device / self.flops_per_device

    def as_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 roofline_fraction=self.roofline_fraction,
                 useful_flops_ratio=self.useful_flops_ratio,
                 t_bound=self.t_bound)
        return d


def analyze(compiled, *, chip: TPUChip = TPU_V5E, int8: bool = False,
            model_flops_per_device: Optional[float] = None,
            hlo_text: Optional[str] = None) -> RooflineReport:
    """Build the 3-term roofline from a compiled (SPMD) executable.

    Compute/memory rates honor any installed cost-model calibration
    (:func:`repro.core.bandwidth.set_calibration` — measured effective
    constants fitted by ``repro.tune.calibrate``); the collective term
    keeps the datasheet ICI rate (no calibration source measures it).
    """
    from repro.core.bandwidth import effective_rates
    cost = hlo_cost.xla_cost(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    parsed = hlo_cost.analyze_text(text)
    peak, hbm_bw = effective_rates(chip, int8)
    return RooflineReport(
        flops_per_device=parsed.flops,
        hbm_bytes_per_device=parsed.bytes_accessed,
        collective_bytes_per_device=parsed.collective_total,
        per_collective=parsed.collective_bytes,
        t_compute=parsed.flops / peak,
        t_memory=parsed.bytes_accessed / hbm_bw,
        t_collective=parsed.collective_total / chip.ici_link_bw,
        peak_flops=peak,
        model_flops_per_device=model_flops_per_device,
        xla_flops_raw=float(cost.get("flops", 0.0)),
        xla_bytes_raw=float(cost.get("bytes accessed", 0.0)),
        n_while=parsed.n_while,
    )
