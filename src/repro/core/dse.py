"""Reuse-maximizing tiling DSE — the paper's IP formulation on TPU.

The paper solves, exhaustively, ``max U*V*W`` (on-chip data reuse) subject
to buffer-depth and block-capacity constraints, then gates designs on
off-chip bandwidth.  The TPU formulation is isomorphic:

    maximize   on-chip reuse  == minimize modeled HBM traffic
    subject to VMEM capacity  (repro.core.memory_model.fits_vmem)
               MXU alignment  (lane/sublane multiples)
    ranked by  roofline time, then traffic, then VMEM efficiency

and the two dataflow strategies ('aie' / 'tb') are searched jointly, the
way the paper searches {A,B,C} -> {BRAM,URAM} mapping permutations.

``solve()`` is exhaustive over the candidate grid (the paper solves its IP
"exhaustively" too) and is cached per problem signature — kernels call it
at trace time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

from repro.core.bandwidth import (
    TrafficEstimate,
    calibration_version,
    estimate,
)
from repro.core.hardware import TPU_V5E, TPUChip
from repro.core.memory_model import (
    fits_vmem,
    vmem_efficiency,
    vmem_footprint,
)
from repro.core.tiling import (
    STRATEGIES,
    GemmProblem,
    TileConfig,
    dtype_bytes,
    min_sublane,
    round_up,
)

# Candidate block edges.  Lane-dim candidates are 128-multiples (MXU edge);
# the m-dim additionally admits small sublane multiples so that skinny
# GEMMs (decode: m = batch) tile without pathological padding.
_LANE_CANDIDATES = (128, 256, 512, 1024, 2048)
_M_EXTRA = (8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class TileDesign:
    """One scored point of the DSE (a Table III/IV row analogue)."""

    tile: TileConfig
    traffic: TrafficEstimate
    vmem_bytes: int
    vmem_eff: float
    tile_eff: float

    @property
    def score(self) -> Tuple:
        # Primary: modeled roofline time.  Ties: less HBM traffic, higher
        # VMEM efficiency, smaller footprint.
        return (self.traffic.t_model, self.traffic.hbm_bytes,
                -self.vmem_eff, self.vmem_bytes)


def _m_candidates(m: int, dtype, chip: TPUChip) -> Sequence[int]:
    base = [c for c in _LANE_CANDIDATES]
    sub = min_sublane(dtype, chip)
    extra = [c for c in _M_EXTRA if c >= sub]
    cands = sorted(set(base + extra))
    # never tile beyond the (padded) problem dim
    cap = round_up(m, sub)
    return [c for c in cands if c <= max(cap, cands[0])] or [cands[0]]


def _lane_candidates(dim: int) -> Sequence[int]:
    cap = round_up(dim, 128)
    out = [c for c in _LANE_CANDIDATES if c <= cap]
    return out or [128]


@functools.lru_cache(maxsize=4096)
def _solve_cached(m: int, k: int, n: int, a_dtype: str, b_dtype: str,
                  out_dtype: str, acc_dtype: str, epilogue: str,
                  n_b_operands: int, n_groups: int, chip_name: str,
                  budget_fraction: float, top: int, cal_version: int
                  ) -> Tuple["TileDesign", ...]:
    assert chip_name == TPU_V5E.name, "single-target build"
    chip = TPU_V5E
    p = GemmProblem(m, k, n, a_dtype, out_dtype, acc_dtype, b_dtype,
                    epilogue, n_b_operands, n_groups)
    designs: List[TileDesign] = []
    for strategy in STRATEGIES:
        if n_b_operands > 1 and strategy == "tb":
            continue    # the gated dual-B kernel is output-stationary only
        if n_groups and strategy == "tb":
            continue    # the grouped sweep is output-stationary only
        # sublane minima are per-operand: bm follows A's dtype; B's
        # (bk, bn) block is billed at b_dtype inside fits_vmem, which is
        # what admits ~2x bigger bk for int8 weight streams.
        for bm in _m_candidates(m, a_dtype, chip):
            for bk in _lane_candidates(k):
                for bn in _lane_candidates(n):
                    tile = TileConfig(bm, bk, bn, strategy)
                    if not tile.mxu_aligned(chip):
                        continue
                    if not fits_vmem(tile, p, chip, budget_fraction):
                        continue
                    designs.append(TileDesign(
                        tile=tile,
                        traffic=estimate(tile, p, chip),
                        vmem_bytes=vmem_footprint(tile, p, chip).total,
                        vmem_eff=vmem_efficiency(tile, p, chip),
                        tile_eff=tile.tile_efficiency(p),
                    ))
    if not designs:
        raise ValueError(f"no feasible tiling for {p}")
    designs.sort(key=lambda d: d.score)
    return tuple(designs[:top])


def solve(p: GemmProblem, chip: TPUChip = TPU_V5E,
          budget_fraction: float = 0.75, top: int = 10
          ) -> List[TileDesign]:
    """Ranked tiling designs for a GEMM problem.  The memo key includes
    the cost-model calibration version: applying measured constants
    (``repro.tune.calibrate.apply``) re-ranks instead of serving stale
    pre-calibration answers."""
    return list(_solve_cached(p.m, p.k, p.n, p.a_dtype, p.b_dtype,
                              p.out_dtype, p.acc_dtype, p.epilogue,
                              p.n_b_operands, p.n_groups, chip.name,
                              budget_fraction, top,
                              calibration_version()))


def best_tile(m: int, k: int, n: int, in_dtype: str = "bfloat16",
              out_dtype: str = "bfloat16", acc_dtype: str = "float32",
              strategy: Optional[str] = None, *,
              b_dtype: Optional[str] = None, epilogue: str = "",
              n_b_operands: int = 1, n_groups: int = 0) -> TileConfig:
    """The DSE winner (optionally restricted to one strategy) — what
    ``repro.kernels.ops.gemm`` uses when no tile is given.

    ``in_dtype`` is A's dtype; pass ``b_dtype="int8"`` for the fused
    quantized-weight path (W8A16 / W8A8) so the search bills B at one
    byte/element.  ``epilogue`` (an :class:`repro.kernels.epilogue
    .Epilogue` key string) bills the fused bias/residual operands, and
    ``n_b_operands=2`` searches the dual-B gated kernel's real footprint
    (second B stream + second accumulator; 'aie' only).  ``n_groups=E``
    searches the grouped ragged sweep ('aie' only): ``m`` is the true
    routed row total and the straddle-instance billing pushes the search
    toward small ``bm`` — exactly the expert-imbalance/tile-granularity
    trade the megablocks formulation makes.
    """
    p = GemmProblem(m, k, n, in_dtype, out_dtype, acc_dtype, b_dtype,
                    epilogue, n_b_operands, n_groups)
    for d in solve(p):
        if strategy is None or d.tile.strategy == strategy:
            return d.tile
    raise ValueError(f"no feasible {strategy!r} tiling for {p}")


# ---------------------------------------------------------------------------
# Layer-level traffic: fused vs unfused MLP compositions
# ---------------------------------------------------------------------------

def _gemm_traffic(p: GemmProblem, chip: TPUChip) -> Tuple[float, float]:
    """(total, weight-stream) HBM bytes of one GEMM at its DSE winner.

    The weight component is billed with the winner's real reuse: the B
    panels (and dequant scale vectors) stream once per m-block row, so
    gm > 1 multiplies the weight bytes — attributing those re-streams to
    the weight side keeps the ``activations`` remainder honest.
    """
    d = solve(p, chip, top=1)[0]
    gm, _, _ = d.tile.grid(p)
    scale = p.n * 4 * p.n_b_operands if p.b_dtype == "int8" else 0
    w = (p.b_bytes * p.n_b_operands + scale) * gm
    return d.traffic.hbm_bytes, w


def mlp_traffic(m: int, d: int, d_ff: int, *, fused: bool,
                gated: bool = True, a_dtype: str = "bfloat16",
                b_dtype: Optional[str] = None,
                residual: bool = False,
                chip: TPUChip = TPU_V5E) -> dict:
    """Modeled HBM bytes of one MLP layer (SwiGLU when ``gated`` else a
    single-activation MLP), with each constituent GEMM at its own DSE
    winner.  Returns ``{"total", "weights", "activations"}``.

    Unfused (the pre-epilogue composition): gate/up (or in) GEMMs write
    their (m, d_ff) intermediates to HBM, an XLA elementwise pass re-reads
    them and writes the gated h, and the down GEMM reads h back.  Fused:
    the gated (or activation-epilogue) kernel emits h directly — the
    gate/up intermediates never touch HBM and A streams once — and the
    down GEMM can absorb the residual add.

    ``weights`` is the B-panel traffic at each winner's real reuse
    (gm passes); at decode shapes (gm == 1, single pass) it is an
    identical irreducible floor on both sides, so the fusion credit
    lands entirely in the ``activations`` component — which is why
    decode-shaped layers report the drop on that component.
    """
    act_b = dtype_bytes(a_dtype)
    n_up = 2 if gated else 1

    if fused:
        if gated:
            p_up = GemmProblem(m, d, d_ff, a_dtype, a_dtype, "float32",
                               b_dtype, "silu", 2)
        else:
            p_up = GemmProblem(m, d, d_ff, a_dtype, a_dtype, "float32",
                               b_dtype, "gelu", 1)
        p_down = GemmProblem(m, d_ff, d, a_dtype, a_dtype, "float32",
                             b_dtype, "res" if residual else "", 1)
        t_up, w_up = _gemm_traffic(p_up, chip)
        t_down, w_down = _gemm_traffic(p_down, chip)
        total, w = t_up + t_down, w_up + w_down
        return {"total": total, "weights": w, "activations": total - w}

    p_wide = GemmProblem(m, d, d_ff, a_dtype, a_dtype, "float32", b_dtype)
    p_down = GemmProblem(m, d_ff, d, a_dtype, a_dtype, "float32", b_dtype)
    t_wide, w_wide = _gemm_traffic(p_wide, chip)
    t_down, w_down = _gemm_traffic(p_down, chip)
    total = n_up * t_wide + t_down
    # XLA epilogue pass: read every (m, d_ff) intermediate, write h once
    total += (n_up + 1) * m * d_ff * act_b
    if residual:
        total += 2 * m * d * act_b          # read x, write x + down(h)
    w = n_up * w_wide + w_down
    return {"total": total, "weights": w, "activations": total - w}
