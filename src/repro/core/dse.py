"""Reuse-maximizing tiling DSE — the paper's IP formulation on TPU.

The paper solves, exhaustively, ``max U*V*W`` (on-chip data reuse) subject
to buffer-depth and block-capacity constraints, then gates designs on
off-chip bandwidth.  The TPU formulation is isomorphic:

    maximize   on-chip reuse  == minimize modeled HBM traffic
    subject to VMEM capacity  (repro.core.memory_model.fits_vmem)
               MXU alignment  (lane/sublane multiples)
    ranked by  roofline time, then traffic, then VMEM efficiency

and the two dataflow strategies ('aie' / 'tb') are searched jointly, the
way the paper searches {A,B,C} -> {BRAM,URAM} mapping permutations.

``solve()`` is exhaustive over the candidate grid (the paper solves its IP
"exhaustively" too) and is cached per problem signature — kernels call it
at trace time.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

from repro.core.bandwidth import TrafficEstimate, estimate
from repro.core.hardware import TPU_V5E, TPUChip
from repro.core.memory_model import (
    fits_vmem,
    vmem_efficiency,
    vmem_footprint,
)
from repro.core.tiling import (
    STRATEGIES,
    GemmProblem,
    TileConfig,
    min_sublane,
    round_up,
)

# Candidate block edges.  Lane-dim candidates are 128-multiples (MXU edge);
# the m-dim additionally admits small sublane multiples so that skinny
# GEMMs (decode: m = batch) tile without pathological padding.
_LANE_CANDIDATES = (128, 256, 512, 1024, 2048)
_M_EXTRA = (8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class TileDesign:
    """One scored point of the DSE (a Table III/IV row analogue)."""

    tile: TileConfig
    traffic: TrafficEstimate
    vmem_bytes: int
    vmem_eff: float
    tile_eff: float

    @property
    def score(self) -> Tuple:
        # Primary: modeled roofline time.  Ties: less HBM traffic, higher
        # VMEM efficiency, smaller footprint.
        return (self.traffic.t_model, self.traffic.hbm_bytes,
                -self.vmem_eff, self.vmem_bytes)


def _m_candidates(m: int, dtype, chip: TPUChip) -> Sequence[int]:
    base = [c for c in _LANE_CANDIDATES]
    sub = min_sublane(dtype, chip)
    extra = [c for c in _M_EXTRA if c >= sub]
    cands = sorted(set(base + extra))
    # never tile beyond the (padded) problem dim
    cap = round_up(m, sub)
    return [c for c in cands if c <= max(cap, cands[0])] or [cands[0]]


def _lane_candidates(dim: int) -> Sequence[int]:
    cap = round_up(dim, 128)
    out = [c for c in _LANE_CANDIDATES if c <= cap]
    return out or [128]


@functools.lru_cache(maxsize=4096)
def _solve_cached(m: int, k: int, n: int, a_dtype: str, b_dtype: str,
                  out_dtype: str, acc_dtype: str, chip_name: str,
                  budget_fraction: float, top: int
                  ) -> Tuple["TileDesign", ...]:
    assert chip_name == TPU_V5E.name, "single-target build"
    chip = TPU_V5E
    p = GemmProblem(m, k, n, a_dtype, out_dtype, acc_dtype, b_dtype)
    designs: List[TileDesign] = []
    for strategy in STRATEGIES:
        # sublane minima are per-operand: bm follows A's dtype; B's
        # (bk, bn) block is billed at b_dtype inside fits_vmem, which is
        # what admits ~2x bigger bk for int8 weight streams.
        for bm in _m_candidates(m, a_dtype, chip):
            for bk in _lane_candidates(k):
                for bn in _lane_candidates(n):
                    tile = TileConfig(bm, bk, bn, strategy)
                    if not tile.mxu_aligned(chip):
                        continue
                    if not fits_vmem(tile, p, chip, budget_fraction):
                        continue
                    designs.append(TileDesign(
                        tile=tile,
                        traffic=estimate(tile, p, chip),
                        vmem_bytes=vmem_footprint(tile, p, chip).total,
                        vmem_eff=vmem_efficiency(tile, p, chip),
                        tile_eff=tile.tile_efficiency(p),
                    ))
    if not designs:
        raise ValueError(f"no feasible tiling for {p}")
    designs.sort(key=lambda d: d.score)
    return tuple(designs[:top])


def solve(p: GemmProblem, chip: TPUChip = TPU_V5E,
          budget_fraction: float = 0.75, top: int = 10
          ) -> List[TileDesign]:
    """Ranked tiling designs for a GEMM problem."""
    return list(_solve_cached(p.m, p.k, p.n, p.a_dtype, p.b_dtype,
                              p.out_dtype, p.acc_dtype, chip.name,
                              budget_fraction, top))


def best_tile(m: int, k: int, n: int, in_dtype: str = "bfloat16",
              out_dtype: str = "bfloat16", acc_dtype: str = "float32",
              strategy: Optional[str] = None, *,
              b_dtype: Optional[str] = None) -> TileConfig:
    """The DSE winner (optionally restricted to one strategy) — what
    ``repro.kernels.ops.gemm`` uses when no tile is given.

    ``in_dtype`` is A's dtype; pass ``b_dtype="int8"`` for the fused
    quantized-weight path (W8A16 / W8A8) so the search bills B at one
    byte/element.
    """
    p = GemmProblem(m, k, n, in_dtype, out_dtype, acc_dtype, b_dtype)
    for d in solve(p):
        if strategy is None or d.tile.strategy == strategy:
            return d.tile
    raise ValueError(f"no feasible {strategy!r} tiling for {p}")
