"""The paper's primary contribution, adapted to TPU.

* :mod:`repro.core.paper_model` — faithful FPGA analytical models
  (reproduces the paper's Tables II-IV).
* :mod:`repro.core.tiling` / :mod:`repro.core.memory_model` /
  :mod:`repro.core.bandwidth` / :mod:`repro.core.dse` — the same
  methodology (analytical memory modeling + reuse-maximizing exhaustive
  DSE + bandwidth gating) on the TPU hierarchy; drives the Pallas GEMM
  kernels' tile selection.
* :mod:`repro.core.roofline` — 3-term roofline extraction from compiled
  XLA artifacts (feeds EXPERIMENTS.md).
"""

from repro.core.hardware import TPU_V5E, TPUChip  # noqa: F401
from repro.core.tiling import GemmProblem, TileConfig  # noqa: F401
from repro.core.dse import best_tile, solve  # noqa: F401
from repro.core.roofline import RooflineReport, analyze  # noqa: F401
