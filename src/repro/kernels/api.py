"""One declarative GEMM operator API: ``GemmSpec`` -> ``plan`` ->
``execute``.

The paper's core contribution is a *systematic framework*: one GEMM
problem description is mapped onto the best platform-specific execution
strategy (Versal AIE vs Stratix tensor-block) by an analytical DSE, and
the same description drives every precision and fusion variant.  This
module is that pipeline as the reproduction's only GEMM entrypoint:

* :class:`GemmSpec` — a frozen, hashable description of the GEMM family
  member being asked for: per-operand dtypes (a quantized B is an int8
  operand with a per-output-channel scale), an optional fused
  :class:`~repro.kernels.epilogue.Epilogue`, an optional gated second B
  operand (``act(A W_g) * (A W_u)``), and strategy / tile / out-dtype
  overrides.  Invalid strategies and activations fail at *construction*
  with the allowed set — nothing falls through to a silent default.
* :func:`plan` — resolves the spec for concrete ``(m, k, n)`` shapes
  exactly once (cached on the spec+shape key): the reuse-maximizing DSE
  (:mod:`repro.core.dse`) picks strategy + tile, explicit user tiles are
  validated against :func:`repro.core.memory_model.fits_vmem` /
  ``feasible_bk`` (infeasible overrides raise instead of being silently
  replaced), and the modeled HBM traffic, VMEM footprint and flops ride
  on the returned :class:`GemmPlan`.  ``GemmPlan.explain()`` renders the
  whole decision — chosen kernel, tile, modeled bytes, fallback reasons
  — and ``repro-dryrun --explain`` surfaces it per model.
* :func:`execute` — runs a plan on concrete operands through ONE generic
  ``jax.custom_vjp`` whose forward *and* backward are driven by the plan
  (quant routing, epilogue recompute, gated composition), replacing the
  six hand-specialized VJP wrappers the pre-redesign dispatch layer
  accreted.  :func:`gemm` is the one-shot composition of the three.

Dispatch policy (the hardware-adaptation contract) is unchanged: Pallas
kernels on TPU (or under ``REPRO_KERNELS=interpret``), the mathematically
identical pure-jnp references elsewhere — but the *plan* is computed the
same way everywhere, so the cost model stays introspectable on hosts
with no TPU.  The legacy ``repro.kernels.ops`` entrypoints are deprecated
shims over this module.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant as _quant
from repro import telemetry
from repro.core import dse
from repro.core.bandwidth import TrafficEstimate, estimate
from repro.core.hardware import TPU_V5E
from repro.core.memory_model import VmemFootprint, fits_vmem, \
    vmem_efficiency, vmem_footprint
from repro.core.tiling import STRATEGIES, GemmProblem, TileConfig, \
    grouped_instances, round_up
from repro.kernels import ref as _ref
from repro.kernels.epilogue import ACTIVATIONS, Epilogue
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_gated import gemm_gated as _gemm_gated_kernel
from repro.kernels.gemm_grouped import gemm_grouped as _gemm_grouped_kernel
from repro.kernels.gemm_tb import feasible_bk, gemm_tb


# ---------------------------------------------------------------------------
# Kernel-mode selection (shared by every kernel entrypoint)
# ---------------------------------------------------------------------------

def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def use_pallas() -> bool:
    return _mode() in ("pallas", "interpret")


def _interpret() -> bool:
    return _mode() == "interpret"


def _dtname(dt) -> str:
    return jnp.dtype(dt).name


def _is_quant(b) -> bool:
    return isinstance(b, dict) and {"q", "scale"} <= set(b)


# ---------------------------------------------------------------------------
# GemmSpec — the declarative problem description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """What GEMM-family member is being asked for (shapes excluded —
    they arrive at :func:`plan` time, so one spec serves every shape).

    * ``a_dtype`` / ``b_dtype`` — per-operand dtypes.  ``b_quant=True``
      means B arrives as a ``{"q", "scale"}`` int8 struct from
      :mod:`repro.quant` (b_dtype is forced to int8): the kernel streams
      q at one byte/element and applies the per-output-channel scale to
      the accumulator in-register.
    * ``gated`` — dual-B kernel ``act(A B_gate) * (A B_up)`` (the
      SwiGLU core): one resident A stream, both intermediates stay in
      VMEM.  Requires an epilogue activation; bias / residual /
      out-quant terms and the 'tb' strategy are rejected.
    * ``grouped`` — the ragged MoE family member: A is (m, k) tokens
      sorted by expert (m = *true* routed rows), B an (E, k, n) expert
      bank, and ``execute`` takes a ``group_sizes=`` (E,) vector.  Plans
      arrive with extended shapes ``(m, k, n, E[, dense_rows])`` so the
      cost model bills the straddling tile instances and ``explain()``
      can report the padding-flops delta vs the dense E*capacity
      formulation.  Output-stationary only ('tb' rejected), single-B
      (``gated`` rejected), epilogue limited to per-expert bias +
      activation, and measured autotuning is skipped (the tuner's
      measurement harness is dense-only) — plans stay analytic.
    * ``epilogue`` — declarative bias / activation / residual /
      out-quant fused into the kernel flush (an
      :class:`~repro.kernels.epilogue.Epilogue`, or its key string).
    * ``strategy`` / ``tile`` — overrides for the DSE.  An explicit tile
      is honored verbatim (quantized or not) after a feasibility check;
      an infeasible explicit tile raises at plan time.
    * ``out_dtype`` — ``None`` resolves to ``a_dtype`` (int8 when the
      epilogue quantizes the output).
    * ``tune`` — measured autotuning (:mod:`repro.tune`): ``True`` makes
      ``plan()`` consult the persistent tuning cache and, on a miss,
      time the top-K analytic candidates on-device and pick the measured
      winner; ``False`` forces the purely analytic DSE; ``None``
      (default) defers to ``repro.tune.enable()`` / ``REPRO_AUTOTUNE``.
      Excluded from :attr:`key` so tuning-cache entries join with the
      same spec regardless of *how* tuning was switched on.

    Frozen and hashable: specs key the plan cache, ride jit static
    arguments, and serialize their intent into ``GemmProblem`` for the
    cost model.
    """

    a_dtype: str = "bfloat16"
    b_dtype: str = "bfloat16"
    b_quant: bool = False
    gated: bool = False
    grouped: bool = False
    epilogue: Epilogue = Epilogue()
    out_dtype: Optional[str] = None
    strategy: Optional[str] = None
    tile: Optional[TileConfig] = None
    tune: Optional[bool] = None

    def __post_init__(self):
        object.__setattr__(self, "a_dtype", _dtname(self.a_dtype))
        if self.b_quant:
            object.__setattr__(self, "b_dtype", "int8")
        else:
            object.__setattr__(self, "b_dtype", _dtname(self.b_dtype))
        if self.out_dtype is not None:
            object.__setattr__(self, "out_dtype", _dtname(self.out_dtype))
        if isinstance(self.epilogue, str):
            object.__setattr__(self, "epilogue",
                               Epilogue.parse(self.epilogue))
        if self.strategy is not None and self.strategy not in STRATEGIES:
            raise ValueError(
                f"unknown strategy {self.strategy!r}: choose from "
                f"{STRATEGIES} (or None for the DSE to search both)")
        if self.tile is not None and not isinstance(self.tile, TileConfig):
            raise ValueError(f"tile must be a TileConfig, got {self.tile!r}")
        if self.gated:
            if self.epilogue.activation is None:
                raise ValueError(
                    "gated GEMM requires an epilogue activation: choose "
                    f"from {tuple(ACTIVATIONS)}")
            if self.epilogue.bias or self.epilogue.residual \
                    or self.epilogue.out_quant:
                raise ValueError(
                    "gated GEMM fuses only the gate activation; bias / "
                    "residual / out-quant epilogue terms are unsupported "
                    f"(got {self.epilogue.key!r})")
            if self.strategy == "tb" or (self.tile is not None
                                         and self.tile.strategy == "tb"):
                raise ValueError(
                    "the gated dual-B kernel is output-stationary "
                    "('aie') only; strategy/tile 'tb' is infeasible")
        if self.grouped:
            if self.gated:
                raise ValueError("grouped GEMM is single-B; it cannot "
                                 "be gated")
            if self.epilogue.residual or self.epilogue.out_quant:
                raise ValueError(
                    "grouped GEMM fuses only a per-expert bias + "
                    "activation; residual / out-quant epilogue terms "
                    f"are unsupported (got {self.epilogue.key!r})")
            if self.strategy == "tb" or (self.tile is not None
                                         and self.tile.strategy == "tb"):
                raise ValueError(
                    "the grouped ragged kernel is output-stationary "
                    "('aie') only; strategy/tile 'tb' is infeasible")

    @property
    def key(self) -> str:
        """Compact canonical string — the join key telemetry events and
        the model-vs-measured report use for this spec."""
        s = f"{self.a_dtype}x{self.b_dtype}"
        if self.b_quant:
            s += "{q}"
        if self.gated:
            s += ":gated"
        if self.grouped:
            s += ":grouped"
        if self.epilogue.key:
            s += f":{self.epilogue.key}"
        if self.out_dtype:
            s += f"->{self.out_dtype}"
        if self.strategy:
            s += f"!{self.strategy}"
        if self.tile is not None:
            s += f"!{self.tile.bm}x{self.tile.bk}x{self.tile.bn}"
        return s

    @classmethod
    def for_operands(cls, a, b, b2=None, *, bias=None,
                     activation: Optional[str] = None, residual=None,
                     out_scale=None, strategy: Optional[str] = None,
                     tile: Optional[TileConfig] = None,
                     out_dtype=None,
                     tune: Optional[bool] = None) -> "GemmSpec":
        """Spec inferred from concrete operands (arrays or ``{"q",
        "scale"}`` weight structs) plus the optional epilogue set — what
        the one-shot :func:`gemm` and the legacy shims build."""
        bq = _is_quant(b)
        if b2 is not None and _is_quant(b2) != bq:
            raise ValueError("quantize both gated operands or neither")
        gated = b2 is not None
        if gated:
            if bias is not None or residual is not None \
                    or out_scale is not None:
                raise ValueError("gated GEMM takes no bias/residual/"
                                 "out_scale epilogue operands")
            ep = Epilogue(activation=activation)
        else:
            ep = Epilogue.from_args(bias, activation, residual, out_scale)
        return cls(
            a_dtype=_dtname(a.dtype),
            b_dtype="int8" if bq else _dtname(b.dtype),
            b_quant=bq, gated=gated, epilogue=ep,
            out_dtype=None if out_dtype is None else _dtname(out_dtype),
            strategy=strategy, tile=tile, tune=tune)


def gemm_shapes(a, b) -> Tuple[int, int, int]:
    """The planned ``(m, k, n)``: leading dims of ``a`` flatten into M
    (the paper tiles 2-D GEMM; models bring (b, s, d))."""
    k = a.shape[-1]
    n = (b["q"] if _is_quant(b) else b).shape[-1]
    return (math.prod(a.shape[:-1]), k, n)


def gemm_grouped_shapes(a, b, dense_rows: Optional[int] = None
                        ) -> Tuple[int, int, int, int, int]:
    """The planned ``(m, k, n, E, dense_rows)`` of a grouped spec: ``a``
    is the (m, k) group-sorted token buffer (m = true routed rows), ``b``
    the (E, k, n) expert bank.  ``dense_rows`` is what the dense
    capacity-padded formulation would multiply (E * capacity) — it rides
    the plan so ``explain()`` can state the padding-flops savings;
    defaults to ``m`` (no claimed savings)."""
    bank = b["q"] if _is_quant(b) else b
    e, k, n = bank.shape
    m = math.prod(a.shape[:-1])
    return (m, k, n, e, int(dense_rows) if dense_rows else m)


# ---------------------------------------------------------------------------
# GemmPlan + the spec+shape-keyed plan cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TunedInfo:
    """The measured-autotuning record riding a tuned plan: the winner's
    measured time (median, with spread), the analytic first choice it
    was compared against, and whether the answer came from the
    persistent cache (zero re-measurement) or a fresh top-K sweep."""

    t_measured_us: float            # winner median wall-clock
    spread: float                   # (max-min)/median of kept samples
    t_analytic_us: Optional[float]  # measured time of the DSE's rank-0
    analytic_tile: str              # e.g. "aie 16x512x512"
    k_searched: int
    from_cache: bool


@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """One resolved execution decision: spec x (m, k, n) -> strategy,
    tile and the modeled costs the DSE ranked it by.  Frozen/hashable so
    it rides the single custom VJP as a static argument."""

    spec: GemmSpec
    m: int
    k: int
    n: int
    problem: GemmProblem
    tile: TileConfig
    traffic: TrafficEstimate
    vmem: VmemFootprint
    fallback_reason: Optional[str] = None
    tuned: Optional[TunedInfo] = None
    n_groups: int = 0           # grouped family: expert-group count E
    dense_rows: int = 0         # ... and the dense E*capacity row count

    @property
    def source(self) -> str:
        """How the tile was chosen: ``'tuned'`` (measured winner from
        the autotuner) or ``'analytic'`` (cost-model DSE)."""
        return "tuned" if self.tuned is not None else "analytic"

    @property
    def hbm_bytes(self) -> float:
        """Modeled HBM bytes of one forward execution at this tile."""
        return self.traffic.hbm_bytes

    @property
    def flops(self) -> float:
        """Padded (executed) flops at this tile."""
        return self.traffic.flops

    @property
    def vmem_bytes(self) -> int:
        """Modeled VMEM working set of the kernel instance."""
        return self.vmem.total

    def explain(self) -> str:
        """Human-readable decision record: chosen kernel, tile, modeled
        traffic/footprint, and why any fallback happened."""
        s, p, t = self.spec, self.problem, self.tile
        mode = _mode()
        if mode in ("pallas", "interpret"):
            kern = "pallas " + ("gemm_gated" if s.gated else
                                "gemm_grouped" if s.grouped else
                                f"gemm_{t.strategy}")
            if mode == "interpret":
                kern += " (interpret)"
        else:
            kern = "jnp reference (no TPU; tile/traffic modeled only)"
        b_desc = p.b_dtype + (" {q,scale}" if s.b_quant else "")
        if s.gated:
            b_desc = "2x " + b_desc
        gm, gn, gk = t.grid(p)
        budget = 0.75 * TPU_V5E.vmem_bytes
        lines = [
            f"GemmPlan {self.m}x{self.k}x{self.n}  A {p.a_dtype}  "
            f"B {b_desc}  -> {p.out_dtype} (acc {p.acc_dtype})",
            f"  kernel   : {kern}",
            f"  tile     : {t.strategy} {t.bm}x{t.bk}x{t.bn}"
            f"{'  (user override)' if s.tile is not None else ''}  "
            f"grid (gm,gn,gk)=({gm},{gn},{gk})  "
            f"pad eff {t.tile_efficiency(p):.0%}",
            f"  vmem     : {self.vmem.total / 2**20:.2f} MiB of "
            f"{budget / 2**20:.0f} MiB budget  "
            f"(a {self.vmem.a_bytes >> 10} KiB, b {self.vmem.b_bytes >> 10}"
            f" KiB, acc {self.vmem.acc_bytes >> 10} KiB)  "
            f"eff {vmem_efficiency(t, p):.0%}",
            f"  hbm      : {self.traffic.hbm_bytes / 2**20:.2f} MiB "
            f"modeled  AI {self.traffic.arithmetic_intensity:.0f} flop/B",
            f"  roofline : {self.traffic.bound}-bound  "
            f"t_model {self.traffic.t_model * 1e6:.1f} us  "
            f"(t_comp {self.traffic.t_compute * 1e6:.1f}, "
            f"t_mem {self.traffic.t_memory * 1e6:.1f})",
            f"  epilogue : {s.epilogue.key or '(none)'}"
            + (f"  gated({s.epilogue.activation})" if s.gated else ""),
        ]
        if p.n_groups:
            inst = grouped_instances(t, p)
            dense_flops = 2.0 * self.dense_rows * p.k * p.n
            saved = 1.0 - self.flops / dense_flops if dense_flops else 0.0
            lines.insert(4, (
                f"  grouped  : E={p.n_groups} groups, <={inst} tile "
                f"instances  A/HBM billed at true rows "
                f"(m={self.m} of {self.dense_rows} dense-capacity), "
                f"B one {t.bk}x{t.bn} panel per instance"))
            lines.insert(5, (
                f"  padding  : {self.flops / 1e9:.2f} GFLOP executed vs "
                f"{dense_flops / 1e9:.2f} dense-capacity "
                f"({saved:+.0%} saved)"))
        if self.tuned is not None:
            ti = self.tuned
            t_model_us = self.traffic.t_model * 1e6
            src = (f"  source   : tuned ({'cache' if ti.from_cache else f'measured top-{ti.k_searched}'})  "
                   f"{ti.t_measured_us:.1f} us measured vs "
                   f"{t_model_us:.1f} us modeled "
                   f"({ti.t_measured_us / t_model_us:.1f}x model, "
                   f"spread {ti.spread:.0%})")
            lines.append(src)
            if ti.t_analytic_us is not None \
                    and ti.analytic_tile != f"{t.strategy} {t.bm}x{t.bk}x{t.bn}":
                lines.append(
                    f"             analytic first choice "
                    f"{ti.analytic_tile} measured "
                    f"{ti.t_analytic_us:.1f} us")
        else:
            lines.append("  source   : analytic")
        if self.fallback_reason:
            lines.append(f"  fallback : {self.fallback_reason}")
        return "\n".join(lines)


class PlanCacheInfo(NamedTuple):
    entries: int
    hits: int
    misses: int


_plan_cache: dict = {}
_executed: set = set()          # plan keys whose execute() already traced
_plan_hits = 0
_plan_misses = 0


def plan_cache_info() -> PlanCacheInfo:
    """(entries, hits, misses) of the spec+shape plan cache — repeated-
    shape workloads should show DSE resolution ran once per unique
    (spec, shape)."""
    return PlanCacheInfo(len(_plan_cache), _plan_hits, _plan_misses)


def plan_cache_clear() -> None:
    """Drop every cached plan and zero the hit/miss counters (tests that
    monkeypatch the DSE or feasibility checks must call this, or stale
    plans computed under different rules leak between tests; benchmark
    sections call it so per-section hit/miss counts start clean)."""
    global _plan_hits, _plan_misses
    _plan_cache.clear()
    _executed.clear()
    _plan_hits = 0
    _plan_misses = 0


def plans() -> Tuple[GemmPlan, ...]:
    """Every plan resolved so far (insertion order) — what
    ``repro-dryrun --explain`` dumps after lowering a model."""
    return tuple(_plan_cache.values())


def _clamp_tile(tile: TileConfig, m: int, k: int, n: int) -> TileConfig:
    bm = min(tile.bm, round_up(m, 8))
    bk = min(tile.bk, round_up(k, 128))
    bn = min(tile.bn, round_up(n, 128))
    return TileConfig(bm, bk, bn, tile.strategy)


def _infeasible_reason(tile: TileConfig, p: GemmProblem) -> Optional[str]:
    """Why a tile cannot run, or None.  'tb' keeps a (bm, bk) A block
    VMEM-resident and refines its own k-chunking, so its gate is
    ``feasible_bk``; 'aie' streams everything, so plain ``fits_vmem``."""
    acc = jnp.int32 if p.a_dtype == "int8" else jnp.float32
    if tile.strategy == "tb":
        if feasible_bk(round_up(p.m, tile.bm), round_up(p.k, tile.bk),
                       round_up(p.n, tile.bn), tile,
                       jnp.dtype(p.a_dtype), jnp.dtype(p.b_dtype),
                       jnp.dtype(p.out_dtype), acc,
                       epilogue=p.epilogue) > 0:
            return None
        return ("no k-chunk keeps the resident (bm, bn) blocks inside "
                "the VMEM budget (feasible_bk == 0)")
    if fits_vmem(tile, p):
        return None
    return (f"VMEM footprint {vmem_footprint(tile, p).total / 2**20:.1f} "
            f"MiB exceeds the {0.75 * TPU_V5E.vmem_bytes / 2**20:.0f} "
            "MiB budget")


def plan(spec: GemmSpec, shapes: Tuple[int, ...]) -> GemmPlan:
    """Resolve ``spec`` for concrete ``(m, k, n)`` — strategy + tile via
    the DSE (or a validated user override) plus the modeled costs —
    exactly once per (spec, shape) key.  Grouped specs take the extended
    shapes ``(m, k, n, E[, dense_rows])`` (:func:`gemm_grouped_shapes`)."""
    global _plan_hits, _plan_misses
    shapes = tuple(int(x) for x in shapes)
    if spec.grouped:
        if len(shapes) not in (4, 5):
            raise ValueError(
                "a grouped spec plans with (m, k, n, E[, dense_rows]) "
                f"shapes — got {shapes}")
        m, k, n, e = shapes[:4]
        dense_rows = shapes[4] if len(shapes) == 5 else m
        if e < 1:
            raise ValueError(f"grouped spec needs E >= 1 groups, got {e}")
    else:
        if len(shapes) != 3:
            raise ValueError(
                f"a dense spec plans with (m, k, n) shapes — got {shapes}")
        m, k, n = shapes
        e, dense_rows = 0, 0
    key = (spec, m, k, n, e, dense_rows)
    cached = _plan_cache.get(key)
    if cached is not None:
        _plan_hits += 1
        if telemetry.enabled():
            _plan_event(cached, "hit")
        return cached
    _plan_misses += 1
    resolved = _resolve(spec, m, k, n, e, dense_rows)
    _plan_cache[key] = resolved
    if telemetry.enabled():
        _plan_event(resolved, "miss")
    return resolved


def _plan_event(pl: "GemmPlan", cache: str) -> None:
    """One telemetry event per plan() call: the full decision record —
    spec key, chosen strategy/tile, modeled HBM/VMEM bytes, flops,
    roofline verdict, cache hit/miss, and any fallback reason."""
    t = pl.tile
    telemetry.counter(f"gemm.plan_cache.{cache}").add(1)
    tuned = pl.tuned
    t_model_us = pl.traffic.t_model * 1e6
    telemetry.event(
        "gemm.plan", cache=cache, spec=pl.spec.key,
        m=pl.m, k=pl.k, n=pl.n, strategy=t.strategy,
        tile=f"{t.bm}x{t.bk}x{t.bn}", hbm_bytes=pl.hbm_bytes,
        vmem_bytes=pl.vmem_bytes, flops=pl.flops,
        t_model_us=t_model_us, bound=pl.traffic.bound,
        source=pl.source,
        t_measured_us=tuned.t_measured_us if tuned else None,
        measured_vs_model=(tuned.t_measured_us / t_model_us
                           if tuned and t_model_us else None),
        fallback_reason=pl.fallback_reason)


def _problem_for(spec: GemmSpec, m: int, k: int, n: int,
                 n_groups: int = 0) -> GemmProblem:
    """The cost-model problem a spec resolves to at concrete shapes —
    shared by ``plan()``, :func:`solve_topk` and the autotuner."""
    ep = spec.epilogue
    out_dtype = spec.out_dtype or ("int8" if ep.out_quant
                                   else spec.a_dtype)
    acc = "int32" if spec.a_dtype == "int8" else "float32"
    return GemmProblem(m, k, n, spec.a_dtype, out_dtype, acc,
                       spec.b_dtype, ep.key, 2 if spec.gated else 1,
                       n_groups if spec.grouped else 0)


def solve_topk(spec: GemmSpec, shapes: Tuple[int, int, int],
               k: int = 5) -> Tuple:
    """The ranked analytic tile candidates the autotuner sweeps for
    ``spec`` at ``shapes`` — a thin introspection wrapper over
    ``dse.solve`` (:class:`repro.core.dse.TileDesign` rows, best first,
    restricted to the spec's strategy when one is pinned; a restricted
    spec can return fewer than ``k`` rows)."""
    m, kk, n = (int(x) for x in shapes[:3])
    problem = _problem_for(spec, m, kk, n,
                           int(shapes[3]) if len(shapes) > 3 else 0)
    k = max(int(k), 1)
    designs = dse.solve(problem, top=k)
    if spec.strategy is not None:
        designs = [d for d in designs if d.tile.strategy == spec.strategy]
    return tuple(designs[:k])


def _tune_enabled(spec: GemmSpec) -> bool:
    if spec.tune is not None:
        return spec.tune
    from repro.tune import autotune as _autotune
    return _autotune.is_enabled(None)


def _resolve(spec: GemmSpec, m: int, k: int, n: int, n_groups: int = 0,
             dense_rows: int = 0) -> GemmPlan:
    problem = _problem_for(spec, m, k, n, n_groups)
    fallback = None
    tuned = None
    if spec.tile is not None:
        # explicit override: honored verbatim (quantized B included) —
        # but an infeasible tile raises instead of silently re-routing
        tile = _clamp_tile(spec.tile, m, k, n)
        err = _infeasible_reason(tile, problem)
        if err:
            raise ValueError(
                f"explicit tile {tile.strategy} {tile.bm}x{tile.bk}x"
                f"{tile.bn} is infeasible for {problem}: {err}")
    else:
        tile = None
        # grouped specs stay analytic: the tuner's measurement harness
        # builds dense operands and would mis-time the ragged sweep
        if _tune_enabled(spec) and not spec.grouped:
            # measured autotuning: the persistent tuning cache first,
            # then a top-K measured sweep; any degradation (over-budget
            # problem, stale/corrupt cache, measurement failure) falls
            # through to the analytic DSE below — never an exception
            from repro import tune as _tune
            found = _tune.lookup_or_search(spec, (m, k, n), problem)
            if found is not None:
                cand, tuned = found
                cand = _clamp_tile(cand, m, k, n)
                err = _infeasible_reason(cand, problem)
                if err:
                    # e.g. a cache entry measured on a different host
                    fallback = (f"tuned tile {cand.strategy} {cand.bm}x"
                                f"{cand.bk}x{cand.bn} infeasible here "
                                f"({err}); re-resolved analytically")
                    tuned = None
                else:
                    tile = cand
        if tile is None:
            designs = dse.solve(problem)
            chosen = next((d for d in designs
                           if spec.strategy in (None, d.tile.strategy)),
                          None)
            if chosen is None:
                raise ValueError(
                    f"no feasible {spec.strategy!r} tiling for {problem}")
            tile = _clamp_tile(chosen.tile, m, k, n)
            err = _infeasible_reason(tile, problem)
            if err:
                # the DSE winner can only fail the stricter post-clamp
                # tb recheck; fall back to the best 'aie' design
                aie = next((d for d in designs
                            if d.tile.strategy == "aie"), None)
                if aie is None:
                    raise ValueError(
                        f"no feasible tiling for {problem}: {err}")
                fallback = (f"tb tile {tile.bm}x{tile.bk}x{tile.bn} "
                            f"infeasible ({err}); fell back to the "
                            "DSE's aie winner")
                tile = _clamp_tile(aie.tile, m, k, n)
    traffic = estimate(tile, problem, TPU_V5E)
    vmem = vmem_footprint(tile, problem, TPU_V5E)
    return GemmPlan(spec, m, k, n, problem, tile, traffic, vmem,
                    fallback, tuned, n_groups, dense_rows)


# ---------------------------------------------------------------------------
# Pallas launch helpers (pad to tile multiples, dispatch, slice back)
# ---------------------------------------------------------------------------

def _pad2(x, m_to, n_to):
    m, n = x.shape
    if m == m_to and n == n_to:
        return x
    return jnp.pad(x, ((0, m_to - m), (0, n_to - n)))


def _gemm_pallas(a: jax.Array, b: jax.Array, tile: TileConfig,
                 out_dtype, *, b_scale: Optional[jax.Array] = None,
                 bias: Optional[jax.Array] = None,
                 residual: Optional[jax.Array] = None,
                 out_scale: Optional[jax.Array] = None,
                 activation: Optional[str] = None) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    ap = _pad2(a, mp, kp)
    bp = _pad2(b, kp, np_)
    sp = None
    if b_scale is not None:
        sp = b_scale if np_ == n else jnp.pad(
            b_scale, ((0, 0), (0, np_ - n)), constant_values=1.0)
        sp = sp.astype(jnp.float32)
    biasp = _pad2(bias, 1, np_) if bias is not None else None
    resp = _pad2(residual, mp, np_) if residual is not None else None
    fn = gemm_aie if tile.strategy == "aie" else gemm_tb
    out = fn(ap, bp, tile=tile, out_dtype=out_dtype, b_scale=sp,
             bias=biasp, residual=resp, out_scale=out_scale,
             activation=activation, interpret=_interpret())
    return out[:m, :n]


def _gated_pallas(a, bg, bu, tile, out_dtype, activation,
                  sg=None, su=None) -> jax.Array:
    m, k = a.shape
    _, n = bg.shape
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    ap = _pad2(a, mp, kp)
    bgp, bup = _pad2(bg, kp, np_), _pad2(bu, kp, np_)
    if sg is not None and np_ != n:
        pad = ((0, 0), (0, np_ - n))
        sg = jnp.pad(sg, pad, constant_values=1.0)
        su = jnp.pad(su, pad, constant_values=1.0)
    out = _gemm_gated_kernel(ap, bgp, bup, tile=tile,
                             activation=activation, out_dtype=out_dtype,
                             bg_scale=sg, bu_scale=su,
                             interpret=_interpret())
    return out[:m, :n]


def _dispatch_grouped(pl: GemmPlan, a, b, b_scale, group_sizes, bias
                      ) -> jax.Array:
    """The grouped-family pallas/reference fan-out: pad to the plan's
    tile, launch the ragged sweep (or the XLA gather oracle), slice
    back.  ``bias`` is (E, n) per-expert; padding rows of A belong to no
    group, padded k/n columns are zeros (scale pads with 1.0), so the
    sliced-back result is exact."""
    spec = pl.spec
    act = spec.epilogue.activation
    out_dtype = jnp.dtype(pl.problem.out_dtype)
    sizes = group_sizes.astype(jnp.int32)
    e = b.shape[0]
    bias3 = bias.reshape((e, 1, bias.shape[-1])) if bias is not None \
        else None
    if use_pallas():
        t = pl.tile
        m, k = a.shape
        _, _, n = b.shape
        mp, kp, np_ = round_up(m, t.bm), round_up(k, t.bk), \
            round_up(n, t.bn)
        ap = _pad2(a, mp, kp)
        bp = b if (kp, np_) == (k, n) else jnp.pad(
            b, ((0, 0), (0, kp - k), (0, np_ - n)))
        sp = None
        if b_scale is not None:
            sp = b_scale if np_ == n else jnp.pad(
                b_scale, ((0, 0), (0, 0), (0, np_ - n)),
                constant_values=1.0)
            sp = sp.astype(jnp.float32)
        bias_p = None
        if bias3 is not None:
            bias_p = bias3 if np_ == n else jnp.pad(
                bias3, ((0, 0), (0, 0), (0, np_ - n)))
        out = _gemm_grouped_kernel(ap, bp, sizes, tile=t,
                                   out_dtype=out_dtype, b_scale=sp,
                                   bias=bias_p, activation=act,
                                   interpret=_interpret())
        return out[:m, :n]
    return _ref.gemm_grouped_ref(a, b, sizes, b_scale=b_scale,
                                 bias=bias3, activation=act,
                                 out_dtype=out_dtype)


def _dispatch(pl: GemmPlan, a, b, b_scale, b2, b2_scale, bias, residual,
              out_scale) -> jax.Array:
    """The one pallas/reference fan-out every GEMM shares, driven by the
    plan: the tile was resolved and feasibility-checked at plan time, so
    this only pads, launches and slices (or runs the jnp oracle)."""
    spec = pl.spec
    act = spec.epilogue.activation
    out_dtype = jnp.dtype(pl.problem.out_dtype)
    if use_pallas():
        if spec.gated:
            return _gated_pallas(a, b, b2, pl.tile, out_dtype, act,
                                 sg=b_scale, su=b2_scale)
        return _gemm_pallas(a, b, pl.tile, out_dtype, b_scale=b_scale,
                            bias=bias, residual=residual,
                            out_scale=out_scale, activation=act)
    if spec.gated:
        return _ref.gemm_gated_ref(a, b, b2, activation=act,
                                   bg_scale=b_scale, bu_scale=b2_scale,
                                   out_dtype=out_dtype)
    if bias is None and act is None and residual is None \
            and out_scale is None:
        if b_scale is not None:
            return _ref.gemm_fused_ref(a, b, b_scale,
                                       out_dtype=out_dtype)
        return _ref.gemm_ref(a, b, out_dtype=out_dtype)
    return _ref.gemm_epilogue_ref(a, b, b_scale=b_scale, bias=bias,
                                  activation=act, residual=residual,
                                  out_scale=out_scale,
                                  out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# The ONE custom VJP of the GEMM family
# ---------------------------------------------------------------------------

def _float0(x):
    return np.zeros(x.shape, jax.dtypes.float0)


def _act_bwd(activation: Optional[str], z: jax.Array, g: jax.Array
             ) -> jax.Array:
    """dL/dz given dL/d(act(z)) — the unfused-composition backward."""
    if activation is None:
        return g
    _, vjp = jax.vjp(ACTIVATIONS[activation], z)
    return vjp(g)[0]


def _plain(a: jax.Array, b: jax.Array, b_scale, out_dtype,
           strategy: Optional[str] = None) -> jax.Array:
    """A planned plain GEMM (no epilogue) — the recompute primitive the
    generic backward is composed from.  Backward GEMMs pin
    ``tune=False``: the autotuner measures forward plans only, and a
    measurement pass must never trigger nested searches from its own
    recompute GEMMs."""
    spec = GemmSpec(a_dtype=a.dtype, b_dtype=b.dtype,
                    b_quant=b_scale is not None, out_dtype=out_dtype,
                    strategy=strategy, tune=False)
    pl = plan(spec, (a.shape[0], a.shape[1], b.shape[1]))
    return _gemm_core(pl, a, b, b_scale, None, None, None, None)


def _bwd_weight(q: jax.Array, b_scale, dtype) -> jax.Array:
    """The ONLY place a quantized weight is dequantized — backward-pass
    rematerialization; the forward never pays 2-byte weight traffic."""
    if b_scale is None:
        return q
    return (q.astype(jnp.float32) * b_scale).astype(dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _gemm_core(pl: GemmPlan, a, b, b_scale, b2, b2_scale, bias,
               residual) -> jax.Array:
    """epilogue(A @ B) (or the gated dual-B form), forward and backward
    both driven by the plan.  Absent operands are None; quantized
    weights arrive as (int8 q, fp32 per-output-channel scale)."""
    return _dispatch(pl, a, b, b_scale, b2, b2_scale, bias, residual,
                     None)


def _gemm_core_fwd(pl, a, b, b_scale, b2, b2_scale, bias, residual):
    out = _gemm_core(pl, a, b, b_scale, b2, b2_scale, bias, residual)
    return out, (a, b, b_scale, b2, b2_scale, bias, residual)


def _gemm_core_bwd(pl, res, g):
    # Unfused-composition backward: recompute the pre-activation z (one
    # extra GEMM — rematerialization, not HBM round-trips), then the
    # standard cotangents through the elementwise epilogue.  Quantized
    # weights are serving artifacts: int8 q gets a float0 cotangent and
    # the scale a zero — they are dequantized only here, never forward.
    a, b, b_scale, b2, b2_scale, bias, residual = res
    spec = pl.spec
    act = spec.epilogue.activation
    strat = spec.strategy
    gf = g.astype(jnp.float32)
    dres = gf.astype(residual.dtype) if residual is not None else None

    if spec.gated:
        if b_scale is not None and a.dtype == jnp.int8:
            return (_float0(a), _float0(b), jnp.zeros_like(b_scale),
                    _float0(b2), jnp.zeros_like(b2_scale), None, None)
        zg = _plain(a, b, b_scale, jnp.float32)
        zu = _plain(a, b2, b2_scale, jnp.float32)
        dzu = gf * ACTIVATIONS[act](zg)
        dzg = _act_bwd(act, zg, gf * zu)
        wg = _bwd_weight(b, b_scale, a.dtype)
        wu = _bwd_weight(b2, b2_scale, a.dtype)
        da = (_plain(dzg.astype(a.dtype), wg.T, None, a.dtype)
              + _plain(dzu.astype(a.dtype), wu.T, None, a.dtype)
              ).astype(a.dtype)
        if b_scale is not None:
            return (da, _float0(b), jnp.zeros_like(b_scale), _float0(b2),
                    jnp.zeros_like(b2_scale), None, None)
        dbg = _plain(a.T, dzg.astype(a.dtype), None, b.dtype
                     ).astype(b.dtype)
        dbu = _plain(a.T, dzu.astype(a.dtype), None, b2.dtype
                     ).astype(b2.dtype)
        return da, dbg, None, dbu, None, None, None

    if act is not None:
        z = _plain(a, b, b_scale, jnp.float32, strat)
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = _act_bwd(act, z, gf)
    else:
        dz = gf
    dbias = jnp.sum(dz, axis=0, keepdims=True).astype(bias.dtype) \
        if bias is not None else None
    if a.dtype == jnp.int8:
        da = _float0(a)
    else:
        w = _bwd_weight(b, b_scale, a.dtype)
        da = _plain(dz.astype(a.dtype), w.T, None, a.dtype,
                    strat).astype(a.dtype)
    if b_scale is not None:
        db, dbs = _float0(b), jnp.zeros_like(b_scale)
    elif b.dtype == jnp.int8:
        db, dbs = _float0(b), None
    else:
        db = _plain(a.T, dz.astype(a.dtype), None, b.dtype,
                    strat).astype(b.dtype)
        dbs = None
    return da, db, dbs, None, None, dbias, dres


_gemm_core.defvjp(_gemm_core_fwd, _gemm_core_bwd)


# ---------------------------------------------------------------------------
# The grouped family's generic VJP (backward = grouped GEMMs with the
# transposed expert bank steered by the SAME group tables)
# ---------------------------------------------------------------------------

def _group_rows(sizes: jax.Array, m: int):
    """Per-row group id (clamped) and liveness under ``sizes`` — the
    backward's reconstruction of the forward's steering tables."""
    ends = jnp.cumsum(sizes.astype(jnp.int32))
    rows = jnp.arange(m, dtype=jnp.int32)
    gid = jnp.searchsorted(ends, rows, side="right").astype(jnp.int32)
    live = rows < ends[-1]
    return jnp.minimum(gid, sizes.shape[0] - 1), live


def _grouped_plain(a, b, b_scale, sizes, out_dtype) -> jax.Array:
    """A planned plain grouped GEMM — the recompute/backward primitive
    (``tune=False`` like ``_plain``; dense_rows defaults to m, so
    internal plans claim no padding savings)."""
    spec = GemmSpec(a_dtype=a.dtype, b_dtype=b.dtype,
                    b_quant=b_scale is not None, grouped=True,
                    out_dtype=out_dtype, tune=False)
    pl = plan(spec, (a.shape[0], a.shape[1], b.shape[2], b.shape[0]))
    return _grouped_core(pl, a, b, b_scale, sizes, None)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _grouped_core(pl: GemmPlan, a, b, b_scale, group_sizes, bias
                  ) -> jax.Array:
    """epilogue(A[r] @ B[g(r)]) over the ragged groups, forward and
    backward driven by the plan.  ``group_sizes`` is a data operand
    (int32 — its cotangent is float0)."""
    return _dispatch_grouped(pl, a, b, b_scale, group_sizes, bias)


def _grouped_core_fwd(pl, a, b, b_scale, group_sizes, bias):
    out = _grouped_core(pl, a, b, b_scale, group_sizes, bias)
    return out, (a, b, b_scale, group_sizes, bias)


def _grouped_core_bwd(pl, res, g):
    # dA rows see only their own expert's panel, so dA is itself a
    # grouped GEMM against the transposed bank with the same group
    # tables; dB is the per-expert segment outer product (one-hot
    # einsum — training-path cost, never paid when serving quantized
    # banks: int8 q gets float0 like the dense family).
    a, b, b_scale, sizes, bias = res
    spec = pl.spec
    act = spec.epilogue.activation
    e = b.shape[0]
    gid, live = _group_rows(sizes, a.shape[0])
    gf = jnp.where(live[:, None], g.astype(jnp.float32), 0.0)
    if act is not None:
        z = _grouped_plain(a, b, b_scale, sizes, jnp.float32)
        if bias is not None:
            z = z + bias[gid].astype(jnp.float32)
        dz = _act_bwd(act, z, gf)
        dz = jnp.where(live[:, None], dz, 0.0)
    else:
        dz = gf
    dbias = None
    if bias is not None:
        dbias = jax.ops.segment_sum(dz, gid, num_segments=e
                                    ).astype(bias.dtype)
    if a.dtype == jnp.int8:
        da = _float0(a)
    else:
        w = b if b_scale is None else \
            (b.astype(jnp.float32) * b_scale).astype(a.dtype)
        da = _grouped_plain(dz.astype(a.dtype), w.swapaxes(1, 2), None,
                            sizes, a.dtype).astype(a.dtype)
    if b_scale is not None:
        db, dbs = _float0(b), jnp.zeros_like(b_scale)
    elif b.dtype == jnp.int8:
        db, dbs = _float0(b), None
    else:
        onehot = (jnp.where(live, gid, e)[:, None]
                  == jnp.arange(e)[None, :]).astype(jnp.float32)
        db = jnp.einsum("re,rk,rn->ekn", onehot,
                        a.astype(jnp.float32), dz).astype(b.dtype)
        dbs = None
    return da, db, dbs, _float0(sizes), dbias


_grouped_core.defvjp(_grouped_core_fwd, _grouped_core_bwd)


# ---------------------------------------------------------------------------
# execute + the one-shot gemm
# ---------------------------------------------------------------------------

def _execute_event(pl: GemmPlan) -> None:
    if not telemetry.enabled():
        return
    spec = pl.spec
    ek = (spec, pl.m, pl.k, pl.n)
    if ek in _executed:
        return
    # first trace of this plan only: jitted callers re-enter execute()
    # once per compilation, eager callers every call — the dedup keeps
    # the event stream one record per plan
    _executed.add(ek)
    telemetry.event(
        "gemm.execute", spec=spec.key, m=pl.m, k=pl.k, n=pl.n,
        strategy=pl.tile.strategy, mode=_mode(),
        hbm_bytes=pl.hbm_bytes, flops=pl.flops)
    telemetry.counter("gemm.execute.first_traces").add(1)


def execute(pl: GemmPlan, a: jax.Array, b, *, b2=None,
            bias: Optional[jax.Array] = None,
            residual: Optional[jax.Array] = None,
            out_scale=None, group_sizes=None) -> jax.Array:
    """Run a resolved plan on concrete operands.

    ``a``: (..., k) — leading dims flatten into the planned M.  ``b`` /
    ``b2``: (k, n) arrays, or ``{"q", "scale"}`` structs when the spec
    says ``b_quant``.  Epilogue operands must match the spec (a plan for
    a bias epilogue requires ``bias=``, and vice versa) — mismatches
    raise rather than silently computing something else.

    A grouped plan requires ``group_sizes=`` (an (E,) integer vector)
    and takes ``b`` as the (E, k, n) expert bank (quantized: q (E, k, n)
    with scale (E, 1, n)); ``bias`` is then per-expert (E, n).  Rows of
    ``a`` must be group-sorted; rows at and beyond ``sum(group_sizes)``
    come back zero.  The W8A8 activation-quant re-route below is dense
    family only — a quantized grouped bank always runs W8A16.

    Under ``quant.activation_mode() == "w8a8"`` a quantized-weight,
    linear-epilogue plan re-routes through dynamic per-row int8
    activation quantization (int8 x int8 kernel, int32 accumulation,
    scales applied outside — forward-only), exactly like the
    pre-redesign dispatch.
    """
    spec = pl.spec
    ep = spec.epilogue
    if spec.gated != (b2 is not None):
        raise ValueError(f"plan {'expects' if spec.gated else 'forbids'} "
                         "a second gated B operand `b2`")
    if spec.grouped != (group_sizes is not None):
        raise ValueError(
            f"plan {'requires' if spec.grouped else 'forbids'} "
            "`group_sizes=`")
    for name, want, got in (("bias", ep.bias, bias is not None),
                            ("residual", ep.residual,
                             residual is not None),
                            ("out_scale", ep.out_quant,
                             out_scale is not None)):
        if want != got:
            raise ValueError(
                f"plan epilogue {ep.key or '(none)'!r} "
                f"{'requires' if want else 'forbids'} `{name}=`")
    if spec.b_quant != _is_quant(b):
        raise ValueError(
            "plan expects B as a {'q','scale'} struct" if spec.b_quant
            else "plan expects a plain B array, got a quant struct")
    b_scale = b2_scale = None
    if spec.b_quant:
        b, b_scale = b["q"], b["scale"]
        if spec.gated:
            b2, b2_scale = b2["q"], b2["scale"]
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    if spec.grouped:
        e = pl.n_groups
        if b.ndim != 3 or b.shape != (e, pl.k, pl.n):
            raise ValueError(
                f"grouped plan expects the ({e}, {pl.k}, {pl.n}) expert "
                f"bank, got B {b.shape}")
        if b_scale is not None and b_scale.shape != (e, 1, pl.n):
            raise ValueError(
                f"grouped quant scale must be ({e}, 1, {pl.n}), got "
                f"{b_scale.shape}")
        if a2.shape != (pl.m, pl.k):
            raise ValueError(
                f"operands {a.shape} @ {b.shape} do not match the "
                f"plan's {pl.m}x{pl.k}x{pl.n}")
        gs = jnp.asarray(group_sizes)
        if gs.shape != (e,) or not jnp.issubdtype(gs.dtype, jnp.integer):
            raise ValueError(
                f"group_sizes must be an ({e},) integer vector, got "
                f"{gs.shape} {gs.dtype}")
        if _dtname(a2.dtype) != spec.a_dtype \
                or _dtname(b.dtype) != spec.b_dtype:
            raise ValueError(
                f"operand dtypes ({_dtname(a2.dtype)}, {_dtname(b.dtype)})"
                f" do not match the spec ({spec.a_dtype}, {spec.b_dtype})")
        bias_g = None
        if bias is not None:
            bias_g = bias.reshape((e, -1))
            if bias_g.shape != (e, pl.n):
                raise ValueError(
                    f"grouped bias must be per-expert ({e}, {pl.n}), "
                    f"got {bias.shape}")
        _execute_event(pl)
        out = _grouped_core(pl, a2, b, b_scale, gs.astype(jnp.int32),
                            bias_g)
        return out.reshape(lead + (pl.n,)).astype(
            jnp.dtype(pl.problem.out_dtype))
    if a2.shape != (pl.m, pl.k) or b.shape != (pl.k, pl.n):
        raise ValueError(
            f"operands {a.shape} @ {b.shape} do not match the plan's "
            f"{pl.m}x{pl.k}x{pl.n}")
    if b2 is not None and b2.shape != (pl.k, pl.n):
        raise ValueError(
            f"gated operand b2 {b2.shape} does not match the plan's "
            f"({pl.k}, {pl.n}) — it would be silently zero-padded")
    if _dtname(a2.dtype) != spec.a_dtype \
            or _dtname(b.dtype) != spec.b_dtype:
        raise ValueError(
            f"operand dtypes ({_dtname(a2.dtype)}, {_dtname(b.dtype)}) "
            f"do not match the spec ({spec.a_dtype}, {spec.b_dtype})")
    _execute_event(pl)
    n = pl.n
    out_dtype = jnp.dtype(pl.problem.out_dtype)
    bias2 = bias.reshape((1, n)) if bias is not None else None
    res2 = residual.reshape((-1, n)) if residual is not None else None
    if res2 is not None and res2.shape[0] != pl.m:
        raise ValueError(
            f"residual {residual.shape} does not match the plan's "
            f"({pl.m}, {n}) output")

    if (spec.b_quant and not spec.gated and ep.activation is None
            and not ep.out_quant
            and _quant.activation_mode() == "w8a8"
            and a2.dtype != jnp.int8):
        # W8A8 + linear epilogue: keep the int8 x int8 / int32 MXU path;
        # the per-row activation scale commutes with bias/residual, so
        # they apply to the scaled fp32 output outside the kernel.
        a_q, a_s = _quant.quantize_activations(
            jax.lax.stop_gradient(a2), axis=-1)
        sub = dataclasses.replace(spec, a_dtype="int8",
                                  epilogue=Epilogue(),
                                  out_dtype="float32", tune=False)
        acc = _gemm_core(plan(sub, (pl.m, pl.k, pl.n)), a_q, b, b_scale,
                         None, None, None, None)
        out = acc * a_s
        if bias2 is not None:
            out = out + bias2.astype(jnp.float32)
        if res2 is not None:
            out = out + res2.astype(jnp.float32)
        return out.astype(out_dtype).reshape(lead + (n,))

    if out_scale is not None:
        # quantized output is a forward-only serving feature (no VJP
        # through the rounding) — dispatch without the VJP wrapper
        osc = jnp.asarray(out_scale, jnp.float32).reshape((1, 1))
        out = _dispatch(pl, a2, b, b_scale, b2, b2_scale, bias2, res2,
                        osc)
        return out.reshape(lead + (n,))
    out = _gemm_core(pl, a2, b, b_scale, b2, b2_scale, bias2, res2)
    return out.reshape(lead + (n,)).astype(out_dtype)


def gemm(a: jax.Array, b, *, b2=None, bias: Optional[jax.Array] = None,
         activation: Optional[str] = None,
         residual: Optional[jax.Array] = None, out_scale=None,
         strategy: Optional[str] = None,
         tile: Optional[TileConfig] = None, out_dtype=None,
         tune: Optional[bool] = None) -> jax.Array:
    """The one-shot planned GEMM: ``spec -> plan -> execute`` in a
    single call.

    * ``gemm(a, b)`` — C = A @ B (``b`` may be a ``{"q", "scale"}``
      int8 weight struct: fused W8A16/W8A8 serving path).
    * ``gemm(a, b, bias=..., activation="gelu", residual=...)`` —
      epilogue fused into the kernel flush.
    * ``gemm(a, b_gate, b2=b_up, activation="silu")`` — the dual-B
      gated SwiGLU core in one kernel call.

    Every call resolves (once, cached) a :class:`GemmPlan`; build the
    spec yourself via :class:`GemmSpec` + :func:`plan` when you want to
    inspect ``plan.explain()`` or amortize the spec construction.
    """
    spec = GemmSpec.for_operands(a, b, b2, bias=bias,
                                 activation=activation, residual=residual,
                                 out_scale=out_scale, strategy=strategy,
                                 tile=tile, out_dtype=out_dtype,
                                 tune=tune)
    pl = plan(spec, gemm_shapes(a, b))
    return execute(pl, a, b, b2=b2, bias=bias, residual=residual,
                   out_scale=out_scale)


def gemm_grouped(a: jax.Array, b, group_sizes: jax.Array, *,
                 bias: Optional[jax.Array] = None,
                 activation: Optional[str] = None,
                 tile: Optional[TileConfig] = None, out_dtype=None,
                 dense_rows: Optional[int] = None) -> jax.Array:
    """The one-shot planned grouped ragged GEMM (the MoE expert sweep):
    ``C[r] = epilogue(A[r] @ B[g(r)])`` with ``g(r)`` the expert owning
    row ``r`` under ``group_sizes``.

    ``a``: (..., k) tokens *sorted by expert* (leading dims flatten into
    the true routed row count m); ``b``: (E, k, n) expert bank, or a
    ``{"q", "scale"}`` W8A16 struct with scale (E, 1, n); ``bias``:
    per-expert (E, n).  Rows at and beyond ``sum(group_sizes)`` come
    back zero.  ``dense_rows`` (the E*capacity rows the dense einsum
    would multiply) feeds ``plan.explain()``'s padding-flops line.
    """
    bq = _is_quant(b)
    bank = b["q"] if bq else b
    spec = GemmSpec(
        a_dtype=_dtname(a.dtype),
        b_dtype="int8" if bq else _dtname(bank.dtype),
        b_quant=bq, grouped=True,
        epilogue=Epilogue.from_args(bias, activation, None, None),
        out_dtype=None if out_dtype is None else _dtname(out_dtype),
        tile=tile)
    pl = plan(spec, gemm_grouped_shapes(a, b, dense_rows))
    return execute(pl, a, b, bias=bias, group_sizes=group_sizes)
