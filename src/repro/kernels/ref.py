"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel test sweeps and double as the
CPU execution path: ``ops.py`` dispatches to these (identical math) when
not running on TPU, so models are bit-for-bit testable on the host.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30          # large-negative for masking (bf16-safe)


def gemm_ref(a: jax.Array, b: jax.Array, *,
             acc_dtype=jnp.float32, out_dtype=None) -> jax.Array:
    """C = A @ B with explicit accumulation dtype.

    int8 x int8 accumulates in int32 (the paper's int8 GEMM semantics:
    8-bit operands, 32-bit accumulation); floats accumulate in fp32.

    REPRO_BF16_REDUCE=1 (experiment, default off): bf16 GEMMs emit bf16
    dot outputs, so GSPMD's cross-shard partial-sum all-reduces move
    bf16 instead of f32 — the Megatron convention.  Per-shard K-tiles
    still accumulate fp32 inside the MXU; the cross-shard sum is what
    drops precision.  See EXPERIMENTS.md §Perf.
    """
    if a.dtype == jnp.int8 and b.dtype == jnp.int8:
        acc_dtype = jnp.int32
    import os
    if (os.environ.get("REPRO_BF16_REDUCE") == "1"
            and a.dtype == jnp.bfloat16 and b.dtype == jnp.bfloat16
            and out_dtype is not None
            and jnp.dtype(out_dtype) == jnp.bfloat16):
        return jnp.dot(a, b, preferred_element_type=jnp.bfloat16)
    # operands stay at their storage dtype — pre-casting to the
    # accumulator dtype would materialize full-width fp32 copies of both
    # operands in HBM (the lm_head chunked-xent hot path pays k*V of it);
    # preferred_element_type alone gets fp32 MXU accumulation for free
    out = jnp.dot(a, b, preferred_element_type=acc_dtype)
    return out.astype(out_dtype or acc_dtype)


def quantize_int8(x: jax.Array, axis: int = -1):
    """Symmetric per-channel int8 quantization -> (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def gemm_fused_ref(a: jax.Array, b_q: jax.Array, b_scale: jax.Array,
                   *, out_dtype=None) -> jax.Array:
    """Oracle for the fused weight-dequant kernels: B stays int8 through
    the dot, the per-output-channel fp32 scale is applied once to the
    accumulator (W8A16: f32 accumulation; W8A8: int8 operands, int32
    accumulation — the paper's scheme).  b_scale: (1, n)."""
    if a.dtype == jnp.int8:
        acc = jnp.dot(a, b_q, preferred_element_type=jnp.int32)
        out = acc.astype(jnp.float32) * b_scale
    else:
        acc = jnp.dot(a.astype(jnp.float32), b_q.astype(jnp.float32),
                      preferred_element_type=jnp.float32)
        out = acc * b_scale
    return out.astype(out_dtype or jnp.float32)


def _acc_f32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Accumulate A @ B into fp32, mirroring the kernels: int8 x int8
    accumulates int32 then widens; a float A sees an in-register-cast B
    (W8A16) and accumulates fp32."""
    if a.dtype == jnp.int8 and b.dtype == jnp.int8:
        return jnp.dot(a, b,
                       preferred_element_type=jnp.int32) \
            .astype(jnp.float32)
    if b.dtype == jnp.int8:
        b = b.astype(a.dtype)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def gemm_epilogue_ref(a: jax.Array, b: jax.Array, *,
                      b_scale: Optional[jax.Array] = None,
                      bias: Optional[jax.Array] = None,
                      activation: Optional[str] = None,
                      residual: Optional[jax.Array] = None,
                      out_scale: Optional[jax.Array] = None,
                      out_dtype=None) -> jax.Array:
    """Oracle for the fused-epilogue kernel flush: accumulate, apply the
    optional per-output-channel dequant scale, then
    bias -> activation -> residual -> output quantization, all in fp32,
    exactly like the kernels' last-k/final-chunk bodies."""
    from repro.kernels.epilogue import apply_epilogue
    x = _acc_f32(a, b)
    if b_scale is not None:
        x = x * b_scale.astype(jnp.float32)
    x = apply_epilogue(x, activation=activation, bias=bias,
                       residual=residual, out_scale=out_scale)
    if out_dtype is None:
        out_dtype = jnp.int8 if out_scale is not None else jnp.float32
    return x.astype(out_dtype)


def gemm_gated_ref(a: jax.Array, b_gate: jax.Array, b_up: jax.Array, *,
                   activation: str = "silu",
                   bg_scale: Optional[jax.Array] = None,
                   bu_scale: Optional[jax.Array] = None,
                   out_dtype=None) -> jax.Array:
    """Oracle for the dual-B gated kernel:
    ``act(A @ B_gate) * (A @ B_up)`` with fp32 gate math (per-output-
    channel dequant scales applied to each accumulator first)."""
    from repro.kernels.epilogue import ACTIVATIONS
    xg = _acc_f32(a, b_gate)
    xu = _acc_f32(a, b_up)
    if bg_scale is not None:
        xg = xg * bg_scale.astype(jnp.float32)
        xu = xu * bu_scale.astype(jnp.float32)
    out = ACTIVATIONS[activation](xg) * xu
    if out_dtype is None:
        out_dtype = a.dtype if a.dtype != jnp.int8 else jnp.float32
    return out.astype(out_dtype)


def gemm_grouped_ref(a: jax.Array, b: jax.Array, group_sizes: jax.Array,
                     *, b_scale: Optional[jax.Array] = None,
                     bias: Optional[jax.Array] = None,
                     activation: Optional[str] = None,
                     out_dtype=None) -> jax.Array:
    """Oracle (and CPU dispatch path) for the grouped ragged GEMM:
    ``C[r] = epilogue(A[r] @ B[g(r)])`` with ``g(r)`` the group owning
    row ``r`` of the group-sorted ``a`` under ``group_sizes``.

    One full (m, k) x (k, n) dot per group with the foreign rows
    select-masked out — O(E) sequential dots, O(m*n) live memory, no
    (E, capacity, d) padding buffer.  Rows at and beyond
    ``sum(group_sizes)`` come back zero.  ``b_scale`` / ``bias``:
    per-expert (E, 1, n).  Same accumulation semantics as the kernels
    (fp32 MXU accumulation, W8A16 in-register widening) but at full-k
    dot granularity — allclose to the tiled kernel, bitwise only when
    the tile covers the problem (``gemm_grouped_blocked_ref`` replays
    the exact tile order for the bitwise sweeps).
    """
    from repro.kernels.epilogue import apply_epilogue
    m, _ = a.shape
    e, _, n = b.shape
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    rows = jnp.arange(m, dtype=jnp.int32)
    gid = jnp.minimum(jnp.searchsorted(ends, rows, side="right"),
                      e - 1).astype(jnp.int32)
    live = rows < ends[-1]

    def group(g, acc):
        z = _acc_f32(a, b[g])
        if b_scale is not None:
            z = z * b_scale[g].astype(jnp.float32)
        return jnp.where((gid == g)[:, None], z, acc)

    z = jax.lax.fori_loop(0, e, group, jnp.zeros((m, n), jnp.float32))
    z = apply_epilogue(z, activation=activation,
                       bias=bias[gid, 0] if bias is not None else None)
    z = jnp.where(live[:, None], z, 0.0)
    return z.astype(out_dtype or jnp.float32)


def gemm_int8_ref(a_q: jax.Array, b_q: jax.Array,
                  a_scale: jax.Array, b_scale: jax.Array,
                  out_dtype=jnp.float32) -> jax.Array:
    """Quantized GEMM: int8 operands, int32 accumulate, fused dequant.

    a_scale: (m, 1) per-row; b_scale: (1, n) per-column.
    """
    acc = jnp.dot(a_q, b_q, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * b_scale).astype(out_dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, pos: jax.Array, *,
                         window: int = 0,
                         scale: Optional[float] = None) -> jax.Array:
    """Single-token attention over a cache (flash_decode oracle).

    q: (b, hq, d); caches: (b, S, hkv, d); pos: (b,) int32 per-slot
    positions just written (a scalar broadcasts) — row i masks slots
    > pos[i]; a sliding window masks slots <= pos[i] - window.
    Returns (b, hq, d); softmax in fp32.
    """
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, groups, d).astype(jnp.float32) * scale
    kf = k_cache.astype(jnp.float32)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, kf)
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    k_pos = jnp.arange(skv)
    mask = k_pos[None, :] <= posv[:, None]
    if window > 0:
        mask &= k_pos[None, :] > posv[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs,
                     v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def _window_mask(q_len: int, kv_len: int, *, causal: bool,
                 window: int, q_offset: int) -> jax.Array:
    """(q_len, kv_len) boolean mask.  ``window`` <= 0 means unbounded.
    ``q_offset`` places the query block inside the full sequence (for
    decode, q_offset = kv_len - q_len)."""
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    k_pos = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    return mask


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  q_offset: Optional[int] = None,
                  scale: Optional[float] = None) -> jax.Array:
    """Reference multi-head attention with GQA + sliding window.

    q: (b, sq, hq, d); k, v: (b, skv, hkv, d) with hq % hkv == 0.
    Softmax in fp32.  ``window`` is the sliding-attention width (tokens a
    query may look back, itself included); 0 = full attention.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    groups = hq // hkv
    if q_offset is None:
        q_offset = skv - sq
    scale = scale if scale is not None else d ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, groups, axis=2)
    vf = jnp.repeat(vf, groups, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    mask = _window_mask(sq, skv, causal=causal, window=window,
                        q_offset=q_offset)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
