"""The attention Spec→Plan→Execute API — the GEMM framework applied to
the second hot-spot.

Mirrors :mod:`repro.kernels.api` exactly: a frozen, hashable
:class:`AttnSpec` describes *what* attention is being asked for
(prefill vs decode vs paged-decode, causal/window, GQA ratio,
per-operand dtypes, the future KV-quant hook); :func:`attn_plan`
resolves it at concrete shapes into an :class:`AttnPlan` — the kernel
family (``flash_attention`` / ``attention_blocked`` / ``flash_decode``
/ ``flash_decode_paged`` / the XLA reference paths) **and** its block
sizes, chosen from the same :mod:`repro.core.memory_model` VMEM-fit and
:mod:`repro.core.bandwidth` HBM-billing machinery the GEMM DSE uses
(decode KV streams billed at per-row true positions and page-rounded
pool reads via :func:`repro.core.bandwidth.decode_kv_bytes`); and
:func:`attn_execute` runs the plan through ONE generic
``jax.custom_vjp`` whose backward recomputes through the differentiable
reference composition — the Pallas flash kernels stay forward-only.

Plans are cached per (spec, shape, dispatch mode) with hit/miss
counters, emit ``attn.plan`` telemetry events with the full modeled
decision record, print themselves via :meth:`AttnPlan.explain` (what
``repro-dryrun --explain`` shows next to the GEMM plans), and — when
autotuning is enabled — route their block choice through the measured
top-K search in :mod:`repro.tune.autotune` and its persistent
``"attn|..."``-keyed cache namespace.

The pre-redesign entrypoints (``repro.kernels.ops.attention`` /
``decode_attention`` / ``decode_attention_paged``) live on as deprecated
shims delegating to the one-shot wrappers here.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import telemetry
from repro.core import bandwidth
from repro.core.hardware import TPU_V5E, TPUChip
from repro.core.memory_model import PIPELINE_STAGES, padded_tile_bytes
from repro.core.tiling import cdiv, dtype_bytes, round_up
from repro.kernels import ref as _ref
from repro.kernels.api import TunedInfo, _dtname, _float0, _mode
from repro.kernels.blocked_attention import attention_blocked
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_paged

#: above this many query/kv positions the unblocked reference would
#: materialize (b, h, sq, skv) scores; the planner switches the XLA
#: fallback family to the blocked path (moved here from kernels.ops)
BLOCKED_ATTN_THRESHOLD = 1024

#: fraction of VMEM a flash block choice may claim (matches the GEMM
#: ``fits_vmem`` headroom for the compiler's own needs)
VMEM_BUDGET_FRACTION = 0.75

_MODES = ("prefill", "decode", "decode_paged")

#: kernel families whose block sizes are free (and therefore tunable);
#: paged decode's kv block IS the page size, and the XLA reference
#: paths have no blocks at all
TUNABLE_KERNELS = ("flash_attention", "attention_blocked", "flash_decode")

_FLOAT_DTYPES = ("float32", "bfloat16", "float16")


# ---------------------------------------------------------------------------
# AttnSpec — the declarative problem description
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """What attention-family member is being asked for (shapes excluded
    — they arrive at :func:`attn_plan` time, so one spec serves every
    shape).

    * ``mode`` — ``prefill`` (q rows over dense k/v, training and
      prompt ingestion), ``decode`` (one token per slot over a dense
      cache + per-slot positions), or ``decode_paged`` (one token per
      slot over the shared page pool + per-slot page tables).
    * ``causal`` / ``window`` — the mask.  Decode is inherently causal;
      a sliding window is a causal look-back construct, so
      ``causal=False`` with ``window > 0`` is rejected.
    * ``group`` — the GQA ratio ``hq // hkv`` (1 = MHA; ``hkv == 1``
      at plan time makes it MQA).
    * ``q_dtype`` / ``kv_dtype`` — per-operand storage dtypes; both
      must be floating today.  ``kv_quant`` reserves the int8-KV hook
      (ROADMAP item) and raises until the quantized cache lands, so the
      flag can never silently mean "ignored".
    * ``bq`` / ``bkv`` — explicit block override, honored verbatim like
      ``GemmSpec(tile=)`` (an infeasible override raises instead of
      silently re-routing).  Rejected for ``decode_paged``: its kv
      block is the page size.
    * ``tune`` — per-spec autotune override (None = process/env
      switch, the same three-level rule as ``GemmSpec.tune``).
    """

    mode: str = "prefill"
    causal: bool = True
    window: int = 0
    group: int = 1
    q_dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"
    kv_quant: bool = False
    bq: Optional[int] = None
    bkv: Optional[int] = None
    tune: Optional[bool] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}, "
                             f"got {self.mode!r}")
        if self.window < 0:
            raise ValueError(f"window must be >= 0, got {self.window}")
        if self.group < 1:
            raise ValueError(f"group (GQA ratio) must be >= 1, "
                             f"got {self.group}")
        if self.mode != "prefill" and not self.causal:
            raise ValueError(f"{self.mode} attention is inherently "
                             "causal; causal=False is a prefill-only "
                             "(cross-attention) shape")
        if not self.causal and self.window:
            raise ValueError("a sliding window is a causal look-back "
                             "construct; window > 0 requires causal=True")
        for name, dt in (("q_dtype", self.q_dtype),
                         ("kv_dtype", self.kv_dtype)):
            if _dtname(dt) not in _FLOAT_DTYPES:
                raise ValueError(f"{name} must be floating "
                                 f"({_FLOAT_DTYPES}), got {dt!r}")
        if self.kv_quant:
            raise ValueError(
                "kv_quant is the forward-compat hook for the int8 KV "
                "cache (ROADMAP item) — not implemented yet")
        if self.mode == "decode_paged" and (self.bq or self.bkv):
            raise ValueError("decode_paged has no free blocks: the kv "
                             "block is the page size")
        if self.bq is not None and (self.bq < 8 or self.bq % 8):
            raise ValueError(f"bq must be a positive multiple of 8, "
                             f"got {self.bq}")
        if self.bkv is not None and (self.bkv < 128 or self.bkv % 128):
            raise ValueError(f"bkv must be a positive multiple of 128, "
                             f"got {self.bkv}")

    @property
    def key(self) -> str:
        """Canonical string id — starts with ``attn|`` so tuning-cache
        entries land in their own namespace next to the GEMM keys."""
        parts = [self.mode, "causal" if self.causal else "full"]
        if self.window:
            parts.append(f"w{self.window}")
        if self.group != 1:
            parts.append(f"g{self.group}")
        parts.append(f"{_dtname(self.q_dtype)}x{_dtname(self.kv_dtype)}")
        if self.kv_quant:
            parts.append("kvq")
        s = ":".join(parts)
        if self.bq is not None or self.bkv is not None:
            s += f"!{self.bq or 0}x{self.bkv or 0}"
        return "attn|" + s

    @classmethod
    def for_operands(cls, q, k, *, mode: str = "prefill",
                     causal: bool = True, window: int = 0,
                     **kw) -> "AttnSpec":
        """Spec inferred from live operands: GQA ratio and per-operand
        dtypes from the arrays, mask/mode from the keywords."""
        hq = q.shape[-2]
        hkv = k.shape[-2]
        if hkv == 0 or hq % hkv:
            raise ValueError(f"hq ({hq}) must be a multiple of "
                             f"hkv ({hkv})")
        return cls(mode=mode, causal=causal, window=window,
                   group=hq // hkv, q_dtype=_dtname(q.dtype),
                   kv_dtype=_dtname(k.dtype), **kw)


# ---------------------------------------------------------------------------
# AttnProblem — the cost-model view (flops + q/kv/o HBM traffic)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnProblem:
    """One attention problem at concrete shapes, as the cost model sees
    it: true-position flops and the q/kv/o HBM streams.  ``skv`` is the
    dense kv length (for ``decode_paged`` the gathered table extent
    ``max_pages * page_size``); ``page_size`` is 0 unless paged."""

    mode: str
    b: int
    sq: int
    skv: int
    hq: int
    hkv: int
    d: int
    q_dtype: str = "bfloat16"
    kv_dtype: str = "bfloat16"
    causal: bool = True
    window: int = 0
    page_size: int = 0

    # -- mask geometry ----------------------------------------------------
    def _row_extent(self, i: int) -> Tuple[int, int]:
        """[lo, hi) kv positions query row ``i`` attends to (billing
        default: the row block sits at the *end* of the kv sequence,
        ``q_offset = skv - sq`` — the decode/prefill contract)."""
        if not self.causal:
            return 0, self.skv
        hi = min(self.skv, self.skv - self.sq + i + 1)
        lo = max(0, hi - self.window) if self.window > 0 else 0
        return lo, max(hi, 0)

    def attended(self) -> int:
        """True attended kv positions summed over every (batch, q row)
        — the per-row true-position accounting the paged-KV billing
        introduced, applied to flops.  Paged decode rounds up to whole
        pages: the kernel executes every token of a touched page."""
        if self.mode == "prefill":
            per_batch = sum(hi - lo for lo, hi in
                            (self._row_extent(i) for i in range(self.sq)))
            return self.b * per_batch
        hi = self.skv                       # worst case: cache full
        if self.page_size > 0:
            return self.b * cdiv(hi, self.page_size) * self.page_size
        if self.window > 0:
            return self.b * min(hi, self.window)
        return self.b * hi

    # -- flops ------------------------------------------------------------
    @property
    def flops(self) -> float:
        """QK^T + PV: 2 GEMMs of (rows x attended x d) per head."""
        return 4.0 * self.hq * self.d * float(self.attended())

    # -- HBM streams ------------------------------------------------------
    @property
    def q_bytes(self) -> int:
        return self.b * self.sq * self.hq * self.d \
            * dtype_bytes(self.q_dtype)

    @property
    def o_bytes(self) -> int:
        return self.q_bytes                 # output written at q dtype

    def decode_positions(self) -> list:
        """The worst-case per-slot positions the static plan bills at —
        a full cache.  Serve telemetry re-bills with live positions
        through the same :func:`bandwidth.decode_kv_bytes`."""
        return [self.skv - 1] * self.b

    def kv_bytes(self, bq: Optional[int] = None) -> int:
        """Modeled HBM bytes of the k+v streams.

        * decode / decode_paged: one pass over the live cache, billed by
          :func:`repro.core.bandwidth.decode_kv_bytes` — per-row true
          positions, window-clamped dense rows, page-rounded pool reads.
        * prefill flash/blocked: k/v blocks are re-streamed once per
          *query head* per q-block row (the grid walks b*hq rows of
          q blocks), and a causal/windowed row block only reads its
          attended kv extent — so a larger ``bq`` genuinely cuts
          traffic, which is what gives the block DSE a gradient.
        """
        if self.mode != "prefill":
            return int(bandwidth.decode_kv_bytes(
                self.decode_positions(), n_kv_heads=self.hkv,
                head_dim=self.d, dtype=self.kv_dtype,
                window=self.window,
                page_size=self.page_size or None))
        per_tok = 2 * self.d * dtype_bytes(self.kv_dtype)   # k + v
        if bq is None:                      # single pass (XLA reference)
            return self.b * self.hkv * self.skv * per_tok
        toks = 0
        for j0 in range(0, self.sq, bq):
            rows = range(j0, min(self.sq, j0 + bq))
            exts = [self._row_extent(i) for i in rows]
            lo = min(e[0] for e in exts)
            hi = max(e[1] for e in exts)
            toks += max(0, hi - lo)
        return self.b * self.hq * toks * per_tok

    def logits_bytes(self) -> int:
        """The (b, hq, rows, skv) fp32 score round-trip the *unblocked*
        XLA reference materializes (write + softmax read) — the cost the
        flash/blocked families exist to avoid."""
        return 2 * self.b * self.hq * self.sq * self.skv * 4


def attn_traffic(p: AttnProblem, kernel: str,
                 bq: Optional[int], bkv: Optional[int],
                 chip: TPUChip = TPU_V5E) -> bandwidth.TrafficEstimate:
    """Roofline estimate for one (kernel family, blocks) choice —
    same :class:`~repro.core.bandwidth.TrafficEstimate` contract (and
    the same calibration-aware :func:`~repro.core.bandwidth.
    effective_rates`) as the GEMM estimator."""
    hbm = float(p.q_bytes + p.o_bytes)
    if kernel in ("flash_attention", "attention_blocked"):
        hbm += p.kv_bytes(bq or p.sq)
    elif kernel == "xla_ref":
        hbm += p.kv_bytes(None) + p.logits_bytes()
    elif kernel == "xla_decode":
        hbm += p.kv_bytes() + p.logits_bytes()
    elif kernel == "xla_decode_paged":
        # gather materializes a dense copy of the table extent, then the
        # dense path reads it back: pool read + dense write + dense read
        hbm += 3 * p.kv_bytes() + p.logits_bytes()
    else:                                   # flash decode families
        hbm += p.kv_bytes()
    flops = p.flops
    peak, bw = bandwidth.effective_rates(chip, int8=False)
    t_c = flops / peak
    t_m = hbm / bw
    return bandwidth.TrafficEstimate(
        hbm_bytes=hbm, flops=flops, t_compute=t_c, t_memory=t_m,
        arithmetic_intensity=flops / hbm if hbm else 0.0)


# ---------------------------------------------------------------------------
# VMEM footprint of one block choice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnVmemFootprint:
    """Per-block VMEM bytes of the flash kernels' working set (the XLA
    families report zeros — the compiler manages their buffers)."""

    q_bytes: int
    kv_bytes: int
    o_bytes: int
    scratch_bytes: int

    @property
    def total(self) -> int:
        return (self.q_bytes + self.kv_bytes + self.o_bytes
                + self.scratch_bytes)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self) | {"total": self.total}


def attn_vmem_footprint(p: AttnProblem, kernel: str,
                        bq: Optional[int], bkv: Optional[int],
                        chip: TPUChip = TPU_V5E) -> AttnVmemFootprint:
    """Double-buffered q/k/v streams + the online-softmax scratch
    ((rows, lane) running max/denominator pair and the fp32
    accumulator), via the same ``padded_tile_bytes`` physical-padding
    rule the GEMM footprint uses."""
    if kernel.startswith("xla"):
        return AttnVmemFootprint(0, 0, 0, 0)
    dp = round_up(p.d, chip.lane)
    if kernel in ("flash_attention", "attention_blocked"):
        rows = bq or min(p.sq, 512)
        kv_rows = bkv or min(p.skv, 512)
    else:                                   # decode families
        rows = max(8, round_up(p.hq // p.hkv, 8))
        kv_rows = (round_up(p.page_size, 8) if p.page_size
                   else (bkv or 512))
    q = PIPELINE_STAGES * padded_tile_bytes(rows, dp, p.q_dtype, chip)
    kv = 2 * PIPELINE_STAGES * padded_tile_bytes(kv_rows, dp,
                                                 p.kv_dtype, chip)
    o = padded_tile_bytes(rows, dp, p.q_dtype, chip)
    scratch = (2 * padded_tile_bytes(rows, chip.lane, "float32", chip)
               + padded_tile_bytes(rows, dp, "float32", chip))
    return AttnVmemFootprint(q, kv, o, scratch)


def _fits(vmem: AttnVmemFootprint, chip: TPUChip = TPU_V5E) -> bool:
    return vmem.total <= VMEM_BUDGET_FRACTION * chip.vmem_bytes


# ---------------------------------------------------------------------------
# Kernel-family + block-size DSE
# ---------------------------------------------------------------------------

class AttnBlockDesign(NamedTuple):
    """One ranked (blocks, modeled cost) candidate from the block DSE."""

    bq: Optional[int]
    bkv: Optional[int]
    traffic: bandwidth.TrafficEstimate
    vmem: AttnVmemFootprint


def _pow2_cap(x: int, floor: int) -> int:
    """The kernels' internal block clamp: never exceed the next power of
    two of the dimension (floored at the hardware minimum)."""
    return max(floor, 1 << max(0, int(x) - 1).bit_length())


def _choose_kernel(spec: AttnSpec, p: AttnProblem,
                   dispatch: str) -> Tuple[str, Optional[str]]:
    """(kernel family, fallback_reason) — the dispatch decision the
    legacy if/else made, lifted into the plan with the silent
    pallas→XLA fallback made loud via ``fallback_reason``."""
    pallas = dispatch in ("pallas", "interpret")
    if spec.mode == "decode":
        return ("flash_decode" if pallas else "xla_decode"), None
    if spec.mode == "decode_paged":
        return (("flash_decode_paged" if pallas
                 else "xla_decode_paged"), None)
    if pallas and p.sq >= 128:
        return "flash_attention", None
    fam = ("attention_blocked"
           if max(p.sq, p.skv) > BLOCKED_ATTN_THRESHOLD else "xla_ref")
    fallback = None
    if pallas:
        fallback = (f"flash_attention needs sq >= 128 (got sq={p.sq}); "
                    f"falling back to {fam}")
    return fam, fallback


def _block_candidates(kernel: str, p: AttnProblem
                      ) -> Tuple[Tuple[Optional[int], Optional[int]], ...]:
    """Deduped (bq, bkv) candidates, kernel defaults first — modeled
    ties (decode traffic is bkv-invariant) resolve to the default, and
    the measured tuner is the authority beyond that."""
    if kernel == "flash_attention":
        bq_cap = _pow2_cap(p.sq, 8)
        bkv_cap = _pow2_cap(p.skv, 128)
        raw = [(bq, bkv)
               for bq in (512, 1024, 256, 128)
               for bkv in (512, 1024, 256, 128)]
        clamp = [(min(bq, bq_cap), min(bkv, bkv_cap)) for bq, bkv in raw]
    elif kernel == "attention_blocked":
        raw = [(bq, bkv)
               for bq in (512, 1024, 256)
               for bkv in (1024, 2048, 512)]
        clamp = [(min(bq, round_up(p.sq, 8)),
                  min(bkv, round_up(p.skv, 128)))
                 for bq, bkv in raw]
    elif kernel == "flash_decode":
        cap = _pow2_cap(p.skv, 128)
        clamp = [(None, min(bkv, cap))
                 for bkv in (512, 1024, 2048, 256, 128)]
    else:       # paged (block = page size) and the XLA families
        return ((None, None),)
    out, seen = [], set()
    for c in clamp:
        if c not in seen:
            seen.add(c)
            out.append(c)
    return tuple(out)


def attn_solve_topk(spec: AttnSpec, shapes: Tuple[int, ...],
                    k: int = 5) -> Tuple[AttnBlockDesign, ...]:
    """The ranked analytic block candidates the autotuner sweeps —
    VMEM-fitting (bq, bkv) choices for the kernel family the dispatch
    mode resolves to, best modeled roofline time first (stable: ties
    keep the kernel-default ordering)."""
    p = _problem_for(spec, shapes)
    kernel, _ = _choose_kernel(spec, p, _mode())
    designs = []
    for bq, bkv in _block_candidates(kernel, p):
        vmem = attn_vmem_footprint(p, kernel, bq, bkv)
        if kernel in ("flash_attention", "flash_decode") \
                and not _fits(vmem):
            continue
        designs.append(AttnBlockDesign(
            bq, bkv, attn_traffic(p, kernel, bq, bkv), vmem))
    designs.sort(key=lambda d: d.traffic.t_model)
    return tuple(designs[:max(int(k), 1)])


# ---------------------------------------------------------------------------
# AttnPlan + the (spec, shape, dispatch-mode)-keyed plan cache
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnPlan:
    """One resolved attention execution decision: spec x shapes x
    dispatch mode -> kernel family, blocks, and the modeled costs.
    Frozen/hashable so it rides the single custom VJP as a static
    argument."""

    spec: AttnSpec
    b: int
    sq: int
    skv: int
    hq: int
    hkv: int
    d: int
    page_size: int                   # 0 unless decode_paged
    max_pages: int                   # 0 unless decode_paged
    dispatch: str                    # pallas | interpret | ref at plan time
    kernel: str
    bq: Optional[int]
    bkv: Optional[int]
    problem: AttnProblem
    traffic: bandwidth.TrafficEstimate
    vmem: AttnVmemFootprint
    fallback_reason: Optional[str] = None
    tuned: Optional[TunedInfo] = None

    @property
    def flops(self) -> float:
        return self.traffic.flops

    @property
    def hbm_bytes(self) -> float:
        return self.traffic.hbm_bytes

    @property
    def vmem_bytes(self) -> int:
        return self.vmem.total

    @property
    def source(self) -> str:
        return "tuned" if self.tuned is not None else "analytic"

    @property
    def shape_key(self) -> str:
        if self.spec.mode == "decode_paged":
            return (f"b{self.b}xp{self.max_pages}x{self.page_size}x"
                    f"h{self.hq}/{self.hkv}xd{self.d}")
        if self.spec.mode == "decode":
            return (f"b{self.b}xS{self.skv}x"
                    f"h{self.hq}/{self.hkv}xd{self.d}")
        return (f"b{self.b}x{self.sq}x{self.skv}x"
                f"h{self.hq}/{self.hkv}xd{self.d}")

    @property
    def grid(self) -> Tuple[int, ...]:
        if self.kernel == "flash_attention":
            return (self.b * self.hq, cdiv(self.sq, self.bq or self.sq),
                    cdiv(self.skv, self.bkv or self.skv))
        if self.kernel == "attention_blocked":
            return (cdiv(self.sq, self.bq or self.sq),
                    cdiv(self.skv, self.bkv or self.skv))
        if self.kernel == "flash_decode":
            return (self.b * self.hkv,
                    cdiv(self.skv, self.bkv or self.skv))
        if self.kernel == "flash_decode_paged":
            return (self.b * self.hkv, self.max_pages)
        return ()

    def explain(self) -> str:
        """Human-readable decision record, the attention analogue of
        ``GemmPlan.explain()``."""
        t = self.traffic
        mib = 2 ** 20
        lines = [f"AttnPlan {self.spec.key} {self.shape_key} "
                 f"[{self.dispatch}]"]
        grid = "x".join(str(g) for g in self.grid) or "-"
        lines.append(f"  kernel   : {self.kernel} (grid {grid})")
        lines.append(f"  blocks   : bq={self.bq or '-'} "
                     f"bkv={self.bkv or '-'}"
                     + (f" page={self.page_size}" if self.page_size
                        else ""))
        if self.vmem.total:
            budget = VMEM_BUDGET_FRACTION * TPU_V5E.vmem_bytes
            lines.append(
                f"  vmem     : {self.vmem.total / mib:.2f} MiB of "
                f"{budget / mib:.0f} MiB budget "
                f"(q {self.vmem.q_bytes / mib:.2f}, "
                f"kv {self.vmem.kv_bytes / mib:.2f}, "
                f"scratch {self.vmem.scratch_bytes / mib:.2f})")
        else:
            lines.append("  vmem     : XLA-managed")
        kv = t.hbm_bytes - self.problem.q_bytes - self.problem.o_bytes
        pos_note = (" (page-rounded)" if self.page_size
                    else " (true positions)"
                    if self.spec.mode != "prefill" else "")
        lines.append(
            f"  hbm      : {t.hbm_bytes / mib:.2f} MiB "
            f"(q {self.problem.q_bytes / mib:.2f}, "
            f"kv {kv / mib:.2f}{pos_note}, "
            f"o {self.problem.o_bytes / mib:.2f})")
        lines.append(
            f"  roofline : {t.bound}-bound, "
            f"{t.t_model * 1e6:.1f} us modeled "
            f"(AI {t.arithmetic_intensity:.1f} flop/B, "
            f"{t.flops / 1e9:.2f} GFLOP)")
        if self.tuned is not None:
            tu = self.tuned
            src = "cache" if tu.from_cache else f"K={tu.k_searched} sweep"
            lines.append(
                f"  source   : tuned ({tu.t_measured_us:.1f} us measured"
                f" ±{tu.spread:.2f}, {src})")
        else:
            lines.append("  source   : analytic")
        if self.fallback_reason:
            lines.append(f"  fallback : {self.fallback_reason}")
        return "\n".join(lines)


class AttnPlanCacheInfo(NamedTuple):
    entries: int
    hits: int
    misses: int


_plan_cache: dict = {}
_executed: set = set()      # plan keys whose execute() already traced
_plan_hits = 0
_plan_misses = 0


def attn_plan_cache_info() -> AttnPlanCacheInfo:
    return AttnPlanCacheInfo(len(_plan_cache), _plan_hits, _plan_misses)


def attn_plan_cache_clear() -> None:
    """Drop every cached attention plan and zero the counters (tests
    that flip ``REPRO_KERNELS`` or monkeypatch kernels must call this —
    plans are dispatch-mode-scoped but stale monkeypatched resolutions
    would otherwise leak)."""
    global _plan_hits, _plan_misses
    _plan_cache.clear()
    _executed.clear()
    _plan_hits = 0
    _plan_misses = 0


def attn_plans() -> Tuple[AttnPlan, ...]:
    """Every attention plan resolved so far (insertion order) — what
    ``repro-dryrun --explain`` prints next to the GEMM plans."""
    return tuple(_plan_cache.values())


def _plan_event(pl: AttnPlan, cache: str) -> None:
    telemetry.counter(f"attn.plan_cache.{cache}").add(1)
    tuned = pl.tuned
    t_model_us = pl.traffic.t_model * 1e6
    telemetry.event(
        "attn.plan", cache=cache, spec=pl.spec.key, shape=pl.shape_key,
        dispatch=pl.dispatch, kernel=pl.kernel,
        bq=pl.bq, bkv=pl.bkv, page_size=pl.page_size or None,
        hbm_bytes=pl.hbm_bytes, vmem_bytes=pl.vmem_bytes,
        flops=pl.flops, t_model_us=t_model_us,
        bound=pl.traffic.bound, source=pl.source,
        t_measured_us=tuned.t_measured_us if tuned else None,
        measured_vs_model=(tuned.t_measured_us / t_model_us
                           if tuned and t_model_us else None),
        fallback_reason=pl.fallback_reason)


def _shape_fields(spec: AttnSpec, shapes: Tuple[int, ...]) -> dict:
    """Validated (b, sq, skv, hq, hkv, d, page_size, max_pages) from
    the per-mode canonical shape tuple:

    * prefill:      ``(b, sq, skv, hq, hkv, d)``
    * decode:       ``(b, skv, hq, hkv, d)``
    * decode_paged: ``(b, max_pages, page_size, hq, hkv, d)``
    """
    want = {"prefill": 6, "decode": 5, "decode_paged": 6}[spec.mode]
    if len(shapes) != want:
        raise ValueError(
            f"{spec.mode} shapes must be {want} ints "
            f"(got {len(shapes)}: {shapes})")
    s = tuple(int(x) for x in shapes)
    if any(x <= 0 for x in s):
        raise ValueError(f"shapes must be positive, got {s}")
    if spec.mode == "prefill":
        b, sq, skv, hq, hkv, d = s
        page_size = max_pages = 0
    elif spec.mode == "decode":
        b, skv, hq, hkv, d = s
        sq = 1
        page_size = max_pages = 0
    else:
        b, max_pages, page_size, hq, hkv, d = s
        sq = 1
        skv = max_pages * page_size
    if hq != hkv * spec.group:
        raise ValueError(
            f"hq ({hq}) != hkv ({hkv}) * spec.group ({spec.group})")
    return dict(b=b, sq=sq, skv=skv, hq=hq, hkv=hkv, d=d,
                page_size=page_size, max_pages=max_pages)


def _problem_for(spec: AttnSpec, shapes: Tuple[int, ...]) -> AttnProblem:
    f = _shape_fields(spec, shapes)
    return AttnProblem(
        mode=spec.mode, b=f["b"], sq=f["sq"], skv=f["skv"],
        hq=f["hq"], hkv=f["hkv"], d=f["d"],
        q_dtype=_dtname(spec.q_dtype), kv_dtype=_dtname(spec.kv_dtype),
        causal=spec.causal, window=spec.window,
        page_size=f["page_size"])


def _tune_enabled(spec: AttnSpec) -> bool:
    if spec.tune is not None:
        return spec.tune
    from repro.tune import autotune as _autotune
    return _autotune.is_enabled(None)


def _resolve(spec: AttnSpec, shapes: Tuple[int, ...]) -> AttnPlan:
    f = _shape_fields(spec, shapes)
    p = _problem_for(spec, shapes)
    dispatch = _mode()
    kernel, fallback = _choose_kernel(spec, p, dispatch)
    tuned = None
    bq = bkv = None
    if kernel in TUNABLE_KERNELS:
        if spec.bq is not None or spec.bkv is not None:
            # explicit override: honored verbatim, but an infeasible
            # block raises instead of silently re-routing
            cands = _block_candidates(kernel, p)
            bq = spec.bq if spec.bq is not None else cands[0][0]
            bkv = spec.bkv if spec.bkv is not None else cands[0][1]
            if kernel != "attention_blocked" \
                    and not _fits(attn_vmem_footprint(p, kernel, bq, bkv)):
                raise ValueError(
                    f"explicit blocks bq={bq} bkv={bkv} exceed the "
                    f"VMEM budget for {kernel} at {shapes}")
        else:
            if _tune_enabled(spec):
                # measured autotuning: persistent cache first, then a
                # top-K sweep; every degradation falls through to the
                # analytic ranking below — never an exception
                from repro import tune as _tune
                found = _tune.attn_lookup_or_search(spec, shapes, p)
                if found is not None:
                    (tq, tkv), tuned = found
                    fit = attn_vmem_footprint(p, kernel, tq, tkv)
                    if kernel == "attention_blocked" or _fits(fit):
                        bq, bkv = tq, tkv
                    else:
                        fallback = (
                            f"tuned blocks bq={tq} bkv={tkv} infeasible "
                            "here; re-resolved analytically")
                        tuned = None
            if bq is None and bkv is None:
                designs = attn_solve_topk(spec, shapes, k=1)
                if designs:
                    bq, bkv = designs[0].bq, designs[0].bkv
                else:       # nothing fits: smallest candidate, loudly
                    bq, bkv = _block_candidates(kernel, p)[-1]
                    fallback = ((fallback + "; ") if fallback else "") \
                        + "no block candidate fits VMEM"
    traffic = attn_traffic(p, kernel, bq, bkv)
    vmem = attn_vmem_footprint(p, kernel, bq, bkv)
    return AttnPlan(
        spec=spec, b=f["b"], sq=f["sq"], skv=f["skv"], hq=f["hq"],
        hkv=f["hkv"], d=f["d"], page_size=f["page_size"],
        max_pages=f["max_pages"], dispatch=dispatch, kernel=kernel,
        bq=bq, bkv=bkv, problem=p, traffic=traffic, vmem=vmem,
        fallback_reason=fallback, tuned=tuned)


def attn_plan(spec: AttnSpec, shapes: Tuple[int, ...]) -> AttnPlan:
    """Resolve (and cache) the execution decision for ``spec`` at the
    canonical ``shapes`` tuple (see :func:`_shape_fields` for the
    per-mode layout).  The cache key includes the dispatch mode —
    ``REPRO_KERNELS=pallas|interpret|ref`` resolve to different kernel
    families, so each gets its own entry."""
    global _plan_hits, _plan_misses
    key = (spec, tuple(int(x) for x in shapes), _mode())
    hit = _plan_cache.get(key)
    if hit is not None:
        _plan_hits += 1
        if telemetry.enabled():
            _plan_event(hit, "hit")
        return hit
    _plan_misses += 1
    resolved = _resolve(spec, shapes)
    _plan_cache[key] = resolved
    if telemetry.enabled():
        _plan_event(resolved, "miss")
    return resolved


# ---------------------------------------------------------------------------
# The XLA decode paths (moved from kernels.ops — the shims there now
# delegate to this module, so the implementations live with the plan)
# ---------------------------------------------------------------------------

def _decode_attention_xla(q, k_cache, v_cache, pos, *, window):
    """Head-grouped einsums with operands at storage dtype + fp32
    accumulation — casting the cache itself to f32 would materialize and
    rewrite a full-precision copy of the entire stacked cache every
    layer (measured 1.38 TB/step on deepseek decode_32k).

    ``pos``: (b,) per-slot positions (scalar broadcasts) — row i masks
    cache slots > pos[i], the continuous-batching contract."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    k_pos = jnp.arange(skv)
    mask = k_pos[None, :] <= posv[:, None]
    if window > 0:
        mask &= k_pos[None, :] > posv[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits, _ref.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def _decode_attention_paged_xla(q, k_pages, v_pages, page_table, pos, *,
                                window):
    """Reference paged decode: gather each row's pages back into a
    dense (b, max_pages * page_size, hkv, d) view and reuse the dense
    path.  Because the engine sizes tables so the gathered length
    equals the dense ``max_len``, the reductions see identical operand
    lengths and the result is bit-identical to the dense cache layout —
    the property the serve acceptance tests pin."""
    n_pages, ps, hkv, d = k_pages.shape
    b, max_pages = page_table.shape
    k = k_pages[page_table].reshape(b, max_pages * ps, hkv, d)
    v = v_pages[page_table].reshape(b, max_pages * ps, hkv, d)
    return _decode_attention_xla(q, k, v, pos, window=window)


# ---------------------------------------------------------------------------
# attn_execute — ONE generic custom VJP for the whole family
# ---------------------------------------------------------------------------

def _dispatch_attn(pl: AttnPlan, scale, q_offset, q, k, v, pos,
                   page_table):
    spec = pl.spec
    interp = pl.dispatch == "interpret"
    kern = pl.kernel
    if kern == "flash_attention":
        return flash_attention(
            q, k, v, causal=spec.causal, window=spec.window, scale=scale,
            q_offset=q_offset, bq=pl.bq, bkv=pl.bkv, interpret=interp)
    if kern == "attention_blocked":
        return attention_blocked(
            q, k, v, causal=spec.causal, window=spec.window, scale=scale,
            q_offset=q_offset, bq=pl.bq, bkv=pl.bkv)
    if kern == "xla_ref":
        return _ref.attention_ref(
            q, k, v, causal=spec.causal, window=spec.window, scale=scale,
            q_offset=q_offset)
    if kern == "flash_decode":
        return flash_decode(q, k, v, pos, window=spec.window,
                            bkv=pl.bkv, scale=scale, interpret=interp)
    if kern == "xla_decode":
        return _decode_attention_xla(q, k, v, pos, window=spec.window)
    if kern == "flash_decode_paged":
        return flash_decode_paged(q, k, v, page_table, pos,
                                  window=spec.window, scale=scale,
                                  interpret=interp)
    if kern == "xla_decode_paged":
        return _decode_attention_paged_xla(q, k, v, page_table, pos,
                                           window=spec.window)
    raise AssertionError(f"unknown kernel family {kern!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _attn_core(pl: AttnPlan, scale, q_offset, q, k, v, pos, page_table):
    """The whole attention family behind one VJP: forward dispatches on
    the plan's kernel; backward recomputes through the differentiable
    reference composition (the Pallas flash kernels are forward-only).
    ``pos``/``page_table`` are int data operands — float0 cotangents."""
    return _dispatch_attn(pl, scale, q_offset, q, k, v, pos, page_table)


def _attn_core_fwd(pl, scale, q_offset, q, k, v, pos, page_table):
    out = _attn_core(pl, scale, q_offset, q, k, v, pos, page_table)
    return out, (q, k, v, pos, page_table)


def _attn_core_bwd(pl, scale, q_offset, res, g):
    # Recompute backward: re-run the differentiable composition at the
    # saved inputs and pull the cotangent through it.  Long prefill
    # recomputes through the blocked path (lax.scan + checkpoint — no
    # (sq, skv) score materialization); short prefill through the plain
    # reference; decode through the head-grouped XLA einsums.
    q, k, v, pos, page_table = res
    spec = pl.spec
    if spec.mode == "prefill":
        if max(pl.sq, pl.skv) > BLOCKED_ATTN_THRESHOLD:
            def fwd(q, k, v):
                return attention_blocked(
                    q, k, v, causal=spec.causal, window=spec.window,
                    scale=scale, q_offset=q_offset,
                    bq=pl.bq or 512, bkv=pl.bkv or 1024)
        else:
            def fwd(q, k, v):
                return _ref.attention_ref(
                    q, k, v, causal=spec.causal, window=spec.window,
                    scale=scale, q_offset=q_offset)
        dq, dk, dv = jax.vjp(fwd, q, k, v)[1](g)
        return dq, dk, dv, None, None
    if spec.mode == "decode":
        def fwd(q, k, v):
            return _decode_attention_xla(q, k, v, pos,
                                         window=spec.window)
        dq, dk, dv = jax.vjp(fwd, q, k, v)[1](g)
        return dq, dk, dv, _float0(pos), None

    def fwd(q, k, v):
        return _decode_attention_paged_xla(q, k, v, page_table, pos,
                                           window=spec.window)
    dq, dk, dv = jax.vjp(fwd, q, k, v)[1](g)
    return dq, dk, dv, _float0(pos), _float0(page_table)


_attn_core.defvjp(_attn_core_fwd, _attn_core_bwd)


def _execute_event(pl: AttnPlan) -> None:
    if not telemetry.enabled():
        return
    ek = (pl.spec, pl.b, pl.sq, pl.skv, pl.hq, pl.d, pl.dispatch)
    if ek in _executed:
        return
    _executed.add(ek)
    telemetry.event("attn.execute", spec=pl.spec.key, shape=pl.shape_key,
                    kernel=pl.kernel, bq=pl.bq, bkv=pl.bkv,
                    hbm_bytes=pl.hbm_bytes, flops=pl.flops)


def attn_execute(pl: AttnPlan, q, k, v, *, pos=None, page_table=None,
                 scale: Optional[float] = None,
                 q_offset: Optional[int] = None):
    """Run a resolved plan on live operands.

    * prefill: ``attn_execute(pl, q, k, v[, scale=, q_offset=])`` with
      q (b, sq, hq, d) and k/v (b, skv, hkv, d);
    * decode: ``attn_execute(pl, q, k_cache, v_cache, pos=pos)`` with
      q (b, hq, d), caches (b, S, hkv, d), pos (b,) int32;
    * decode_paged: ``attn_execute(pl, q, k_pages, v_pages,
      page_table=tbl, pos=pos)`` with pools (n_pages, page_size, hkv, d)
      and tables (b, max_pages) int32.

    Operands that disagree with the plan's spec/shapes raise — a plan
    is a contract, not a hint.
    """
    spec = pl.spec
    if spec.mode == "prefill":
        want_q = (pl.b, pl.sq, pl.hq, pl.d)
        want_kv = (pl.b, pl.skv, pl.hkv, pl.d)
        if pos is not None or page_table is not None:
            raise ValueError("pos/page_table are decode-only operands")
    elif spec.mode == "decode":
        want_q = (pl.b, pl.hq, pl.d)
        want_kv = (pl.b, pl.skv, pl.hkv, pl.d)
        if pos is None:
            raise ValueError("decode plans require pos=")
        if page_table is not None:
            raise ValueError("page_table is a decode_paged operand")
    else:
        want_q = (pl.b, pl.hq, pl.d)
        want_kv = (None, pl.page_size, pl.hkv, pl.d)
        if pos is None or page_table is None:
            raise ValueError("decode_paged plans require pos= and "
                             "page_table=")
        if tuple(page_table.shape) != (pl.b, pl.max_pages):
            raise ValueError(
                f"page_table shape {tuple(page_table.shape)} != plan's "
                f"({pl.b}, {pl.max_pages})")
    if tuple(q.shape) != want_q:
        raise ValueError(f"q shape {tuple(q.shape)} != plan's {want_q}")
    for name, op in (("k", k), ("v", v)):
        got = tuple(op.shape)
        if got[1:] != want_kv[1:] or (want_kv[0] is not None
                                      and got[0] != want_kv[0]):
            raise ValueError(
                f"{name} shape {got} != plan's {want_kv}")
    if _dtname(q.dtype) != _dtname(spec.q_dtype):
        raise ValueError(f"q dtype {q.dtype} != spec q_dtype "
                         f"{spec.q_dtype}")
    if _dtname(k.dtype) != _dtname(spec.kv_dtype):
        raise ValueError(f"k dtype {k.dtype} != spec kv_dtype "
                         f"{spec.kv_dtype}")
    if spec.mode != "prefill" and (scale is not None
                                   or q_offset is not None):
        raise ValueError("scale/q_offset are prefill-only statics; "
                         "decode uses d**-0.5 at position pos")
    _execute_event(pl)
    return _attn_core(pl, scale, q_offset, q, k, v, pos, page_table)


# ---------------------------------------------------------------------------
# One-shot wrappers — what every model layer calls (identical dispatch:
# they build the spec and go through the same plan cache)
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: Optional[float] = None,
              q_offset: Optional[int] = None,
              tune: Optional[bool] = None,
              bq: Optional[int] = None,
              bkv: Optional[int] = None) -> jax.Array:
    """Planned multi-head attention with GQA + optional sliding window.
    q: (b, sq, hq, d); k/v: (b, skv, hkv, d) -> (b, sq, hq, d)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    spec = AttnSpec.for_operands(q, k, mode="prefill", causal=causal,
                                 window=window, tune=tune, bq=bq, bkv=bkv)
    pl = attn_plan(spec, (b, sq, skv, hq, hkv, d))
    return attn_execute(pl, q, k, v, scale=scale, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     tune: Optional[bool] = None,
                     bkv: Optional[int] = None) -> jax.Array:
    """Planned single-token attention over a dense KV cache.
    q: (b, hq, d); caches: (b, S, hkv, d); pos: (b,) int32 (a scalar
    broadcasts) -> (b, hq, d)."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    spec = AttnSpec.for_operands(q, k_cache, mode="decode",
                                 window=window, tune=tune, bkv=bkv)
    pl = attn_plan(spec, (b, skv, hq, hkv, d))
    return attn_execute(pl, q, k_cache, v_cache, pos=pos)


def decode_attention_paged(q, k_pages, v_pages, page_table, pos, *,
                           window: int = 0) -> jax.Array:
    """Planned single-token attention over the block-paged KV pool.
    q: (b, hq, d); pools: (n_pages, page_size, hkv, d); page_table:
    (b, max_pages) int32; pos: (b,) int32 -> (b, hq, d)."""
    b, hq, d = q.shape
    _, page_size, hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    spec = AttnSpec.for_operands(q, k_pages, mode="decode_paged",
                                 window=window)
    pl = attn_plan(spec, (b, max_pages, page_size, hq, hkv, d))
    return attn_execute(pl, q, k_pages, v_pages, page_table=page_table,
                        pos=pos)
