"""Output-stationary Pallas GEMM — the Versal AIE dataflow on TPU.

Paper mapping (SS IV-A): on Versal, each AIE core computes an MxKxN block
and adder trees reduce partial products across the Y (reduction) axis
*before* anything leaves the array, so each C element is written once.
The TPU analogue is an output-stationary kernel: grid (m, n, k) with k
innermost, partial sums held in a VMEM scratch accumulator (fp32 for
float operands, int32 for int8 — the paper's 8-bit operand / 32-bit
accumulation scheme), and the C block written on the last k step.

That last-k flush is also where the *epilogue* fuses: because the
accumulator is already resident on-chip, a per-output-channel bias, an
activation (silu/gelu/relu), a residual add and an optional int8 output
quantization run on the VMEM block before the single C write — the
unfused ``gemm -> XLA elementwise`` composition would instead round-trip
the full (m, n) intermediate through HBM.  The fused weight-dequant
``b_scale`` path composes: scale first, then the epilogue.

Block shapes come from the reuse-maximizing DSE (:mod:`repro.core.dse`),
the way the paper's U,V,W come from its IP solver.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import TileConfig
from repro.kernels import _compiler_params, acc_dtype
from repro.kernels.epilogue import apply_epilogue


def _gemm_aie_kernel(activation, has_scale, has_bias, has_res, has_oscale,
                     *refs):
    """One kernel body for every aie variant.  ``refs`` order follows the
    in_specs: a, b, [scale], [bias], [residual], [out_scale], then the
    output ref and the accumulator scratch."""
    it = iter(refs)
    a_ref, b_ref = next(it), next(it)
    s_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    osc_ref = next(it) if has_oscale else None
    o_ref, acc_ref = next(it), next(it)
    fused = (has_scale or has_bias or has_res or has_oscale
             or activation is not None)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    # W8A16 only: widen an int8 B in-register to A's dtype.  Any other
    # mismatch must not silently narrow (e.g. float B with int8 A).
    if b.dtype == jnp.int8 and a.dtype != jnp.int8:
        b = b.astype(a.dtype)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        x = acc_ref[...]
        if fused:
            x = x.astype(jnp.float32)
            if s_ref is not None:
                x = x * s_ref[...]
            x = apply_epilogue(
                x, activation=activation,
                bias=bias_ref[...] if bias_ref is not None else None,
                residual=res_ref[...] if res_ref is not None else None,
                out_scale=osc_ref[...] if osc_ref is not None else None)
        o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "activation", "interpret"))
def gemm_aie(a: jax.Array, b: jax.Array, *, tile: TileConfig,
             out_dtype=None, b_scale: Optional[jax.Array] = None,
             bias: Optional[jax.Array] = None,
             residual: Optional[jax.Array] = None,
             out_scale: Optional[jax.Array] = None,
             activation: Optional[str] = None,
             interpret: bool = False) -> jax.Array:
    """C[m,n] = epilogue(sum_k A[m,k] B[k,n]), output-stationary.

    Dims must be multiples of the tile (ops.py pads — the paper's
    zero-padding alignment, SS V-C2).

    ``b_scale`` (1, n) fp32 turns on the fused weight-dequant path: ``b``
    must then be int8, streamed into VMEM at one byte/element, and
    ``C[m,n] = b_scale[n] * sum_k A[m,k] Bq[k,n]`` with the scale applied
    on the last-k flush (int32 accumulation when A is int8 too).

    Epilogue operands, all applied on the flush (after ``b_scale``), in
    order: ``bias`` (1, n) add, ``activation`` in fp32, ``residual``
    (m, n) add, ``out_scale`` (1, 1) fp32 output quantization (divide,
    round, clip to [-127, 127]; pair with ``out_dtype=jnp.int8``).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    acc = acc_dtype(a.dtype)
    fused = (b_scale is not None or bias is not None or residual is not None
             or out_scale is not None or activation is not None)
    out_dtype = out_dtype or (jnp.float32 if fused else acc)
    grid = (m // bm, n // bn, k // bk)

    operands = [a, b]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
        pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
    ]
    if b_scale is not None:
        assert b.dtype == jnp.int8, b.dtype
        assert b_scale.shape == (1, n), (b_scale.shape, n)
        operands.append(b_scale.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l: (0, j)))
    if bias is not None:
        assert bias.shape == (1, n), (bias.shape, n)
        operands.append(bias.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, l: (0, j)))
    if residual is not None:
        assert residual.shape == (m, n), (residual.shape, (m, n))
        operands.append(residual)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)))
    if out_scale is not None:
        assert out_scale.shape == (1, 1), out_scale.shape
        operands.append(out_scale.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, l: (0, 0)))

    kernel = functools.partial(
        _gemm_aie_kernel, activation, b_scale is not None,
        bias is not None, residual is not None, out_scale is not None)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
