"""Output-stationary Pallas GEMM — the Versal AIE dataflow on TPU.

Paper mapping (SS IV-A): on Versal, each AIE core computes an MxKxN block
and adder trees reduce partial products across the Y (reduction) axis
*before* anything leaves the array, so each C element is written once.
The TPU analogue is an output-stationary kernel: grid (m, n, k) with k
innermost, partial sums held in a VMEM scratch accumulator (fp32 for
float operands, int32 for int8 — the paper's 8-bit operand / 32-bit
accumulation scheme), and the C block written on the last k step.

Block shapes come from the reuse-maximizing DSE (:mod:`repro.core.dse`),
the way the paper's U,V,W come from its IP solver.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import TileConfig
from repro.kernels import _compiler_params


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if in_dtype == jnp.int8 else jnp.float32


def _gemm_aie_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _gemm_aie_fused_kernel(a_ref, b_ref, s_ref, o_ref, acc_ref):
    """Fused-dequant body: int8 B blocks arrive in VMEM at one
    byte/element; the per-output-channel scale is applied once, on the
    final-k flush (the paper's 8-bit-operand / 32-bit-accumulate scheme
    when A is also int8; f32 accumulation of in-register-dequantized B
    for W8A16)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    if b.dtype != a.dtype:          # W8A16: in-register int8 -> a-dtype
        b = b.astype(a.dtype)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = (acc_ref[...].astype(jnp.float32)
                      * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "interpret"))
def gemm_aie(a: jax.Array, b: jax.Array, *, tile: TileConfig,
             out_dtype=None, b_scale: Optional[jax.Array] = None,
             interpret: bool = False) -> jax.Array:
    """C[m,n] = sum_k A[m,k] B[k,n], output-stationary.

    Dims must be multiples of the tile (ops.py pads — the paper's
    zero-padding alignment, SS V-C2).

    ``b_scale`` (1, n) fp32 turns on the fused weight-dequant path: ``b``
    must then be int8, streamed into VMEM at one byte/element, and
    ``C[m,n] = b_scale[n] * sum_k A[m,k] Bq[k,n]`` with the scale applied
    on the last-k flush (int32 accumulation when A is int8 too).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    acc = _acc_dtype(a.dtype)
    grid = (m // bm, n // bn, k // bk)
    if b_scale is None:
        out_dtype = out_dtype or acc
        return pl.pallas_call(
            _gemm_aie_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
                pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
            compiler_params=_compiler_params(
                dimension_semantics=("parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(a, b)
    assert b.dtype == jnp.int8, b.dtype
    assert b_scale.shape == (1, n), (b_scale.shape, n)
    out_dtype = out_dtype or jnp.float32
    return pl.pallas_call(
        _gemm_aie_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
            pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, b_scale.astype(jnp.float32))
