"""Declarative GEMM epilogue spec — the paper's in-array reduction,
extended past the flush.

Paper mapping (SS IV-A): on Versal the adder-tree cascade reduces partial
products *inside* the AIE array, so each C element leaves the fabric
exactly once.  The TPU analogue keeps the accumulator in VMEM scratch and
writes the C block on the last-k grid step — which makes that flush the
one place a bias add, an activation, a residual add, or an output
quantization can run for free: the accumulator is already on-chip in
fp32/int32, so fusing the epilogue there removes the full-width
intermediate that an unfused ``gemm -> XLA epilogue`` round-trips through
HBM.

An :class:`Epilogue` is a tiny declarative value object:

* it is **hashable** (frozen dataclass), so kernels can take it as a jit
  static argument and the DSE can key its solution cache on it;
* ``key`` serializes it into the canonical ``"bias+silu+res+q8"`` string
  that :class:`repro.core.tiling.GemmProblem` carries (keeping the cost
  model free of kernel imports in its cache signature);
* :func:`apply_epilogue` is the single shared implementation of the math
  — Pallas kernel bodies and the pure-jnp references both call it, so
  parity is structural, not coincidental.

Fixed application order (matching every model call site)::

    x (f32 accumulator, b_scale already applied)
      -> + bias            (per-output-channel, f32)
      -> activation        (silu | gelu | relu, f32)
      -> + residual        (same shape as C)
      -> / out_scale, round, clip   (optional int8 output quantization)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,        # tanh approximation, like the model layers
    "relu": jax.nn.relu,
}


@dataclasses.dataclass(frozen=True)
class Epilogue:
    """What the GEMM flush applies before the C block leaves VMEM."""

    bias: bool = False
    activation: Optional[str] = None     # "silu" | "gelu" | "relu"
    residual: bool = False
    out_quant: bool = False              # int8 output, caller-given scale

    def __post_init__(self):
        if self.activation is not None \
                and self.activation not in ACTIVATIONS:
            raise ValueError(f"unknown activation {self.activation!r}")

    def __bool__(self) -> bool:
        return (self.bias or self.activation is not None or self.residual
                or self.out_quant)

    @property
    def key(self) -> str:
        """Canonical string form (cost-model / cache key): e.g.
        ``"bias+silu+res"``; the empty epilogue serializes to ``""``."""
        parts = []
        if self.bias:
            parts.append("bias")
        if self.activation:
            parts.append(self.activation)
        if self.residual:
            parts.append("res")
        if self.out_quant:
            parts.append("q8")
        return "+".join(parts)

    @classmethod
    def parse(cls, key: str) -> "Epilogue":
        """Inverse of :attr:`key` (used by the cost model, which stores
        the epilogue as a plain string inside ``GemmProblem``)."""
        if not key:
            return cls()
        parts = key.split("+")
        act = [p for p in parts if p in ACTIVATIONS]
        if len(act) > 1:
            raise ValueError(f"multiple activations in {key!r}")
        known = set(act) | {"bias", "res", "q8"}
        bad = [p for p in parts if p not in known]
        if bad:
            raise ValueError(f"unknown epilogue terms {bad} in {key!r}")
        return cls(bias="bias" in parts,
                   activation=act[0] if act else None,
                   residual="res" in parts,
                   out_quant="q8" in parts)

    @classmethod
    def from_args(cls, bias=None, activation: Optional[str] = None,
                  residual=None, out_scale=None) -> "Epilogue":
        """Spec from the optional operand set an op-level call provides."""
        return cls(bias=bias is not None, activation=activation,
                   residual=residual is not None,
                   out_quant=out_scale is not None)


def apply_epilogue(x: jax.Array, *, activation: Optional[str] = None,
                   bias: Optional[jax.Array] = None,
                   residual: Optional[jax.Array] = None,
                   out_scale: Optional[jax.Array] = None) -> jax.Array:
    """The epilogue math, on an fp32 accumulator (block or full array).

    Shared by the Pallas kernel flush paths and the jnp references; the
    caller casts the result to the output dtype (int8 when ``out_scale``
    quantization is on).
    """
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    if activation is not None:
        x = ACTIVATIONS[activation](x)
    if residual is not None:
        x = x + residual.astype(jnp.float32)
    if out_scale is not None:
        x = jnp.clip(jnp.round(x / out_scale.astype(jnp.float32)),
                     -127, 127)
    return x
