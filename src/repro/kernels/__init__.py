# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

"""Pallas-TPU version compat.

The TPU compiler-params dataclass was renamed across jax releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); resolve
whichever the pinned toolchain ships so every kernel builds on both.
"""


def _compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under either name."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def acc_dtype(in_dtype):
    """The paper's accumulation rule, shared by every GEMM kernel:
    int8 operands accumulate in int32, floats in fp32."""
    import jax.numpy as jnp
    return jnp.int32 if in_dtype == jnp.int8 else jnp.float32
