# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.

"""Pallas-TPU version compat.

The TPU compiler-params dataclass was renamed across jax releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); resolve
whichever the pinned toolchain ships so every kernel builds on both.
"""


def _compiler_params(**kwargs):
    """``pltpu.CompilerParams(**kwargs)`` under either name."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams")
    return cls(**kwargs)
