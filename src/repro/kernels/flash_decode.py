"""Flash-decoding: single-token attention over a long KV cache (Pallas).

The decode_32k / long_500k serving shapes are pure memory-roofline: one
query token must attend over a 32k–524k cache, so the kernel's job is to
stream k/v through VMEM exactly once at their storage dtype with the
online-softmax state held in VMEM scratch.  The XLA reference path
materializes (b, h, S) logits and (on CPU) fp32 cache copies; this kernel
reads k/v blocks once and writes (groups, d) per kv head.

Grid: (b·hkv, S/bkv) with the kv-block dimension 'arbitrary' (sequential
accumulation).  GQA is handled by shaping the query block as
(groups, d) — the group dim rides the sublane axis, so MQA
(recurrentgemma, groups=16) and GQA (deepseek, groups=8) tile the MXU
without materializing repeated kv heads.  The per-slot positions enter
as a prefetched (b,) vector (`PrefetchScalarGridSpec`) indexed by the
grid's batch coordinate and used only for masking, so one compiled
kernel serves every decode step of a continuous batch — each row
attends at its own length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INF
from repro.kernels import _compiler_params

LANES = 128
SUBLANES = 8


def _flash_decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         scale: float, window: int, bkv: int,
                         kv_len: int, hkv: int):
    kvi = pl.program_id(1)

    @pl.when(kvi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-slot position: the prefetched (b,) vector indexed by this
    # program's batch coordinate — each row masks at its own length
    pos = pos_ref[pl.program_id(0) // hkv]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (gp, dp)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, dp)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (gp, bkv)

    gp = q.shape[0]
    k_pos = kvi * bkv + jax.lax.broadcasted_iota(jnp.int32, (gp, bkv), 1)
    mask = (k_pos <= pos) & (k_pos < kv_len)
    if window > 0:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                                # (gp, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha \
        + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kvi == pl.num_programs(1) - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "bkv", "scale", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 pos: jax.Array, *, window: int = 0, bkv: int = 512,
                 scale: float | None = None,
                 interpret: bool = False) -> jax.Array:
    """q: (b, hq, d) one token per slot; caches: (b, S, hkv, d);
    pos: (b,) int32 per-slot positions (a scalar broadcasts — the
    lockstep special case).

    Returns (b, hq, d).  Row i masks cache slots > pos[i] (and a sliding
    window when ``window`` > 0 — positions <= pos[i] - window are
    excluded).
    """
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)

    dp = max(LANES, ((d + LANES - 1) // LANES) * LANES)
    gp = max(SUBLANES, ((groups + SUBLANES - 1) // SUBLANES) * SUBLANES)
    bkv = min(bkv, max(128, 1 << (skv - 1).bit_length()))
    skv_p = ((skv + bkv - 1) // bkv) * bkv

    qt = q.reshape(b, hkv, groups, d)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, gp - groups), (0, dp - d)))
    kt = jnp.pad(k_cache, ((0, 0), (0, skv_p - skv), (0, 0),
                           (0, dp - d))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v_cache, ((0, 0), (0, skv_p - skv), (0, 0),
                           (0, dp - d))).transpose(0, 2, 1, 3)

    grid = (b * hkv, skv_p // bkv)

    def q_map(bh, kvi, pos_ref):
        return (bh // hkv, bh % hkv, 0, 0)

    def kv_map(bh, kvi, pos_ref):
        return (bh // hkv, bh % hkv, kvi, 0)

    kernel = functools.partial(
        _flash_decode_kernel, scale=scale, window=window, bkv=bkv,
        kv_len=skv, hkv=hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, dp), q_map),
            pl.BlockSpec((1, 1, bkv, dp), kv_map),
            pl.BlockSpec((1, 1, bkv, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dp), q_map),
        scratch_shapes=[
            pltpu.VMEM((gp, LANES), jnp.float32),    # running max
            pltpu.VMEM((gp, LANES), jnp.float32),    # running denom
            pltpu.VMEM((gp, dp), jnp.float32),       # accumulator
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, dp), q.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,)), qt, kt, vt)

    return out[:, :, :groups, :d].reshape(b, hq, d)


def _flash_decode_paged_kernel(pos_ref, tbl_ref, q_ref, k_ref, v_ref,
                               o_ref, m_ref, l_ref, acc_ref, *,
                               scale: float, window: int, ps: int,
                               ps_p: int, hkv: int):
    """Same online softmax as `_flash_decode_kernel`, but the kv block
    for grid step `pi` is whatever physical page the prefetched table
    names — the index_map did the gather, the kernel only re-derives
    the block's logical positions as `pi * ps + lane`."""
    pi = pl.program_id(1)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos = pos_ref[pl.program_id(0) // hkv]
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (gp, dp)
    k = k_ref[0, 0].astype(jnp.float32)                  # (ps_p, dp)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (gp, ps_p)

    gp = q.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (gp, ps_p), 1)
    k_pos = pi * ps + lane
    mask = (k_pos <= pos) & (lane < ps)
    if window > 0:
        mask &= k_pos > pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha \
        + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(pi == pl.num_programs(1) - 1)
    def _flush():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)) \
            .astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "window", "scale", "interpret"))
def flash_decode_paged(q: jax.Array, k_pages: jax.Array,
                       v_pages: jax.Array, page_table: jax.Array,
                       pos: jax.Array, *, window: int = 0,
                       scale: float | None = None,
                       interpret: bool = False) -> jax.Array:
    """Paged flash-decoding: the cache is a shared page pool.

    q: (b, hq, d) one token per slot; k_pages/v_pages:
    (n_pages, page_size, hkv, d) pool shared by every slot;
    page_table: (b, max_pages) int32 — row i's logical block `pi` lives
    in physical page `page_table[i, pi]`; pos: (b,) int32 per-slot
    positions.  Returns (b, hq, d).

    The table joins the per-slot positions as a second prefetched
    scalar operand: the kv BlockSpec index_map reads
    `tbl_ref[bh // hkv, pi]`, so the pipeline DMA fetches exactly the
    pages a row touches (`ceil((pos+1)/page_size)` of them matter;
    later blocks are masked).  When `page_size == bkv` the block
    accumulation order matches `flash_decode` exactly, so paged and
    dense outputs are bit-identical.
    """
    b, hq, d = q.shape
    n_pages, ps, hkv, _ = k_pages.shape
    _, max_pages = page_table.shape
    assert hq % hkv == 0
    groups = hq // hkv
    scale = float(scale if scale is not None else d ** -0.5)

    dp = max(LANES, ((d + LANES - 1) // LANES) * LANES)
    gp = max(SUBLANES, ((groups + SUBLANES - 1) // SUBLANES) * SUBLANES)
    ps_p = ((ps + SUBLANES - 1) // SUBLANES) * SUBLANES

    qt = q.reshape(b, hkv, groups, d)
    qt = jnp.pad(qt, ((0, 0), (0, 0), (0, gp - groups), (0, dp - d)))
    kt = jnp.pad(k_pages, ((0, 0), (0, ps_p - ps), (0, 0),
                           (0, dp - d))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v_pages, ((0, 0), (0, ps_p - ps), (0, 0),
                           (0, dp - d))).transpose(0, 2, 1, 3)

    grid = (b * hkv, max_pages)

    def q_map(bh, pi, pos_ref, tbl_ref):
        return (bh // hkv, bh % hkv, 0, 0)

    def kv_map(bh, pi, pos_ref, tbl_ref):
        return (tbl_ref[bh // hkv, pi], bh % hkv, 0, 0)

    kernel = functools.partial(
        _flash_decode_paged_kernel, scale=scale, window=window, ps=ps,
        ps_p=ps_p, hkv=hkv)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, gp, dp), q_map),
            pl.BlockSpec((1, 1, ps_p, dp), kv_map),
            pl.BlockSpec((1, 1, ps_p, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, dp), q_map),
        scratch_shapes=[
            pltpu.VMEM((gp, LANES), jnp.float32),    # running max
            pltpu.VMEM((gp, LANES), jnp.float32),    # running denom
            pltpu.VMEM((gp, dp), jnp.float32),       # accumulator
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, dp), q.dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,)),
      jnp.asarray(page_table, jnp.int32), qt, kt, vt)

    return out[:, :, :groups, :d].reshape(b, hq, d)
