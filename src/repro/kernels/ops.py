"""Legacy kernel entrypoints + the attention dispatch layer.

The GEMM family moved to the declarative planned API in
:mod:`repro.kernels.api` (``GemmSpec`` -> ``plan`` -> ``execute``,
re-exported as :mod:`repro.ops`): one spec describes operands /
quantization / epilogue / gating, one cached plan resolves the DSE tile
and modeled costs, one generic custom VJP executes it.  The four
pre-redesign entrypoints below (``gemm``, ``gemm_fused``, ``gemm_gated``,
``gemm_int8``) remain as thin deprecated shims that build the equivalent
spec and delegate — bit-identical results, plus a ``DeprecationWarning``
so stragglers surface under ``-W error::DeprecationWarning``.

Attention stays here (it is not part of the GEMM plan space): Pallas
flash kernels on TPU, blocked/reference XLA paths elsewhere, same
``REPRO_KERNELS`` mode contract as the GEMM layer.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.kernels import api
from repro.kernels import ref as _ref
from repro.kernels.api import _interpret, _mode, use_pallas  # noqa: F401
from repro.kernels.blocked_attention import attention_blocked
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode, flash_decode_paged


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use repro.ops "
        "(GemmSpec / plan / execute, or the one-shot repro.ops.gemm)",
        DeprecationWarning, stacklevel=3)


def gemm(a, b, *, strategy=None, tile=None, out_dtype=None):
    """Deprecated shim: C = A @ B through the planned GemmSpec API
    (``b`` may be a ``{"q", "scale"}`` int8 weight struct)."""
    _warn("gemm")
    return api.gemm(a, b, strategy=strategy, tile=tile,
                    out_dtype=out_dtype)


def gemm_fused(a, b, *, bias=None, activation=None, residual=None,
               out_scale=None, strategy=None, tile=None, out_dtype=None):
    """Deprecated shim: epilogue-fused GEMM through the planned API."""
    _warn("gemm_fused")
    return api.gemm(a, b, bias=bias, activation=activation,
                    residual=residual, out_scale=out_scale,
                    strategy=strategy, tile=tile, out_dtype=out_dtype)


def gemm_gated(a, b_gate, b_up, *, activation="silu", tile=None,
               out_dtype=None):
    """Deprecated shim: dual-B gated GEMM through the planned API."""
    _warn("gemm_gated")
    return api.gemm(a, b_gate, b2=b_up, activation=activation, tile=tile,
                    out_dtype=out_dtype)


def gemm_int8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
              tile=None):
    """Deprecated shim: raw int8 x int8 GEMM (int32 accumulation, scales
    applied outside) through the planned API."""
    _warn("gemm_int8")
    acc = api.gemm(a_q, b_q, tile=tile, out_dtype=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * b_scale).astype(out_dtype)


quantize_int8 = _ref.quantize_int8
dequantize = _ref.dequantize


# Above this many query/kv positions the unblocked reference would
# materialize (b, h, sq, skv) scores; switch to the blocked XLA path.
BLOCKED_ATTN_THRESHOLD = 1024


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale=None, q_offset=None) -> jax.Array:
    """Multi-head attention with GQA + optional sliding window.

    Dispatch: Pallas flash kernel on TPU for prefill/train-sized queries;
    blocked lax implementation (same tiling, XLA-lowerable — what the
    dry-run compiles) for long sequences elsewhere; plain reference for
    short ones.  Single-token decode stays on the fused XLA path in the
    model layer.
    """
    sq, skv = q.shape[1], k.shape[1]
    if use_pallas() and sq >= 128:
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset,
                               interpret=_interpret())
    if max(sq, skv) > BLOCKED_ATTN_THRESHOLD:
        return attention_blocked(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)


def _decode_attention_xla(q, k_cache, v_cache, pos, *, window):
    """Head-grouped einsums with operands at storage dtype + fp32
    accumulation — casting the cache itself to f32 would materialize and
    rewrite a full-precision copy of the entire stacked cache every
    layer (measured 1.38 TB/step on deepseek decode_32k).

    ``pos``: (b,) per-slot positions (scalar broadcasts) — row i masks
    cache slots > pos[i], the continuous-batching contract."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    k_pos = jnp.arange(skv)
    mask = k_pos[None, :] <= posv[:, None]
    if window > 0:
        mask &= k_pos[None, :] > posv[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits,
                       _ref.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """Single-token attention over a KV cache (serve_step hot-spot).

    Pallas flash-decoding on TPU (k/v streamed through VMEM once at
    storage dtype, online softmax in scratch); head-grouped einsum with
    fp32 accumulation elsewhere.  q: (b, hq, d) -> (b, hq, d);
    ``pos``: (b,) per-slot positions (a scalar broadcasts).
    """
    if use_pallas():
        return flash_decode(q, k_cache, v_cache, pos, window=window,
                            interpret=_interpret())
    return _decode_attention_xla(q, k_cache, v_cache, pos,
                                 window=window)


def _decode_attention_paged_xla(q, k_pages, v_pages, page_table, pos, *,
                                window):
    """Reference paged decode: gather each row's pages back into a
    dense (b, max_pages * page_size, hkv, d) view and reuse the dense
    path.  Because the engine sizes tables so the gathered length
    equals the dense ``max_len``, the reductions see identical operand
    lengths and the result is bit-identical to the dense cache layout —
    the property the serve acceptance tests pin."""
    n_pages, ps, hkv, d = k_pages.shape
    b, max_pages = page_table.shape
    k = k_pages[page_table].reshape(b, max_pages * ps, hkv, d)
    v = v_pages[page_table].reshape(b, max_pages * ps, hkv, d)
    return _decode_attention_xla(q, k, v, pos, window=window)


def decode_attention_paged(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           pos: jax.Array, *,
                           window: int = 0) -> jax.Array:
    """Single-token attention over a block-paged KV pool.

    k_pages/v_pages: (n_pages, page_size, hkv, d) shared pool;
    page_table: (b, max_pages) int32 per-slot tables (entries past a
    row's live length point at the sink page and are masked by ``pos``).
    Pallas paged flash-decoding on TPU (the table rides prefetched
    scalar memory and steers the kv BlockSpec index_map); gather + the
    dense XLA einsum path elsewhere.
    """
    if use_pallas():
        return flash_decode_paged(q, k_pages, v_pages, page_table, pos,
                                  window=window, interpret=_interpret())
    return _decode_attention_paged_xla(q, k_pages, v_pages, page_table,
                                       pos, window=window)
