"""Legacy kernel entrypoints — every family now has a planned API.

The GEMM family moved to the declarative planned API in
:mod:`repro.kernels.api` (``GemmSpec`` -> ``plan`` -> ``execute``,
re-exported as :mod:`repro.ops`): one spec describes operands /
quantization / epilogue / gating, one cached plan resolves the DSE tile
and modeled costs, one generic custom VJP executes it.  Attention
followed the same redesign into :mod:`repro.kernels.attn_api`
(``AttnSpec`` -> ``attn_plan`` -> ``attn_execute``), so the ad-hoc
if/else dispatch that used to live here is gone.

Everything below is a thin deprecated shim that delegates to the
planned path — bit-identical results, plus a ``DeprecationWarning`` so
stragglers surface under ``-W error::DeprecationWarning``.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.kernels import api
from repro.kernels import attn_api
from repro.kernels import ref as _ref
from repro.kernels.api import _interpret, _mode, use_pallas  # noqa: F401
from repro.kernels.attn_api import (  # noqa: F401  (back-compat aliases)
    BLOCKED_ATTN_THRESHOLD,
    _decode_attention_paged_xla,
    _decode_attention_xla,
)


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.kernels.ops.{name} is deprecated; use repro.ops "
        "(the planned Spec / plan / execute APIs or their one-shots)",
        DeprecationWarning, stacklevel=3)


def gemm(a, b, *, strategy=None, tile=None, out_dtype=None):
    """Deprecated shim: C = A @ B through the planned GemmSpec API
    (``b`` may be a ``{"q", "scale"}`` int8 weight struct)."""
    _warn("gemm")
    return api.gemm(a, b, strategy=strategy, tile=tile,
                    out_dtype=out_dtype)


def gemm_fused(a, b, *, bias=None, activation=None, residual=None,
               out_scale=None, strategy=None, tile=None, out_dtype=None):
    """Deprecated shim: epilogue-fused GEMM through the planned API."""
    _warn("gemm_fused")
    return api.gemm(a, b, bias=bias, activation=activation,
                    residual=residual, out_scale=out_scale,
                    strategy=strategy, tile=tile, out_dtype=out_dtype)


def gemm_gated(a, b_gate, b_up, *, activation="silu", tile=None,
               out_dtype=None):
    """Deprecated shim: dual-B gated GEMM through the planned API."""
    _warn("gemm_gated")
    return api.gemm(a, b_gate, b2=b_up, activation=activation, tile=tile,
                    out_dtype=out_dtype)


def gemm_int8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
              tile=None):
    """Deprecated shim: raw int8 x int8 GEMM (int32 accumulation, scales
    applied outside) through the planned API."""
    _warn("gemm_int8")
    acc = api.gemm(a_q, b_q, tile=tile, out_dtype=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * b_scale).astype(out_dtype)


quantize_int8 = _ref.quantize_int8
dequantize = _ref.dequantize


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale=None, q_offset=None) -> jax.Array:
    """Deprecated shim: prefill attention through the planned AttnSpec
    API (same dispatch, now recorded on the plan)."""
    _warn("attention")
    return attn_api.attention(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, pos, *,
                     window: int = 0) -> jax.Array:
    """Deprecated shim: dense-cache decode attention through the
    planned AttnSpec API."""
    _warn("decode_attention")
    return attn_api.decode_attention(q, k_cache, v_cache, pos,
                                     window=window)


def decode_attention_paged(q, k_pages, v_pages, page_table, pos, *,
                           window: int = 0) -> jax.Array:
    """Deprecated shim: paged-pool decode attention through the planned
    AttnSpec API."""
    _warn("decode_attention_paged")
    return attn_api.decode_attention_paged(q, k_pages, v_pages,
                                           page_table, pos, window=window)
