"""Public, jit-friendly kernel API — every model GEMM routes through here.

Dispatch policy (the hardware-adaptation contract):

* On TPU (or when ``REPRO_KERNELS=interpret`` forces Pallas-interpret for
  tests) the Pallas kernels run, with block shapes chosen by the
  reuse-maximizing DSE (:mod:`repro.core.dse`) unless a ``tile`` is given.
* Elsewhere (this CPU container, dry-runs) the mathematically identical
  pure-jnp reference path runs, so models/training/serving behave the
  same everywhere and the multi-pod dry-run lowers pure XLA.

``gemm`` carries a custom VJP (dA = dC Bᵀ, dB = Aᵀ dC, both routed back
through ``gemm``) so the Pallas forward is trainable.

Quantized ``{"q", "scale"}`` weight structs route to the *fused* kernels
(int8 B streamed at one byte/element, dequantized in-register — never
pre-dequantized on the forward path); their custom VJP dequantizes only
in the backward, so serving stays forward-only at 1-byte weight traffic.

Fused epilogues: ``gemm_fused`` applies bias / activation / residual on
the kernels' accumulator flush (the full-width intermediate never
touches HBM), and ``gemm_gated`` computes ``act(A W_gate) * (A W_up)``
in ONE Pallas call with a single resident A stream.  Both carry custom
VJPs whose backward falls back to the unfused composition (recompute the
pre-activation, then the standard GEMM cotangents).  Note on the dynamic
W8A8 activation mode: a linear epilogue (bias/residual only) commutes
with the per-row activation scale, so it keeps the int8 x int8 MXU path
with the epilogue applied outside; a nonlinear epilogue does not, so
those GEMMs serve quantized weights as fused W8A16.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant as _quant
from repro.core import dse
from repro.core.tiling import TileConfig, round_up
from repro.kernels import ref as _ref
from repro.kernels.blocked_attention import attention_blocked
from repro.kernels.epilogue import ACTIVATIONS, Epilogue
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_gated import gemm_gated as _gemm_gated_kernel
from repro.kernels.gemm_tb import feasible_bk, gemm_tb


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def use_pallas() -> bool:
    return _mode() in ("pallas", "interpret")


def _interpret() -> bool:
    return _mode() == "interpret"


def _pad2(x, m_to, n_to):
    m, n = x.shape
    if m == m_to and n == n_to:
        return x
    return jnp.pad(x, ((0, m_to - m), (0, n_to - n)))


def _clamp_tile(tile: TileConfig, m: int, k: int, n: int) -> TileConfig:
    bm = min(tile.bm, round_up(m, 8))
    bk = min(tile.bk, round_up(k, 128))
    bn = min(tile.bn, round_up(n, 128))
    return TileConfig(bm, bk, bn, tile.strategy)


def _tb_viable(tile: TileConfig, m: int, k: int, n: int, a_dtype,
               b_dtype, out_dtype, ep_key: str = "") -> TileConfig:
    """Feasibility gate (satellite): a 'tb' tile keeps a (bm, bk) A block
    VMEM-resident; ``gemm_tb`` refines the k-chunking when that busts,
    but when even bk=128 is infeasible (the (bm, bn) blocks themselves
    over-subscribe VMEM) fall back to the DSE's 'aie' winner instead of
    dispatching a kernel that cannot fit.  ``ep_key`` bills any fused
    bias/residual blocks on both sides of the gate."""
    if tile.strategy != "tb":
        return tile
    acc = jnp.int32 if a_dtype == jnp.int8 else jnp.float32
    if feasible_bk(round_up(m, tile.bm), round_up(k, tile.bk),
                   round_up(n, tile.bn), tile, a_dtype, b_dtype,
                   out_dtype, acc, epilogue=ep_key) > 0:
        return tile
    b_key = "int8" if b_dtype == jnp.int8 else None
    t = dse.best_tile(m, k, n, str(a_dtype), str(jnp.dtype(out_dtype)),
                      str(jnp.dtype(acc)), strategy="aie", b_dtype=b_key,
                      epilogue=ep_key)
    return _clamp_tile(t, m, k, n)


def _gemm_pallas(a: jax.Array, b: jax.Array, tile: TileConfig,
                 out_dtype, *, b_scale: Optional[jax.Array] = None,
                 bias: Optional[jax.Array] = None,
                 residual: Optional[jax.Array] = None,
                 out_scale: Optional[jax.Array] = None,
                 activation: Optional[str] = None) -> jax.Array:
    """Pad to tile multiples, dispatch the aie/tb kernel (with any fused
    dequant-scale / epilogue operands padded alongside), slice back."""
    m, k = a.shape
    _, n = b.shape
    tile = _clamp_tile(tile, m, k, n)
    ep_key = Epilogue.from_args(bias, activation, residual,
                                out_scale).key
    tile = _tb_viable(tile, m, k, n, a.dtype, b.dtype,
                      out_dtype or jnp.float32, ep_key)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    ap = _pad2(a, mp, kp)
    bp = _pad2(b, kp, np_)
    sp = None
    if b_scale is not None:
        sp = b_scale if np_ == n else jnp.pad(
            b_scale, ((0, 0), (0, np_ - n)), constant_values=1.0)
        sp = sp.astype(jnp.float32)
    biasp = _pad2(bias, 1, np_) if bias is not None else None
    resp = _pad2(residual, mp, np_) if residual is not None else None
    fn = gemm_aie if tile.strategy == "aie" else gemm_tb
    out = fn(ap, bp, tile=tile, out_dtype=out_dtype, b_scale=sp,
             bias=biasp, residual=resp, out_scale=out_scale,
             activation=activation, interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _gemm2d(a: jax.Array, b: jax.Array, strategy: Optional[str],
            tile: Optional[TileConfig], out_dtype) -> jax.Array:
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, b.shape[1]
            t = dse.best_tile(m, k, n, str(a.dtype),
                              str(jnp.dtype(out_dtype)), strategy=strategy)
        return _gemm_pallas(a, b, t, out_dtype)
    return _ref.gemm_ref(a, b, out_dtype=out_dtype)


def _gemm2d_fwd(a, b, strategy, tile, out_dtype):
    return _gemm2d(a, b, strategy, tile, out_dtype), (a, b)


def _gemm2d_bwd(strategy, tile, out_dtype, res, g):
    a, b = res
    g = g.astype(a.dtype)
    da = _gemm2d(g, b.T, strategy, None, a.dtype)
    db = _gemm2d(a.T, g, strategy, None, b.dtype)
    return da.astype(a.dtype), db.astype(b.dtype)


_gemm2d.defvjp(_gemm2d_fwd, _gemm2d_bwd)


def _gemm_q_pallas(a: jax.Array, q: jax.Array, scale: jax.Array,
                   tile: TileConfig, out_dtype) -> jax.Array:
    """Pad + run a fused weight-dequant Pallas kernel (b_scale path)."""
    return _gemm_pallas(a, q, tile, out_dtype, b_scale=scale)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _gemm2d_q(a: jax.Array, q: jax.Array, scale: jax.Array,
              strategy: Optional[str], tile: Optional[TileConfig],
              out_dtype) -> jax.Array:
    """C = A @ (q * scale) without materializing the dequantized weight:
    the kernel streams int8 q and applies the per-output-channel scale
    to the accumulator."""
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, q.shape[1]
            acc = "int32" if a.dtype == jnp.int8 else "float32"
            t = dse.best_tile(m, k, n, str(a.dtype),
                              str(jnp.dtype(out_dtype)), acc,
                              strategy=strategy, b_dtype="int8")
        return _gemm_q_pallas(a, q, scale, t, out_dtype)
    return _ref.gemm_fused_ref(a, q, scale, out_dtype=out_dtype)


def _gemm2d_q_fwd(a, q, scale, strategy, tile, out_dtype):
    return _gemm2d_q(a, q, scale, strategy, tile, out_dtype), \
        (a, q, scale)


def _gemm2d_q_bwd(strategy, tile, out_dtype, res, g):
    # The ONLY place the weight is dequantized — the forward path never
    # pays 2-byte weight traffic.  Quantized weights are serving
    # artifacts: they get no gradient (int8 cotangent is float0).
    a, q, scale = res
    if a.dtype == jnp.int8:
        da = np.zeros(a.shape, jax.dtypes.float0)
    else:
        w = (q.astype(jnp.float32) * scale).astype(a.dtype)
        da = _gemm2d(g.astype(a.dtype), w.T, strategy, None,
                     a.dtype).astype(a.dtype)
    dq = np.zeros(q.shape, jax.dtypes.float0)
    dscale = jnp.zeros_like(scale)
    return da, dq, dscale


_gemm2d_q.defvjp(_gemm2d_q_fwd, _gemm2d_q_bwd)


# ---------------------------------------------------------------------------
# Fused-epilogue GEMM (bias / activation / residual on the flush)
# ---------------------------------------------------------------------------

def _ep_tile(m: int, k: int, n: int, a_dtype, out_dtype, ep_key: str,
             strategy: Optional[str], b_dtype: Optional[str] = None,
             n_b: int = 1) -> TileConfig:
    acc = "int32" if a_dtype == jnp.int8 else "float32"
    return dse.best_tile(m, k, n, str(a_dtype), str(jnp.dtype(out_dtype)),
                         acc, strategy=strategy, b_dtype=b_dtype,
                         epilogue=ep_key, n_b_operands=n_b)


def _act_bwd(activation: Optional[str], z: jax.Array, g: jax.Array
             ) -> jax.Array:
    """dL/dz given dL/d(act(z)) — the unfused-composition backward."""
    if activation is None:
        return g
    _, vjp = jax.vjp(ACTIVATIONS[activation], z)
    return vjp(g)[0]


def _ep_dispatch(a2: jax.Array, b2: jax.Array, scale, bias, residual,
                 out_scale, activation: Optional[str],
                 strategy: Optional[str], tile: Optional[TileConfig],
                 out_dtype) -> jax.Array:
    """The one pallas/ref fan-out every epilogue path shares: pick the
    DSE tile for the real (epilogue-billed) footprint, run the fused
    kernel, or fall back to the jnp reference composition off-TPU.
    ``scale`` is the quantized-weight dequant vector (None for plain B).
    """
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a2.shape, b2.shape[1]
            ep_key = Epilogue.from_args(bias, activation, residual,
                                        out_scale).key
            t = _ep_tile(m, k, n, a2.dtype, out_dtype, ep_key, strategy,
                         b_dtype="int8" if scale is not None else None)
        return _gemm_pallas(a2, b2, t, out_dtype, b_scale=scale,
                            bias=bias, residual=residual,
                            out_scale=out_scale, activation=activation)
    return _ref.gemm_epilogue_ref(a2, b2, b_scale=scale, bias=bias,
                                  activation=activation,
                                  residual=residual, out_scale=out_scale,
                                  out_dtype=out_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _gemm2d_ep(a: jax.Array, b: jax.Array, bias, residual,
               activation: Optional[str], strategy: Optional[str],
               tile: Optional[TileConfig], out_dtype) -> jax.Array:
    """C = epilogue(A @ B): bias (1, n) add, activation, residual (m, n)
    add — applied to the fp32 accumulator inside the kernel flush."""
    return _ep_dispatch(a, b, None, bias, residual, None, activation,
                        strategy, tile, out_dtype)


def _gemm2d_ep_fwd(a, b, bias, residual, activation, strategy, tile,
                   out_dtype):
    out = _gemm2d_ep(a, b, bias, residual, activation, strategy, tile,
                     out_dtype)
    return out, (a, b, bias, residual)


def _gemm2d_ep_bwd(activation, strategy, tile, out_dtype, res, g):
    # Unfused-composition fallback: recompute the pre-activation z (one
    # extra GEMM — rematerialization, not HBM round-trips), then the
    # standard cotangents through the elementwise epilogue.
    a, b, bias, residual = res
    gf = g.astype(jnp.float32)
    dres = gf.astype(residual.dtype) if residual is not None else None
    if activation is not None:
        z = _gemm2d(a, b, strategy, None, jnp.dtype(jnp.float32))
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = _act_bwd(activation, z, gf)
    else:
        dz = gf
    dbias = jnp.sum(dz, axis=0, keepdims=True).astype(bias.dtype) \
        if bias is not None else None
    dzc = dz.astype(a.dtype)
    da = _gemm2d(dzc, b.T, strategy, None, a.dtype).astype(a.dtype)
    db = _gemm2d(a.T, dzc, strategy, None, b.dtype).astype(b.dtype)
    return da, db, dbias, dres


_gemm2d_ep.defvjp(_gemm2d_ep_fwd, _gemm2d_ep_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _gemm2d_ep_q(a: jax.Array, q: jax.Array, scale: jax.Array, bias,
                 residual, activation: Optional[str],
                 strategy: Optional[str], tile: Optional[TileConfig],
                 out_dtype) -> jax.Array:
    """Fused-epilogue GEMM against a quantized weight: the int8 block
    streams at one byte/element, the per-output-channel scale applies to
    the accumulator on the flush, and the epilogue follows — still a
    single C write."""
    return _ep_dispatch(a, q, scale, bias, residual, None, activation,
                        strategy, tile, out_dtype)


def _gemm2d_ep_q_fwd(a, q, scale, bias, residual, activation, strategy,
                     tile, out_dtype):
    out = _gemm2d_ep_q(a, q, scale, bias, residual, activation, strategy,
                       tile, out_dtype)
    return out, (a, q, scale, bias, residual)


def _gemm2d_ep_q_bwd(activation, strategy, tile, out_dtype, res, g):
    # Quantized weights are serving artifacts: the weight is dequantized
    # only here, and q/scale get no gradient (like _gemm2d_q_bwd).
    a, q, scale, bias, residual = res
    gf = g.astype(jnp.float32)
    dres = gf.astype(residual.dtype) if residual is not None else None
    if activation is not None:
        z = _gemm2d_q(a, q, scale, strategy, None,
                      jnp.dtype(jnp.float32))
        if bias is not None:
            z = z + bias.astype(jnp.float32)
        dz = _act_bwd(activation, z, gf)
    else:
        dz = gf
    dbias = jnp.sum(dz, axis=0, keepdims=True).astype(bias.dtype) \
        if bias is not None else None
    if a.dtype == jnp.int8:
        da = np.zeros(a.shape, jax.dtypes.float0)
    else:
        w = (q.astype(jnp.float32) * scale).astype(a.dtype)
        da = _gemm2d(dz.astype(a.dtype), w.T, strategy, None,
                     a.dtype).astype(a.dtype)
    dq = np.zeros(q.shape, jax.dtypes.float0)
    dscale = jnp.zeros_like(scale)
    return da, dq, dscale, dbias, dres


_gemm2d_ep_q.defvjp(_gemm2d_ep_q_fwd, _gemm2d_ep_q_bwd)


# ---------------------------------------------------------------------------
# Dual-B gated GEMM (SwiGLU core): act(A W_gate) * (A W_up) in one call
# ---------------------------------------------------------------------------

def _gated_pallas(a, bg, bu, tile, out_dtype, activation,
                  sg=None, su=None) -> jax.Array:
    m, k = a.shape
    _, n = bg.shape
    tile = _clamp_tile(tile, m, k, n)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    mp, kp, np_ = round_up(m, bm), round_up(k, bk), round_up(n, bn)
    ap = _pad2(a, mp, kp)
    bgp, bup = _pad2(bg, kp, np_), _pad2(bu, kp, np_)
    if sg is not None and np_ != n:
        pad = ((0, 0), (0, np_ - n))
        sg = jnp.pad(sg, pad, constant_values=1.0)
        su = jnp.pad(su, pad, constant_values=1.0)
    out = _gemm_gated_kernel(ap, bgp, bup, tile=tile,
                             activation=activation, out_dtype=out_dtype,
                             bg_scale=sg, bu_scale=su,
                             interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _gemm2d_gated(a: jax.Array, bg: jax.Array, bu: jax.Array,
                  activation: str, tile: Optional[TileConfig],
                  out_dtype) -> jax.Array:
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, bg.shape[1]
            t = _ep_tile(m, k, n, a.dtype, out_dtype, activation, None,
                         n_b=2)
        return _gated_pallas(a, bg, bu, t, out_dtype, activation)
    return _ref.gemm_gated_ref(a, bg, bu, activation=activation,
                               out_dtype=out_dtype)


def _gemm2d_gated_fwd(a, bg, bu, activation, tile, out_dtype):
    return _gemm2d_gated(a, bg, bu, activation, tile, out_dtype), \
        (a, bg, bu)


def _gemm2d_gated_bwd(activation, tile, out_dtype, res, g):
    # Unfused composition: zg = A Wg, zu = A Wu, h = act(zg) * zu.
    a, bg, bu = res
    gf = g.astype(jnp.float32)
    zg = _gemm2d(a, bg, None, None, jnp.dtype(jnp.float32))
    zu = _gemm2d(a, bu, None, None, jnp.dtype(jnp.float32))
    dzu = gf * ACTIVATIONS[activation](zg)
    dzg = _act_bwd(activation, zg, gf * zu)
    dzgc, dzuc = dzg.astype(a.dtype), dzu.astype(a.dtype)
    da = (_gemm2d(dzgc, bg.T, None, None, a.dtype)
          + _gemm2d(dzuc, bu.T, None, None, a.dtype)).astype(a.dtype)
    dbg = _gemm2d(a.T, dzgc, None, None, bg.dtype).astype(bg.dtype)
    dbu = _gemm2d(a.T, dzuc, None, None, bu.dtype).astype(bu.dtype)
    return da, dbg, dbu


_gemm2d_gated.defvjp(_gemm2d_gated_fwd, _gemm2d_gated_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _gemm2d_gated_q(a: jax.Array, qg: jax.Array, sg: jax.Array,
                    qu: jax.Array, su: jax.Array, activation: str,
                    tile: Optional[TileConfig], out_dtype) -> jax.Array:
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, qg.shape[1]
            t = _ep_tile(m, k, n, a.dtype, out_dtype, activation, None,
                         b_dtype="int8", n_b=2)
        return _gated_pallas(a, qg, qu, t, out_dtype, activation,
                             sg=sg, su=su)
    return _ref.gemm_gated_ref(a, qg, qu, activation=activation,
                               bg_scale=sg, bu_scale=su,
                               out_dtype=out_dtype)


def _gemm2d_gated_q_fwd(a, qg, sg, qu, su, activation, tile, out_dtype):
    out = _gemm2d_gated_q(a, qg, sg, qu, su, activation, tile, out_dtype)
    return out, (a, qg, sg, qu, su)


def _gemm2d_gated_q_bwd(activation, tile, out_dtype, res, g):
    a, qg, sg, qu, su = res
    gf = g.astype(jnp.float32)
    if a.dtype == jnp.int8:
        da = np.zeros(a.shape, jax.dtypes.float0)
    else:
        zg = _gemm2d_q(a, qg, sg, None, None, jnp.dtype(jnp.float32))
        zu = _gemm2d_q(a, qu, su, None, None, jnp.dtype(jnp.float32))
        dzu = gf * ACTIVATIONS[activation](zg)
        dzg = _act_bwd(activation, zg, gf * zu)
        wg = (qg.astype(jnp.float32) * sg).astype(a.dtype)
        wu = (qu.astype(jnp.float32) * su).astype(a.dtype)
        da = (_gemm2d(dzg.astype(a.dtype), wg.T, None, None, a.dtype)
              + _gemm2d(dzu.astype(a.dtype), wu.T, None, None,
                        a.dtype)).astype(a.dtype)
    return (da, np.zeros(qg.shape, jax.dtypes.float0),
            jnp.zeros_like(sg), np.zeros(qu.shape, jax.dtypes.float0),
            jnp.zeros_like(su))


_gemm2d_gated_q.defvjp(_gemm2d_gated_q_fwd, _gemm2d_gated_q_bwd)


def gemm_fused(a: jax.Array, b, *, bias: Optional[jax.Array] = None,
               activation: Optional[str] = None,
               residual: Optional[jax.Array] = None,
               out_scale: Optional[jax.Array] = None,
               strategy: Optional[str] = None,
               tile: Optional[TileConfig] = None,
               out_dtype=None) -> jax.Array:
    """C = epilogue(A @ B) with the epilogue fused into the kernel flush.

    ``a``: (..., k); ``b``: (k, n) array or quantized ``{"q", "scale"}``
    struct.  ``bias``: (n,) or (1, n); ``residual``: same shape as the
    output (the pre-attention/pre-MLP x of a transformer residual
    stream); ``activation``: "silu" | "gelu" | "relu", computed in fp32
    on the accumulator.  ``out_scale`` (scalar-like, forward-only)
    additionally quantizes the epilogue output to int8.

    With no epilogue operands this degenerates to :func:`gemm` (same
    dispatch, same VJP).  W8A8 dynamic activation quantization: a
    *linear* epilogue (bias/residual, no activation) commutes with the
    per-row scale applied after the int8 x int8 GEMM, so it keeps the
    int8 MXU path — the epilogue then runs as XLA ops on the fp32
    dequantized output (the fusion is traded for the cheaper
    multiplies).  A *nonlinear* epilogue cannot (the scale would have to
    be applied inside the kernel before the activation), so those GEMMs
    serve quantized weights as fused W8A16.
    """
    if bias is None and activation is None and residual is None \
            and out_scale is None:
        return gemm(a, b, strategy=strategy, tile=tile,
                    out_dtype=out_dtype)
    quantized = isinstance(b, dict) and {"q", "scale"} <= set(b)
    if quantized and activation is None and out_scale is None \
            and _quant.activation_mode() == "w8a8" \
            and a.dtype != jnp.int8:
        # linear epilogue + w8a8: keep the int8 x int8 / int32 MXU path
        # (the decode-dominant wo / down projections); the scaled fp32
        # output then takes bias/residual outside the kernel.
        out = gemm(a, b, strategy=strategy, tile=tile,
                   out_dtype=jnp.float32)
        if bias is not None:
            out = out + bias.astype(jnp.float32)
        if residual is not None:
            out = out + residual.astype(jnp.float32)
        return out.astype(out_dtype or a.dtype)
    n = b["q"].shape[-1] if quantized else b.shape[-1]
    out_dtype = out_dtype or (a.dtype if out_scale is None else jnp.int8)
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    bias2 = bias.reshape((1, n)) if bias is not None else None
    res2 = residual.reshape((-1, n)) if residual is not None else None
    if out_scale is not None:
        # quantized output is a forward-only serving feature (no VJP
        # through the rounding) — dispatch without the custom-VJP wrapper
        osc = jnp.asarray(out_scale, jnp.float32).reshape((1, 1))
        out = _ep_dispatch(a2, b["q"] if quantized else b,
                           b["scale"] if quantized else None, bias2,
                           res2, osc, activation, strategy, tile,
                           out_dtype)
        return out.reshape(lead + (n,))
    if quantized:
        out = _gemm2d_ep_q(a2, b["q"], b["scale"], bias2, res2,
                           activation, strategy, tile,
                           jnp.dtype(out_dtype))
    else:
        out = _gemm2d_ep(a2, b, bias2, res2, activation, strategy, tile,
                         jnp.dtype(out_dtype))
    return out.reshape(lead + (n,)).astype(out_dtype)


def gemm_gated(a: jax.Array, b_gate, b_up, *, activation: str = "silu",
               tile: Optional[TileConfig] = None,
               out_dtype=None) -> jax.Array:
    """h = act(A @ B_gate) * (A @ B_up) — the SwiGLU/GeGLU core as ONE
    kernel call: a single resident A stream feeds both B operands and
    the (m, n) gate/up intermediates never leave VMEM.

    ``b_gate`` / ``b_up``: (k, n) arrays or quantized ``{"q", "scale"}``
    structs (both or neither).  Output-stationary dataflow; gate math in
    fp32 on the accumulators.  The custom VJP falls back to the unfused
    two-GEMM composition.
    """
    out_dtype = out_dtype or a.dtype
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    qg = isinstance(b_gate, dict) and {"q", "scale"} <= set(b_gate)
    qu = isinstance(b_up, dict) and {"q", "scale"} <= set(b_up)
    assert qg == qu, "quantize both gated operands or neither"
    if qg:
        n = b_gate["q"].shape[-1]
        out = _gemm2d_gated_q(a2, b_gate["q"], b_gate["scale"],
                              b_up["q"], b_up["scale"], activation, tile,
                              jnp.dtype(out_dtype))
    else:
        n = b_gate.shape[-1]
        out = _gemm2d_gated(a2, b_gate, b_up, activation, tile,
                            jnp.dtype(out_dtype))
    return out.reshape(lead + (n,)).astype(out_dtype)


def gemm(a: jax.Array, b, *, strategy: Optional[str] = None,
         tile: Optional[TileConfig] = None,
         out_dtype=None) -> jax.Array:
    """C = A @ B.  ``a``: (..., k), ``b``: (k, n).  Leading dims of ``a``
    are flattened into M (the paper tiles GEMM, models bring (b, s, d)).

    ``b`` may be a weight-only int8 struct ``{"q", "scale"}`` from
    ``repro.quant`` (the paper's int8 precision as a serving mode) —
    routed to the fused kernels, which stream the int8 block at one
    byte/element and dequantize in-register (W8A16).  Under
    ``quant.activation_mode() == "w8a8"`` the activations are
    additionally quantized per-row on the fly and the kernel runs
    int8 x int8 with int32 accumulation (forward-only).
    """
    out_dtype = out_dtype or a.dtype
    if isinstance(b, dict) and {"q", "scale"} <= set(b):
        n = b["q"].shape[-1]
        lead = a.shape[:-1]
        a2 = a.reshape((-1, a.shape[-1]))
        if _quant.activation_mode() == "w8a8" \
                and a2.dtype != jnp.int8:
            a_q, a_s = _quant.quantize_activations(
                jax.lax.stop_gradient(a2), axis=-1)
            acc = _gemm2d_q(a_q, b["q"], b["scale"], strategy, tile,
                            jnp.dtype(jnp.float32))
            out = (acc * a_s).astype(out_dtype)
        else:
            out = _gemm2d_q(a2, b["q"], b["scale"], strategy, tile,
                            jnp.dtype(out_dtype)).astype(out_dtype)
        return out.reshape(lead + (n,))
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    out = _gemm2d(a2, b, strategy, tile, jnp.dtype(out_dtype))
    return out.reshape(lead + (b.shape[-1],)).astype(out_dtype)


def gemm_int8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
              tile: Optional[TileConfig] = None):
    """Quantized GEMM (int8 operands, int32 accumulation, fused dequant) —
    the paper's precision scheme as a serving-path op."""
    if use_pallas():
        m, k = a_q.shape
        _, n = b_q.shape
        # int32 OUTPUT: the kernel writes the int32 accumulator, so the
        # DSE must bill C at 4 bytes (an "int8" out under-billed C
        # traffic 4x and could pick tiles that bust VMEM).
        t = tile or dse.best_tile(m, k, n, "int8", "int32", "int32")
        acc = _gemm_pallas(a_q, b_q, t, jnp.int32)
    else:
        acc = jnp.dot(a_q, b_q, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * b_scale).astype(out_dtype)


quantize_int8 = _ref.quantize_int8
dequantize = _ref.dequantize


# Above this many query/kv positions the unblocked reference would
# materialize (b, h, sq, skv) scores; switch to the blocked XLA path.
BLOCKED_ATTN_THRESHOLD = 1024


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale=None, q_offset=None) -> jax.Array:
    """Multi-head attention with GQA + optional sliding window.

    Dispatch: Pallas flash kernel on TPU for prefill/train-sized queries;
    blocked lax implementation (same tiling, XLA-lowerable — what the
    dry-run compiles) for long sequences elsewhere; plain reference for
    short ones.  Single-token decode stays on the fused XLA path in the
    model layer.
    """
    sq, skv = q.shape[1], k.shape[1]
    if use_pallas() and sq >= 128:
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset,
                               interpret=_interpret())
    if max(sq, skv) > BLOCKED_ATTN_THRESHOLD:
        return attention_blocked(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)


def _decode_attention_xla(q, k_cache, v_cache, pos, *, window):
    """Head-grouped einsums with operands at storage dtype + fp32
    accumulation — casting the cache itself to f32 would materialize and
    rewrite a full-precision copy of the entire stacked cache every
    layer (measured 1.38 TB/step on deepseek decode_32k).

    ``pos``: (b,) per-slot positions (scalar broadcasts) — row i masks
    cache slots > pos[i], the continuous-batching contract."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    k_pos = jnp.arange(skv)
    mask = k_pos[None, :] <= posv[:, None]
    if window > 0:
        mask &= k_pos[None, :] > posv[:, None] - window
    logits = jnp.where(mask[:, None, None, :], logits,
                       _ref.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """Single-token attention over a KV cache (serve_step hot-spot).

    Pallas flash-decoding on TPU (k/v streamed through VMEM once at
    storage dtype, online softmax in scratch); head-grouped einsum with
    fp32 accumulation elsewhere.  q: (b, hq, d) -> (b, hq, d);
    ``pos``: (b,) per-slot positions (a scalar broadcasts).
    """
    if use_pallas():
        return flash_decode(q, k_cache, v_cache, pos, window=window,
                            interpret=_interpret())
    return _decode_attention_xla(q, k_cache, v_cache, pos,
                                 window=window)
