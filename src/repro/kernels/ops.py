"""Public, jit-friendly kernel API — every model GEMM routes through here.

Dispatch policy (the hardware-adaptation contract):

* On TPU (or when ``REPRO_KERNELS=interpret`` forces Pallas-interpret for
  tests) the Pallas kernels run, with block shapes chosen by the
  reuse-maximizing DSE (:mod:`repro.core.dse`) unless a ``tile`` is given.
* Elsewhere (this CPU container, dry-runs) the mathematically identical
  pure-jnp reference path runs, so models/training/serving behave the
  same everywhere and the multi-pod dry-run lowers pure XLA.

``gemm`` carries a custom VJP (dA = dC Bᵀ, dB = Aᵀ dC, both routed back
through ``gemm``) so the Pallas forward is trainable.

Quantized ``{"q", "scale"}`` weight structs route to the *fused* kernels
(int8 B streamed at one byte/element, dequantized in-register — never
pre-dequantized on the forward path); their custom VJP dequantizes only
in the backward, so serving stays forward-only at 1-byte weight traffic.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant as _quant
from repro.core import dse
from repro.core.tiling import TileConfig, round_up
from repro.kernels import ref as _ref
from repro.kernels.blocked_attention import attention_blocked
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_decode import flash_decode
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_tb import gemm_tb


def _mode() -> str:
    env = os.environ.get("REPRO_KERNELS", "auto")
    if env in ("interpret", "ref", "pallas"):
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def use_pallas() -> bool:
    return _mode() in ("pallas", "interpret")


def _interpret() -> bool:
    return _mode() == "interpret"


def _pad2(x, m_to, n_to):
    m, n = x.shape
    if m == m_to and n == n_to:
        return x
    return jnp.pad(x, ((0, m_to - m), (0, n_to - n)))


def _gemm_pallas(a: jax.Array, b: jax.Array, tile: TileConfig,
                 out_dtype) -> jax.Array:
    m, k = a.shape
    _, n = b.shape
    bm = min(tile.bm, round_up(m, 8))
    bk = min(tile.bk, round_up(k, 128))
    bn = min(tile.bn, round_up(n, 128))
    tile = TileConfig(bm, bk, bn, tile.strategy)
    ap = _pad2(a, round_up(m, bm), round_up(k, bk))
    bp = _pad2(b, round_up(k, bk), round_up(n, bn))
    fn = gemm_aie if tile.strategy == "aie" else gemm_tb
    out = fn(ap, bp, tile=tile, out_dtype=out_dtype,
             interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _gemm2d(a: jax.Array, b: jax.Array, strategy: Optional[str],
            tile: Optional[TileConfig], out_dtype) -> jax.Array:
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, b.shape[1]
            t = dse.best_tile(m, k, n, str(a.dtype),
                              str(jnp.dtype(out_dtype)), strategy=strategy)
        return _gemm_pallas(a, b, t, out_dtype)
    return _ref.gemm_ref(a, b, out_dtype=out_dtype)


def _gemm2d_fwd(a, b, strategy, tile, out_dtype):
    return _gemm2d(a, b, strategy, tile, out_dtype), (a, b)


def _gemm2d_bwd(strategy, tile, out_dtype, res, g):
    a, b = res
    g = g.astype(a.dtype)
    da = _gemm2d(g, b.T, strategy, None, a.dtype)
    db = _gemm2d(a.T, g, strategy, None, b.dtype)
    return da.astype(a.dtype), db.astype(b.dtype)


_gemm2d.defvjp(_gemm2d_fwd, _gemm2d_bwd)


def _gemm_q_pallas(a: jax.Array, q: jax.Array, scale: jax.Array,
                   tile: TileConfig, out_dtype) -> jax.Array:
    """Pad + run a fused weight-dequant Pallas kernel (b_scale path)."""
    m, k = a.shape
    _, n = q.shape
    bm = min(tile.bm, round_up(m, 8))
    bk = min(tile.bk, round_up(k, 128))
    bn = min(tile.bn, round_up(n, 128))
    tile = TileConfig(bm, bk, bn, tile.strategy)
    np_ = round_up(n, bn)
    ap = _pad2(a, round_up(m, bm), round_up(k, bk))
    qp = _pad2(q, round_up(k, bk), np_)
    sp = scale if np_ == n else jnp.pad(
        scale, ((0, 0), (0, np_ - n)), constant_values=1.0)
    fn = gemm_aie if tile.strategy == "aie" else gemm_tb
    out = fn(ap, qp, tile=tile, out_dtype=out_dtype,
             b_scale=sp.astype(jnp.float32), interpret=_interpret())
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _gemm2d_q(a: jax.Array, q: jax.Array, scale: jax.Array,
              strategy: Optional[str], tile: Optional[TileConfig],
              out_dtype) -> jax.Array:
    """C = A @ (q * scale) without materializing the dequantized weight:
    the kernel streams int8 q and applies the per-output-channel scale
    to the accumulator."""
    if use_pallas():
        t = tile
        if t is None:
            (m, k), n = a.shape, q.shape[1]
            acc = "int32" if a.dtype == jnp.int8 else "float32"
            t = dse.best_tile(m, k, n, str(a.dtype),
                              str(jnp.dtype(out_dtype)), acc,
                              strategy=strategy, b_dtype="int8")
        return _gemm_q_pallas(a, q, scale, t, out_dtype)
    return _ref.gemm_fused_ref(a, q, scale, out_dtype=out_dtype)


def _gemm2d_q_fwd(a, q, scale, strategy, tile, out_dtype):
    return _gemm2d_q(a, q, scale, strategy, tile, out_dtype), \
        (a, q, scale)


def _gemm2d_q_bwd(strategy, tile, out_dtype, res, g):
    # The ONLY place the weight is dequantized — the forward path never
    # pays 2-byte weight traffic.  Quantized weights are serving
    # artifacts: they get no gradient (int8 cotangent is float0).
    a, q, scale = res
    if a.dtype == jnp.int8:
        da = np.zeros(a.shape, jax.dtypes.float0)
    else:
        w = (q.astype(jnp.float32) * scale).astype(a.dtype)
        da = _gemm2d(g.astype(a.dtype), w.T, strategy, None,
                     a.dtype).astype(a.dtype)
    dq = np.zeros(q.shape, jax.dtypes.float0)
    dscale = jnp.zeros_like(scale)
    return da, dq, dscale


_gemm2d_q.defvjp(_gemm2d_q_fwd, _gemm2d_q_bwd)


def gemm(a: jax.Array, b, *, strategy: Optional[str] = None,
         tile: Optional[TileConfig] = None,
         out_dtype=None) -> jax.Array:
    """C = A @ B.  ``a``: (..., k), ``b``: (k, n).  Leading dims of ``a``
    are flattened into M (the paper tiles GEMM, models bring (b, s, d)).

    ``b`` may be a weight-only int8 struct ``{"q", "scale"}`` from
    ``repro.quant`` (the paper's int8 precision as a serving mode) —
    routed to the fused kernels, which stream the int8 block at one
    byte/element and dequantize in-register (W8A16).  Under
    ``quant.activation_mode() == "w8a8"`` the activations are
    additionally quantized per-row on the fly and the kernel runs
    int8 x int8 with int32 accumulation (forward-only).
    """
    out_dtype = out_dtype or a.dtype
    if isinstance(b, dict) and {"q", "scale"} <= set(b):
        n = b["q"].shape[-1]
        lead = a.shape[:-1]
        a2 = a.reshape((-1, a.shape[-1]))
        if _quant.activation_mode() == "w8a8" \
                and a2.dtype != jnp.int8:
            a_q, a_s = _quant.quantize_activations(
                jax.lax.stop_gradient(a2), axis=-1)
            acc = _gemm2d_q(a_q, b["q"], b["scale"], strategy, tile,
                            jnp.dtype(jnp.float32))
            out = (acc * a_s).astype(out_dtype)
        else:
            out = _gemm2d_q(a2, b["q"], b["scale"], strategy, tile,
                            jnp.dtype(out_dtype)).astype(out_dtype)
        return out.reshape(lead + (n,))
    lead = a.shape[:-1]
    a2 = a.reshape((-1, a.shape[-1]))
    out = _gemm2d(a2, b, strategy, tile, jnp.dtype(out_dtype))
    return out.reshape(lead + (b.shape[-1],)).astype(out_dtype)


def gemm_int8(a_q, b_q, a_scale, b_scale, *, out_dtype=jnp.float32,
              tile: Optional[TileConfig] = None):
    """Quantized GEMM (int8 operands, int32 accumulation, fused dequant) —
    the paper's precision scheme as a serving-path op."""
    if use_pallas():
        m, k = a_q.shape
        _, n = b_q.shape
        # int32 OUTPUT: the kernel writes the int32 accumulator, so the
        # DSE must bill C at 4 bytes (an "int8" out under-billed C
        # traffic 4x and could pick tiles that bust VMEM).
        t = tile or dse.best_tile(m, k, n, "int8", "int32", "int32")
        acc = _gemm_pallas(a_q, b_q, t, jnp.int32)
    else:
        acc = jnp.dot(a_q, b_q, preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * a_scale * b_scale).astype(out_dtype)


quantize_int8 = _ref.quantize_int8
dequantize = _ref.dequantize


# Above this many query/kv positions the unblocked reference would
# materialize (b, h, sq, skv) scores; switch to the blocked XLA path.
BLOCKED_ATTN_THRESHOLD = 1024


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale=None, q_offset=None) -> jax.Array:
    """Multi-head attention with GQA + optional sliding window.

    Dispatch: Pallas flash kernel on TPU for prefill/train-sized queries;
    blocked lax implementation (same tiling, XLA-lowerable — what the
    dry-run compiles) for long sequences elsewhere; plain reference for
    short ones.  Single-token decode stays on the fused XLA path in the
    model layer.
    """
    sq, skv = q.shape[1], k.shape[1]
    if use_pallas() and sq >= 128:
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset,
                               interpret=_interpret())
    if max(sq, skv) > BLOCKED_ATTN_THRESHOLD:
        return attention_blocked(q, k, v, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset)
    return _ref.attention_ref(q, k, v, causal=causal, window=window,
                              scale=scale, q_offset=q_offset)


def _decode_attention_xla(q, k_cache, v_cache, pos, *, window):
    """Head-grouped einsums with operands at storage dtype + fp32
    accumulation — casting the cache itself to f32 would materialize and
    rewrite a full-precision copy of the entire stacked cache every
    layer (measured 1.38 TB/step on deepseek decode_32k)."""
    b, hq, d = q.shape
    _, skv, hkv, _ = k_cache.shape
    groups = hq // hkv
    qg = q.reshape(b, hkv, groups, d)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * d ** -0.5
    k_pos = jnp.arange(skv)
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > pos - window
    logits = jnp.where(mask[None, None, None, :], logits,
                       _ref.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.reshape(b, hq, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, *,
                     window: int = 0) -> jax.Array:
    """Single-token attention over a KV cache (serve_step hot-spot).

    Pallas flash-decoding on TPU (k/v streamed through VMEM once at
    storage dtype, online softmax in scratch); head-grouped einsum with
    fp32 accumulation elsewhere.  q: (b, hq, d) -> (b, hq, d).
    """
    if use_pallas():
        return flash_decode(q, k_cache, v_cache, pos, window=window,
                            interpret=_interpret())
    return _decode_attention_xla(q, k_cache, v_cache, pos,
                                 window=window)
