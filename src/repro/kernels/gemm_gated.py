"""Dual-B gated GEMM — one Pallas call for ``act(A W_gate) * (A W_up)``.

The SwiGLU/GeGLU block is two GEMMs that share the same activation
operand A and whose outputs meet in one elementwise gate.  Run unfused,
A streams from HBM twice and both (m, d_ff) intermediates round-trip
through HBM before the multiply.  This kernel is the paper's
keep-it-in-the-array discipline (SS IV-A) applied across *two* reductions:
the grid is (m, n, k) with k innermost, ONE A block is fetched per grid
step and multiplied against both B streams, two VMEM scratch accumulators
hold the partial gate/up sums, and the last-k flush computes
``act(acc_gate) * acc_up`` (per-output-channel dequant scales first, for
int8 B operands) — so A is read once and the gate/up intermediates never
exist outside VMEM.

Output-stationary ('aie' dataflow) only: the DSE bills the second B
stream and the second accumulator via ``GemmProblem(n_b_operands=2)``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import TileConfig
from repro.kernels import _compiler_params, acc_dtype
from repro.kernels.epilogue import ACTIVATIONS


def _gated_kernel(activation, has_scale, *refs):
    it = iter(refs)
    a_ref, bg_ref, bu_ref = next(it), next(it), next(it)
    sg_ref = next(it) if has_scale else None
    su_ref = next(it) if has_scale else None
    o_ref, accg_ref, accu_ref = next(it), next(it), next(it)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        accg_ref[...] = jnp.zeros_like(accg_ref)
        accu_ref[...] = jnp.zeros_like(accu_ref)

    a = a_ref[...]                   # fetched once, used against both Bs
    bg = bg_ref[...]
    bu = bu_ref[...]
    if bg.dtype == jnp.int8 and a.dtype != jnp.int8:
        bg = bg.astype(a.dtype)      # W8A16: in-register int8 -> a-dtype
        bu = bu.astype(a.dtype)
    accg_ref[...] += jnp.dot(a, bg, preferred_element_type=accg_ref.dtype)
    accu_ref[...] += jnp.dot(a, bu, preferred_element_type=accu_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        xg = accg_ref[...].astype(jnp.float32)
        xu = accu_ref[...].astype(jnp.float32)
        if sg_ref is not None:
            xg = xg * sg_ref[...]
            xu = xu * su_ref[...]
        o_ref[...] = (ACTIVATIONS[activation](xg) * xu).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "activation", "interpret"))
def gemm_gated(a: jax.Array, b_gate: jax.Array, b_up: jax.Array, *,
               tile: TileConfig, activation: str = "silu",
               out_dtype=None,
               bg_scale: Optional[jax.Array] = None,
               bu_scale: Optional[jax.Array] = None,
               interpret: bool = False) -> jax.Array:
    """C[m,n] = act(A @ B_gate) * (A @ B_up), single resident A stream.

    Dims must be multiples of the tile (ops.py pads).  ``bg_scale`` /
    ``bu_scale`` (1, n) fp32 turn on the fused weight-dequant path (both
    B operands must then be int8); scales apply to their accumulators on
    the flush, before the gate.
    """
    m, k = a.shape
    k2, n = b_gate.shape
    assert k == k2 and b_up.shape == (k, n), \
        (a.shape, b_gate.shape, b_up.shape)
    assert tile.strategy == "aie", \
        f"gemm_gated is output-stationary only (got {tile.strategy!r})"
    assert activation in ACTIVATIONS, activation
    assert (bg_scale is None) == (bu_scale is None), \
        "quantize both B operands or neither"
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b_gate.shape, tile)
    acc = acc_dtype(a.dtype)
    out_dtype = out_dtype or (a.dtype if a.dtype != jnp.int8
                              else jnp.float32)
    grid = (m // bm, n // bn, k // bk)

    operands = [a, b_gate, b_up]
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
        pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
    ]
    if bg_scale is not None:
        assert b_gate.dtype == jnp.int8 and b_up.dtype == jnp.int8
        assert bg_scale.shape == (1, n) and bu_scale.shape == (1, n)
        operands += [bg_scale.astype(jnp.float32),
                     bu_scale.astype(jnp.float32)]
        in_specs += [pl.BlockSpec((1, bn), lambda i, j, l: (0, j)),
                     pl.BlockSpec((1, bn), lambda i, j, l: (0, j))]

    kernel = functools.partial(_gated_kernel, activation,
                               bg_scale is not None)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc),
                        pltpu.VMEM((bm, bn), acc)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
