"""Blocked online-softmax attention in pure lax ops (no Pallas).

This is the XLA-lowerable twin of :mod:`repro.kernels.flash_attention`:
same tiling (q chunks x kv chunks), same online-softmax recurrence, but
expressed with ``lax.map``/``lax.scan`` so it runs and *lowers* on any
backend — which is what the multi-pod dry-run compiles.  Peak score
memory is (b, heads, bq, bkv) instead of (b, heads, S, S): at 32k
prefill that's the difference between ~8 MB and ~4 GB per device.

The kv-step is wrapped in ``jax.checkpoint`` so backward recomputes
scores instead of storing every chunk's probabilities (the standard
flash-attention backward trade, here at the XLA level).

GQA is handled by head-grouped einsums (no kv-head materialization).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ref import NEG_INF


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq",
                                             "bkv", "scale", "q_offset"))
def attention_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      scale: Optional[float] = None,
                      q_offset: Optional[int] = None,
                      bq: int = 512, bkv: int = 1024) -> jax.Array:
    """q: (b, sq, hq, d); k/v: (b, skv, hkv, d) -> (b, sq, hq, d)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    if q_offset is None:
        q_offset = skv - sq
    scale_f = float(scale if scale is not None else d ** -0.5)
    bq = min(bq, sq)
    bkv = min(bkv, skv)

    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    nq, nk = (sq + pad_q) // bq, (skv + pad_kv) // bkv

    # (nq, b, bq, hkv, g, d) — q-chunks on the leading map axis
    qc = qp.reshape(b, nq, bq, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)
    kc = kp.reshape(b, nk, bkv, hkv, d).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(b, nk, bkv, hkv, d).transpose(1, 0, 2, 3, 4)
    qpos = (jnp.arange(nq * bq) + q_offset).reshape(nq, bq)
    kpos = jnp.arange(nk * bkv).reshape(nk, bkv)

    def kv_step(carry, inp):
        m, l, acc, qck, qpos_c = carry
        kck, vck, kpos_c = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk",
                       qck.astype(jnp.float32) * scale_f,
                       kck.astype(jnp.float32))
        valid = (kpos_c < skv)[None, :]
        if causal:
            valid = valid & (kpos_c[None, :] <= qpos_c[:, None])
        if window > 0:
            valid = valid & (kpos_c[None, :] > qpos_c[:, None] - window)
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(valid[None, None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc * alpha[..., 0, None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vck.astype(jnp.float32))
        return (m_new, l_new, acc_new, qck, qpos_c), None

    def q_chunk(args):
        qck, qpos_c = args
        m0 = jnp.full((b, hkv, g, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq, 1), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc, _, _), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (m0, l0, a0, qck, qpos_c),
            (kc, vc, kpos))
        out = acc / jnp.where(l > 0, l, 1.0)
        # (b, hkv, g, bq, d) -> (b, bq, hkv, g, d)
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    out = jax.lax.map(q_chunk, (qc, qpos))            # (nq, b, bq, hkv, g, d)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, hq, d)
    return out[:, :sq]
