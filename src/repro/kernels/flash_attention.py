"""Blocked online-softmax attention (flash attention) for TPU Pallas.

Why it lives here: the 32k-prefill and 4k-train shapes make attention the
second GEMM hot-spot after the projections, and the paper's methodology
(VMEM-tiled blocks + analytically chosen block shapes) applies directly —
q/k/v tiles are sized by the same VMEM footprint model used for the GEMM
kernels.

Features: causal masking, sliding-window (SWA) masking, GQA via
index-mapped kv heads (no materialized head repeat), fp32 online softmax
with the standard post-exp re-mask so fully-masked rows stay exact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import NEG_INF
from repro.kernels import _compiler_params

LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  bq: int, bkv: int, kv_len: int):
    qi = pl.program_id(1)
    kvi = pl.program_id(2)

    @pl.when(kvi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)

    q_pos = (qi * bq + q_offset
             + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0))
    k_pos = kvi * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, :1]                             # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)                       # exact masked rows
    alpha = jnp.exp(m_prev - m_new)                   # (bq, 1)

    l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha \
        + jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kvi == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[:, :1]
        out = acc_ref[...] / jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bkv", "scale", "q_offset", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None, q_offset: int | None = None,
                    bq: int = 512, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (b, sq, hq, d); k, v: (b, skv, hkv, d); returns (b, sq, hq, d).

    hq % hkv == 0 (GQA: kv head = q head // group, via BlockSpec index
    maps).  d is padded to the 128-lane width inside; sq/skv are padded to
    block multiples (scores for padded kv positions are masked by
    ``kv_len``).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0
    groups = hq // hkv
    if q_offset is None:
        q_offset = skv - sq
    scale = float(scale if scale is not None else d ** -0.5)

    dp = max(LANES, ((d + LANES - 1) // LANES) * LANES)
    bq = min(bq, max(8, 1 << (sq - 1).bit_length()))
    bkv = min(bkv, max(128, 1 << (skv - 1).bit_length()))
    sq_p = ((sq + bq - 1) // bq) * bq
    skv_p = ((skv + bkv - 1) // bkv) * bkv

    def pad(x, s_p):
        return jnp.pad(x, ((0, 0), (0, s_p - x.shape[1]), (0, 0),
                           (0, dp - d)))

    # (b, h, s, d) layout so the last two dims tile (s, d).
    qt = pad(q, sq_p).transpose(0, 2, 1, 3)
    kt = pad(k, skv_p).transpose(0, 2, 1, 3)
    vt = pad(v, skv_p).transpose(0, 2, 1, 3)

    grid = (b * hq, sq_p // bq, skv_p // bkv)

    def q_map(bh, qi, kvi):
        return (bh // hq, bh % hq, qi, 0)

    def kv_map(bh, qi, kvi):
        return (bh // hq, (bh % hq) // groups, kvi, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, bq=bq, bkv=bkv, kv_len=skv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dp), q_map),
            pl.BlockSpec((1, 1, bkv, dp), kv_map),
            pl.BlockSpec((1, 1, bkv, dp), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dp), q_map),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, LANES), jnp.float32),   # running denom
            pltpu.VMEM((bq, dp), jnp.float32),      # output accumulator
        ],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)

    return out.transpose(0, 2, 1, 3)[:, :sq, :, :d]
