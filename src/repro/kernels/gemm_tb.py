"""A-stationary Pallas GEMM — the Stratix Tensor-Block dataflow on TPU.

Paper mapping (SS IV-B): on Stratix, a 3x10 A block is pinned in each
TB's ping-pong registers while a stream of B blocks is broadcast past it;
partial dot products cascade outward and are accumulated *into the C
buffer by PL soft-logic adders* (read-modify-write, II=1).  The TPU
analogue:

* within one ``pallas_call`` the grid is (m, n) with n innermost — the A
  block is fetched once per m row and stays VMEM-resident while the B
  stream (all n blocks) passes it: weight-stationary, like the TB
  registers;
* the reduction (K) dimension is chunked *outside* the kernel; each
  k-chunk re-reads and updates C in place via ``input_output_aliasing``
  — exactly the paper's PL-accumulator pattern (and its V*Y*K-dimension
  tile reduction).

This has a genuinely different traffic signature from the output-
stationary 'aie' kernel (C is rmw-ed gk times but A is read once), which
is why the DSE searches both.

The *final* k-chunk is special: it is the one visit that knows the full
accumulator, so the fused epilogue (b_scale dequant, bias, activation,
residual, optional int8 output quantization) runs inside that last
kernel body before the single out-dtype C write — the tb analogue of the
aie kernel's last-k flush.

Feasibility: the requested ``bk`` k-chunk must keep the resident
(bm, bk) A block plus the streaming B/C blocks inside VMEM.  The DSE
only emits tiles it has already checked, but explicit/legacy tiles can
bust for large K — :func:`gemm_tb` re-checks against
:func:`repro.core.memory_model.fits_vmem` and transparently refines the
k-chunking (smaller ``bk``; the result is identical, only the chunk loop
gets longer) rather than over-subscribing VMEM.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import memory_model
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import _compiler_params, acc_dtype
from repro.kernels.epilogue import apply_epilogue


def _gemm_tb_kernel(a_ref, b_ref, c_ref, o_ref):
    # One (m,n) visit: accumulate this k-chunk's contribution onto C.
    # A quantized B stream arrives as int8 (one byte/element in VMEM) and
    # is dequantized in-register to A's dtype; per-output-channel scales
    # commute with the k-sum, so they are applied once after the cascade
    # (gemm_tb), like the paper's outward-cascaded TB accumulation.
    b = b_ref[...]
    if b.dtype == jnp.int8 and a_ref.dtype != jnp.int8:    # W8A16 only
        b = b.astype(a_ref.dtype)
    o_ref[...] = c_ref[...] + jnp.dot(a_ref[...], b,
                                      preferred_element_type=o_ref.dtype)


def _gemm_tb_final_kernel(activation, has_scale, has_bias, has_res,
                          has_oscale, *refs):
    """Last k-chunk: finish the accumulation AND apply the fused epilogue
    before the single out-dtype C write (the tb flush)."""
    it = iter(refs)
    a_ref, b_ref, c_ref = next(it), next(it), next(it)
    s_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_res else None
    osc_ref = next(it) if has_oscale else None
    o_ref = next(it)
    b = b_ref[...]
    if b.dtype == jnp.int8 and a_ref.dtype != jnp.int8:    # W8A16 only
        b = b.astype(a_ref.dtype)
    acc = c_ref[...] + jnp.dot(a_ref[...], b,
                               preferred_element_type=c_ref.dtype)
    x = acc.astype(jnp.float32)
    if s_ref is not None:
        x = x * s_ref[...]
    x = apply_epilogue(
        x, activation=activation,
        bias=bias_ref[...] if bias_ref is not None else None,
        residual=res_ref[...] if res_ref is not None else None,
        out_scale=osc_ref[...] if osc_ref is not None else None)
    o_ref[...] = x.astype(o_ref.dtype)


def _tb_call(a, b, c, *, bm: int, bn: int, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gemm_tb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # A row resident
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),   # B stream
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # C rmw in
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        input_output_aliases={2: 0},                      # C updated in place
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)


def _tb_call_final(a, b, c, *, bm: int, bn: int, out_dtype, b_scale,
                   bias, residual, out_scale, activation, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn)
    operands = [a, b, c]
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
    ]
    if b_scale is not None:
        operands.append(b_scale.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
    if bias is not None:
        operands.append(bias.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
    if residual is not None:
        operands.append(residual)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
    if out_scale is not None:
        operands.append(out_scale.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j: (0, 0)))
    kernel = functools.partial(
        _gemm_tb_final_kernel, activation, b_scale is not None,
        bias is not None, residual is not None, out_scale is not None)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


def feasible_bk(m: int, k: int, n: int, tile: TileConfig, a_dtype,
                b_dtype, out_dtype, acc_dtype, epilogue: str = "") -> int:
    """Largest k-chunk <= tile.bk that divides K, is lane-aligned, and
    keeps the tb working set (resident (bm, bk) A + streamed B/C blocks
    + any fused bias/residual blocks, via ``epilogue``) inside the VMEM
    budget.  Returns 0 when even bk=128 busts (then the (bm, bn) blocks
    themselves are infeasible — the caller should use a different tile
    or the 'aie' strategy)."""
    def fits(bk: int) -> bool:
        p = GemmProblem(m, k, n, str(jnp.dtype(a_dtype)),
                        str(jnp.dtype(out_dtype)),
                        str(jnp.dtype(acc_dtype)), str(jnp.dtype(b_dtype)),
                        epilogue)
        return memory_model.fits_vmem(
            TileConfig(tile.bm, bk, tile.bn, "tb"), p)

    for bk in range(min(tile.bk, k), 0, -128):
        if k % bk == 0 and fits(bk):
            return bk
    return 0


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "activation", "interpret"))
def gemm_tb(a: jax.Array, b: jax.Array, *, tile: TileConfig,
            out_dtype=None, b_scale: Optional[jax.Array] = None,
            bias: Optional[jax.Array] = None,
            residual: Optional[jax.Array] = None,
            out_scale: Optional[jax.Array] = None,
            activation: Optional[str] = None,
            interpret: bool = False) -> jax.Array:
    """C[m,n] = epilogue(sum_k A[m,k] B[k,n]), A-stationary with k-chunked
    PL-style accumulation.  Dims must be tile multiples (ops.py pads).

    ``b_scale`` (1, n) fp32 turns on the fused weight-dequant path:
    ``b`` must then be int8 (streamed at one byte/element, dequantized
    in-register inside the kernel body for W8A16; int32 accumulation
    when A is int8 too) and the per-output-channel scale is applied once
    after the last k-chunk cascade.

    Epilogue operands (``bias`` (1, n), ``activation``, ``residual``
    (m, n), ``out_scale`` (1, 1) int8 output quantization) fuse into the
    final k-chunk's kernel body — the accumulator is completed and
    post-processed in VMEM, written once at ``out_dtype``.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    if b_scale is not None:
        assert b.dtype == jnp.int8, b.dtype
        assert b_scale.shape == (1, n), (b_scale.shape, n)
    if bias is not None:
        assert bias.shape == (1, n), (bias.shape, n)
    if residual is not None:
        assert residual.shape == (m, n), (residual.shape, (m, n))
    if out_scale is not None:
        assert out_scale.shape == (1, 1), out_scale.shape
    acc = acc_dtype(a.dtype)
    fused = (b_scale is not None or bias is not None or residual is not None
             or out_scale is not None or activation is not None)
    out_dtype = out_dtype or (jnp.float32 if fused else acc)

    # Feasibility (satellite): the (bm, bk) A block is VMEM-resident for
    # a whole n sweep — refine the k-chunking when the requested bk would
    # over-subscribe VMEM (identical result, longer chunk loop).  The
    # fused final-chunk operands (bias/residual blocks) count too.
    from repro.kernels.epilogue import Epilogue
    ep_key = Epilogue.from_args(bias, activation, residual, out_scale).key
    bk_fit = feasible_bk(m, k, n, tile, a.dtype, b.dtype, out_dtype, acc,
                         epilogue=ep_key)
    if bk_fit == 0:
        raise ValueError(
            f"tb tile {tile} infeasible for ({m},{k},{n}) even at bk=128:"
            " (bm, bn) blocks bust VMEM — shrink the tile or use 'aie'")
    bk = min(bk, bk_fit)

    gk = k // bk
    c = jnp.zeros((m, n), acc)
    for kk in range(gk - 1):        # k-chunk loop = the paper's V loop
        a_k = jax.lax.slice(a, (0, kk * bk), (m, (kk + 1) * bk))
        b_k = jax.lax.slice(b, (kk * bk, 0), ((kk + 1) * bk, n))
        c = _tb_call(a_k, b_k, c, bm=bm, bn=bn, interpret=interpret)
    a_k = jax.lax.slice(a, (0, (gk - 1) * bk), (m, k))
    b_k = jax.lax.slice(b, ((gk - 1) * bk, 0), (k, n))
    if not fused:
        c = _tb_call(a_k, b_k, c, bm=bm, bn=bn, interpret=interpret)
        return c.astype(out_dtype)
    return _tb_call_final(a_k, b_k, c, bm=bm, bn=bn, out_dtype=out_dtype,
                          b_scale=b_scale, bias=bias, residual=residual,
                          out_scale=out_scale, activation=activation,
                          interpret=interpret)
