"""A-stationary Pallas GEMM — the Stratix Tensor-Block dataflow on TPU.

Paper mapping (SS IV-B): on Stratix, a 3x10 A block is pinned in each
TB's ping-pong registers while a stream of B blocks is broadcast past it;
partial dot products cascade outward and are accumulated *into the C
buffer by PL soft-logic adders* (read-modify-write, II=1).  The TPU
analogue:

* within one ``pallas_call`` the grid is (m, n) with n innermost — the A
  block is fetched once per m row and stays VMEM-resident while the B
  stream (all n blocks) passes it: weight-stationary, like the TB
  registers;
* the reduction (K) dimension is chunked *outside* the kernel; each
  k-chunk re-reads and updates C in place via ``input_output_aliasing``
  — exactly the paper's PL-accumulator pattern (and its V*Y*K-dimension
  tile reduction).

This has a genuinely different traffic signature from the output-
stationary 'aie' kernel (C is rmw-ed gk times but A is read once), which
is why the DSE searches both.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import TileConfig
from repro.kernels import _compiler_params


def _acc_dtype(in_dtype) -> jnp.dtype:
    return jnp.int32 if in_dtype == jnp.int8 else jnp.float32


def _gemm_tb_kernel(a_ref, b_ref, c_ref, o_ref):
    # One (m,n) visit: accumulate this k-chunk's contribution onto C.
    # A quantized B stream arrives as int8 (one byte/element in VMEM) and
    # is dequantized in-register to A's dtype; per-output-channel scales
    # commute with the k-sum, so they are applied once after the cascade
    # (gemm_tb), like the paper's outward-cascaded TB accumulation.
    b = b_ref[...]
    if b.dtype != a_ref.dtype:
        b = b.astype(a_ref.dtype)
    o_ref[...] = c_ref[...] + jnp.dot(a_ref[...], b,
                                      preferred_element_type=o_ref.dtype)


def _tb_call(a, b, c, *, bm: int, bn: int, interpret: bool):
    m, k = a.shape
    _, n = b.shape
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _gemm_tb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # A row resident
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),   # B stream
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),  # C rmw in
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        input_output_aliases={2: 0},                      # C updated in place
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c)


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "interpret"))
def gemm_tb(a: jax.Array, b: jax.Array, *, tile: TileConfig,
            out_dtype=None, b_scale: Optional[jax.Array] = None,
            interpret: bool = False) -> jax.Array:
    """C[m,n] = sum_k A[m,k] B[k,n], A-stationary with k-chunked
    PL-style accumulation.  Dims must be tile multiples (ops.py pads).

    ``b_scale`` (1, n) fp32 turns on the fused weight-dequant path:
    ``b`` must then be int8 (streamed at one byte/element, dequantized
    in-register inside the kernel body for W8A16; int32 accumulation
    when A is int8 too) and the per-output-channel scale is applied once
    after the last k-chunk cascade.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    if b_scale is not None:
        assert b.dtype == jnp.int8, b.dtype
        assert b_scale.shape == (1, n), (b_scale.shape, n)
    acc = _acc_dtype(a.dtype)
    out_dtype = out_dtype or (jnp.float32 if b_scale is not None else acc)
    gk = k // bk
    c = jnp.zeros((m, n), acc)
    for kk in range(gk):            # k-chunk loop = the paper's V loop
        a_k = jax.lax.slice(a, (0, kk * bk), (m, (kk + 1) * bk))
        b_k = jax.lax.slice(b, (kk * bk, 0), ((kk + 1) * bk, n))
        c = _tb_call(a_k, b_k, c, bm=bm, bn=bn, interpret=interpret)
    if b_scale is not None:
        c = c.astype(jnp.float32) * b_scale.astype(jnp.float32)
    return c.astype(out_dtype)
