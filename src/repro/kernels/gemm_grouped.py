"""Grouped ragged GEMM — one output-stationary sweep over concatenated
expert token groups (the MoE expert compute, megablocks-style).

Problem: ``A`` is ``(m, k)`` tokens *sorted by expert* so each expert's
rows are contiguous (``group_sizes[e]`` rows for expert ``e``, groups
packed from row 0, zero tail); ``B`` is the ``(E, k, n)`` expert weight
bank.  A dense formulation pads every group to capacity and multiplies
the padding at full price; this kernel visits only the m-tiles a group
actually covers.

Paper mapping: this is the GotoBLAS2-on-Versal move (PAPERS.md) — one
hierarchically tiled micro-kernel sweeping irregular panels, instead of
per-panel (per-expert) dispatch.  The steering trick is the same scalar
prefetch PR 8 used for KV page tables: three CSR-style tables ride
``PrefetchScalarGridSpec`` scalar memory and the ``index_map``s read
them to pick each grid step's A row-tile and B expert slice:

    group_offsets : (E+1,)  row offset of each group (cumsum, leading 0)
    group_ids     : (I,)    expert id of grid instance i
    m_tile_ids    : (I,)    A/C m-tile of grid instance i

with ``I = tiles_m + E - 1`` static (a tile straddling a group boundary
is visited once per group it hosts).  The actual instance count is
dynamic — the grid's middle dimension is a traced scalar, so tile visits
scale with the *real* routed token counts, not the static worst case.

A straddling tile masks the foreign rows on the flush: consecutive
instances of the same output tile blend via ``where(mask, x, out)``, so
each C element is written by exactly the instance that owns its row and
the accumulation per tile is exact.  Rows beyond ``sum(group_sizes)``
(dropped-token tail) are zeroed outside the kernel.

The W8A16 ``{q, scale}`` dequant path and the bias/activation
``Epilogue`` fuse on the last-k flush exactly like ``gemm_aie`` —
per-expert ``(E, 1, n)`` scale/bias vectors are steered by the same
``group_ids`` table.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.tiling import TileConfig
from repro.kernels import _compiler_params, acc_dtype
from repro.kernels.epilogue import apply_epilogue


def group_metadata(group_sizes: jax.Array, m: int, bm: int
                   ) -> Tuple[Tuple[jax.Array, jax.Array, jax.Array],
                              jax.Array]:
    """CSR-style steering tables for the grouped sweep.

    Returns ``((group_offsets, group_ids, m_tile_ids), num_instances)``.
    The tables have static length ``tiles_m + E - 1`` (the worst case:
    every group boundary lands mid-tile); ``num_instances`` is the traced
    number of live entries — empty groups contribute none, and a group
    contributes one instance per m-tile it overlaps.  Entries past
    ``num_instances`` are repeat-padding and must never be executed.
    """
    e = group_sizes.shape[0]
    tiles_m = m // bm
    sizes = group_sizes.astype(jnp.int32)
    ends = jnp.cumsum(sizes)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), ends]).astype(jnp.int32)
    starts = offsets[:-1]
    # m-tiles each group overlaps: [floor(start/bm), ceil(end/bm))
    tiles_per_group = jnp.where(
        sizes == 0, 0, (ends + bm - 1) // bm - starts // bm)
    n_inst = tiles_m + e - 1
    group_ids = jnp.repeat(jnp.arange(e, dtype=jnp.int32), tiles_per_group,
                           total_repeat_length=n_inst)
    # visits per m-tile: 1 + number of (non-empty) groups starting mid-tile
    mid_start = (starts % bm != 0) & (sizes > 0)
    start_tile = jnp.where(mid_start, starts // bm, tiles_m)
    visits = jnp.ones((tiles_m,), jnp.int32).at[start_tile].add(
        1, mode="drop")
    m_tile_ids = jnp.repeat(jnp.arange(tiles_m, dtype=jnp.int32), visits,
                            total_repeat_length=n_inst)
    num_instances = tiles_per_group.sum()
    return (offsets, group_ids, m_tile_ids), num_instances


def _grouped_kernel(activation, has_scale, has_bias, bm, bn, *refs):
    """Body for every grouped variant.  ``refs``: the three prefetched
    tables, then a, b, [scale], [bias], the output ref and the
    accumulator scratch."""
    it = iter(refs)
    offs_ref, gids_ref, tids_ref = next(it), next(it), next(it)
    a_ref, b_ref = next(it), next(it)
    s_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    o_ref, acc_ref = next(it), next(it)
    gi = pl.program_id(1)
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    b = b_ref[...]
    # W8A16: widen an int8 B bank in-register to A's dtype (gemm_aie rule)
    if b.dtype == jnp.int8 and a.dtype != jnp.int8:
        b = b.astype(a.dtype)
    acc_ref[...] += jnp.dot(a, b, preferred_element_type=acc_ref.dtype)

    @pl.when(k_i == pl.num_programs(2) - 1)
    def _flush():
        g = gids_ref[gi]
        x = acc_ref[...]
        if has_scale or has_bias or activation is not None:
            x = x.astype(jnp.float32)
            if s_ref is not None:
                x = x * s_ref[...]
            x = apply_epilogue(
                x, activation=activation,
                bias=bias_ref[...] if bias_ref is not None else None)
        x = x.astype(o_ref.dtype)
        # blend: only the rows this instance's group owns are written,
        # so a straddling tile's other visitor(s) keep their rows intact
        rows = tids_ref[gi] * bm \
            + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
        mask = (rows >= offs_ref[g]) & (rows < offs_ref[g + 1])
        o_ref[...] = jnp.where(mask, x, o_ref[...])


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "activation", "interpret"))
def gemm_grouped(a: jax.Array, b: jax.Array, group_sizes: jax.Array, *,
                 tile: TileConfig, out_dtype=None,
                 b_scale: Optional[jax.Array] = None,
                 bias: Optional[jax.Array] = None,
                 activation: Optional[str] = None,
                 interpret: bool = False) -> jax.Array:
    """``C[r, n] = epilogue(sum_k A[r, k] B[g(r), k, n])`` where ``g(r)``
    is the group owning row ``r`` under ``group_sizes``.

    ``a``: (m, k) group-sorted rows; ``b``: (E, k, n) bank.  Dims must be
    tile multiples (api.py pads).  Rows at and beyond
    ``sum(group_sizes)`` come back zero.  ``b_scale`` (E, 1, n) fp32
    turns on the fused W8A16 dequant (``b`` int8); ``bias`` (E, 1, n) is
    a per-expert bias, applied with ``activation`` on the flush.
    """
    m, k = a.shape
    e, k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert group_sizes.shape == (e,), (group_sizes.shape, e)
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    acc = acc_dtype(a.dtype)
    fused = b_scale is not None or bias is not None or activation is not None
    out_dtype = out_dtype or (jnp.float32 if fused else acc)
    (offsets, group_ids, m_tile_ids), num_instances = \
        group_metadata(group_sizes, m, bm)
    grid = (n // bn, num_instances, k // bk)

    operands = [a, b]
    in_specs = [
        pl.BlockSpec((bm, bk),
                     lambda ni, gi, ki, offs, gids, tids: (tids[gi], ki)),
        pl.BlockSpec((None, bk, bn),
                     lambda ni, gi, ki, offs, gids, tids:
                     (gids[gi], ki, ni)),
    ]
    vec_map = (lambda ni, gi, ki, offs, gids, tids: (gids[gi], 0, ni))
    if b_scale is not None:
        assert b.dtype == jnp.int8, b.dtype
        assert b_scale.shape == (e, 1, n), (b_scale.shape, (e, 1, n))
        operands.append(b_scale.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((None, 1, bn), vec_map))
    if bias is not None:
        assert bias.shape == (e, 1, n), (bias.shape, (e, 1, n))
        operands.append(bias.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((None, 1, bn), vec_map))

    kernel = functools.partial(_grouped_kernel, activation,
                               b_scale is not None, bias is not None,
                               bm, bn)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (bm, bn),
            lambda ni, gi, ki, offs, gids, tids: (tids[gi], ni)),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(offsets, group_ids, m_tile_ids, *operands)
    # unvisited tail tiles (and straddle rows past the last group) hold
    # whatever the out buffer held — zero everything past the live rows
    live = jnp.arange(m, dtype=jnp.int32)[:, None] < offsets[-1]
    return jnp.where(live, out, jnp.zeros((), out.dtype))


@functools.partial(jax.jit, static_argnames=("tile", "out_dtype",
                                             "activation"))
def gemm_grouped_blocked_ref(a: jax.Array, b: jax.Array,
                             group_sizes: jax.Array, *, tile: TileConfig,
                             out_dtype=None,
                             b_scale: Optional[jax.Array] = None,
                             bias: Optional[jax.Array] = None,
                             activation: Optional[str] = None
                             ) -> jax.Array:
    """XLA gather oracle at the kernel's exact tile/accumulation order.

    Replays the grouped sweep instance by instance with dynamic-slice
    gathers — same (bm, bk)x(bk, bn) dots in the same k order, same
    flush, same blend — so interpret-mode kernel output must match
    *bitwise*.  O(instances) sequential; test-sized problems only (the
    fast dispatch oracle is ``ref.gemm_grouped_ref``).
    """
    m, k = a.shape
    e, _, n = b.shape
    bm, bk, bn = tile.bm, tile.bk, tile.bn
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, \
        (a.shape, b.shape, tile)
    acc_d = acc_dtype(a.dtype)
    fused = b_scale is not None or bias is not None or activation is not None
    out_dtype = out_dtype or (jnp.float32 if fused else acc_d)
    (offsets, group_ids, m_tile_ids), num_instances = \
        group_metadata(group_sizes, m, bm)
    gk, gn = k // bk, n // bn
    rows_iota = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)

    def instance(i, out):
        g, t = group_ids[i], m_tile_ids[i]
        a_row = jax.lax.dynamic_slice(a, (t * bm, 0), (bm, k))
        w = jax.lax.dynamic_index_in_dim(b, g, 0, keepdims=False)

        def column(ni, out):
            def kstep(ki, acc):
                ab = jax.lax.dynamic_slice(a_row, (0, ki * bk), (bm, bk))
                wb = jax.lax.dynamic_slice(w, (ki * bk, ni * bn), (bk, bn))
                if wb.dtype == jnp.int8 and ab.dtype != jnp.int8:
                    wb = wb.astype(ab.dtype)
                return acc + jnp.dot(ab, wb,
                                     preferred_element_type=acc.dtype)
            x = jax.lax.fori_loop(0, gk, kstep,
                                  jnp.zeros((bm, bn), acc_d))
            if fused:
                x = x.astype(jnp.float32)
                if b_scale is not None:
                    x = x * jax.lax.dynamic_slice(
                        b_scale, (g, 0, ni * bn), (1, 1, bn))[0]
                x = apply_epilogue(
                    x, activation=activation,
                    bias=jax.lax.dynamic_slice(
                        bias, (g, 0, ni * bn), (1, 1, bn))[0]
                    if bias is not None else None)
            x = x.astype(out.dtype)
            rows = t * bm + rows_iota
            mask = (rows >= offsets[g]) & (rows < offsets[g + 1])
            cur = jax.lax.dynamic_slice(out, (t * bm, ni * bn), (bm, bn))
            return jax.lax.dynamic_update_slice(
                out, jnp.where(mask, x, cur), (t * bm, ni * bn))

        return jax.lax.fori_loop(0, gn, column, out)

    def guarded(i, out):
        return jax.lax.cond(i < num_instances,
                            lambda o: instance(i, o), lambda o: o, out)

    out = jax.lax.fori_loop(0, group_ids.shape[0], guarded,
                            jnp.zeros((m, n), out_dtype))
    live = jnp.arange(m, dtype=jnp.int32)[:, None] < offsets[-1]
    return jnp.where(live, out, jnp.zeros((), out.dtype))
