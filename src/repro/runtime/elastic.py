"""Elastic re-mesh: resume a run on a different device count/topology.

Because checkpoints store logical (unsharded) arrays with a manifest
(:mod:`repro.checkpoint.checkpointer`) and shardings are derived from the
(config, mesh) pair by the layout engine, shrinking or growing the mesh
is just: build the new mesh -> re-derive shardings -> restore with
``device_put`` onto them.  The data pipeline is deterministic in
(step, row-range), so the global batch re-partitions cleanly too.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.dist import layout
from repro.optim import adafactor, adamw


def state_specs(target_state, cfg: ModelConfig, mesh: jax.sharding.Mesh,
                layout_name=None):
    """PartitionSpecs for a TrainState: params via the layout engine,
    optimizer state via the optimizer's own ``state_specs`` (Adafactor's
    factored stats need rank-adjusted specs — a 1T-param model cannot
    afford replicated row/col moments)."""
    p_specs = layout.param_specs(target_state.params, cfg, mesh,
                                 layout_name)
    opt = target_state.opt
    if isinstance(opt, adamw.AdamWState):
        opt_specs = adamw.state_specs(p_specs, target_state.params)
    elif isinstance(opt, adafactor.AdafactorState):
        opt_specs = adafactor.state_specs(p_specs, target_state.params)
    else:                                     # unknown: replicate
        opt_specs = jax.tree.map(lambda _: P(), opt)
    return type(target_state)(params=p_specs, opt=opt_specs, step=P())


def state_shardings(target_state, cfg: ModelConfig,
                    mesh: jax.sharding.Mesh, layout_name=None):
    specs = state_specs(target_state, cfg, mesh, layout_name)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def remesh_restore(ckpt: Checkpointer, target_state, cfg: ModelConfig,
                   new_mesh: jax.sharding.Mesh,
                   step: Optional[int] = None):
    """Restore ``target_state`` (TrainState-shaped pytree of arrays or
    ShapeDtypeStructs) re-sharded onto ``new_mesh``."""
    shardings = state_shardings(target_state, cfg, new_mesh)
    return ckpt.restore(target_state, step=step, shardings=shardings)
