"""Fault-tolerance machinery: step watchdog (straggler detection),
failure injection, and a resumable step-runner.

At 1000+ nodes the failure model is: (a) hard node loss -> restart from
the last committed checkpoint (possibly on fewer nodes — see
``repro.runtime.elastic``); (b) stragglers -> detect via step-time
outliers and surface a mitigation decision (re-shard / evict / backup
step).  Both paths are exercised in tests via ``FailureInjector``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional


class PreemptionError(RuntimeError):
    """Simulated node loss / preemption."""


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StepWatchdog:
    """Tracks step durations; flags steps slower than
    ``threshold x running median`` as stragglers."""

    def __init__(self, threshold: float = 2.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, duration: float) -> Optional[StragglerEvent]:
        hist = self.durations[-self.window:]
        self.durations.append(duration)
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if duration > self.threshold * med:
                ev = StragglerEvent(step, duration, med)
                self.events.append(ev)
                return ev
        return None


class FailureInjector:
    """Deterministically raises PreemptionError at chosen steps (tests)."""

    def __init__(self, fail_at_steps=()):
        self.fail_at = set(fail_at_steps)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise PreemptionError(f"injected failure at step {step}")


def run_resumable(total_steps: int, run_step: Callable[[int], None],
                  restore: Callable[[], int],
                  max_restarts: int = 10) -> int:
    """Drive ``run_step`` from the restored step to ``total_steps``,
    restarting from ``restore()`` on preemption.  Returns restart count."""
    restarts = 0
    while True:
        start = restore()
        try:
            for step in range(start, total_steps):
                run_step(step)
            return restarts
        except PreemptionError:
            restarts += 1
            if restarts > max_restarts:
                raise
