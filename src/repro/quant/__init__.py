"""Int8 quantization for serving — the paper's precision scheme as a
first-class inference mode.

The paper's entire evaluation is int8 GEMM (8-bit operands, 32-bit
accumulation).  Training here stays bf16, but the serving path can load
weights quantized to symmetric per-output-channel int8:
:func:`quantize_params` rewrites every dense projection leaf into a
``{"q": int8 (k,n), "scale": f32 (1,n)}`` struct, and
``repro.ops.gemm`` consumes those structs through the *fused*
Pallas path: the int8 block streams into VMEM at one byte/element and is
dequantized in-register inside the kernel body, so weight HBM traffic —
the dominant term of batched decode — halves vs bf16 (W8A16).

Two serving modes:

* **W8A16** (default with quantized params): bf16 activations against
  in-register-dequantized int8 weights, f32 accumulation.
* **W8A8** (:func:`set_activation_mode`, or ``REPRO_W8A8=1``):
  activations are dynamically quantized per-row to int8 at each GEMM, the
  kernel runs int8 x int8 with int32 accumulation and applies the weight
  scale on flush — the paper's exact scheme — and the per-row activation
  scale is applied outside.  Decode-oriented: the w8a8 path is
  forward-only (no gradient through the activation quantizer).

Only leaves that flow through ``ops.gemm``/``ops.gemm_grouped`` are
rewritten (attention and MLP projections, SSM/RG-LRU projections,
lm_head, and the stacked MoE expert banks — the grouped ragged kernel
dequantizes each (bk, bn) expert panel in-register with its per-expert
(1, n) scale row); embeddings (gather), the MoE router, and norms keep
their dtype.
"""

from __future__ import annotations

import os
import re
from typing import Tuple

import jax
import jax.numpy as jnp

# leaves consumed via ops.gemm(x, w) with w: (k, n), plus the stacked
# (E, k, n) MoE expert banks consumed via ops.gemm_grouped (their
# per-output-channel scales quantize to (E, 1, n) — exactly the
# per-expert scale rows the grouped kernel's epilogue streams)
QUANT_PATHS = re.compile(
    r"(attn|cross)/w[qkvo]$|mlp/w_(gate|up|down|in|out)$"
    r"|moe/w_(gate|up|down)$"
    r"|(mixer|rec)/(in|out)_proj$|rec/w_[ri]$|lm_head$")


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "scale"}


def quantize_weight(w: jax.Array) -> dict:
    """Symmetric per-output-channel (axis -2 = k reduced) int8."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_weight(wq: dict, dtype) -> jax.Array:
    return (wq["q"].astype(jnp.float32) * wq["scale"]).astype(dtype)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_params(params) -> Tuple[dict, int]:
    """Quantize every GEMM weight leaf.  Returns (params', n_quantized).

    Works on stacked (scan) leaves too — quantization is elementwise
    over the trailing (k, n) dims with per-(…, n) scales.
    """
    count = 0

    def one(path, leaf):
        nonlocal count
        ps = _path_str(path)
        if QUANT_PATHS.search(ps) and leaf.ndim >= 2:
            count += 1
            return quantize_weight(leaf)
        return leaf

    out = jax.tree_util.tree_map_with_path(one, params)
    return out, count


def param_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total


def gemm_weight_bytes(params) -> int:
    """HBM bytes of the GEMM-consumed weight stream — the modeled
    weight traffic of ONE batched decode step (every projection leaf is
    read once per step; quantized leaves bill q at one byte/element plus
    their fp32 scale vector)."""
    total = 0

    def one(path, leaf):
        nonlocal total
        if is_quantized(leaf):
            total += leaf["q"].size * leaf["q"].dtype.itemsize
            total += leaf["scale"].size * leaf["scale"].dtype.itemsize
        elif QUANT_PATHS.search(_path_str(path)) \
                and getattr(leaf, "ndim", 0) >= 2:
            total += leaf.size * leaf.dtype.itemsize
        return leaf

    jax.tree_util.tree_map_with_path(one, params, is_leaf=is_quantized)
    return total


# --------------------------------------------------------------- W8A8
# Dynamic activation quantization mode for decode.  The planned GEMM
# execute path consults
# this at trace time when it receives a quantized weight struct.

_ACTIVATION_MODES = ("none", "w8a8")
_activation_mode = "none"


def set_activation_mode(mode: str) -> None:
    """Select the serving activation precision: "none" (W8A16 against
    quantized weights) or "w8a8" (dynamic per-row int8 activations,
    int8 x int8 GEMM, int32 accumulation)."""
    global _activation_mode
    if mode not in _ACTIVATION_MODES:
        raise ValueError(f"unknown activation mode {mode!r}")
    _activation_mode = mode


def activation_mode() -> str:
    """Active mode; the ``REPRO_W8A8`` env var, when set, overrides the
    programmatic setter (tests, ad-hoc CLI runs).  Values are strict —
    junk like ``REPRO_W8A8=false`` raises instead of silently enabling
    or disabling quantization."""
    env = os.environ.get("REPRO_W8A8")
    if env is None:
        return _activation_mode
    if env in ("1", "true", "w8a8"):
        return "w8a8"
    if env in ("", "0", "false", "none"):
        return "none"
    raise ValueError(f"REPRO_W8A8={env!r}: use 1/0")


def quantize_activations(x: jax.Array, axis: int = -1):
    """Symmetric dynamic per-row int8 activation quantization ->
    (q, scale); the W8A8 front half (the weight half is pre-quantized by
    :func:`quantize_params`)."""
    from repro.kernels import ref as _ref
    return _ref.quantize_int8(x, axis=axis)
