"""Weight-only int8 quantization for serving — the paper's precision
scheme as a first-class inference mode.

The paper's entire evaluation is int8 GEMM (8-bit operands, 32-bit
accumulation).  Training here stays bf16, but the serving path can load
weights quantized to symmetric per-output-channel int8:
:func:`quantize_params` rewrites every dense projection leaf into a
``{"q": int8 (k,n), "scale": f32 (1,n)}`` struct, and
``repro.kernels.ops.gemm`` consumes those structs transparently
(dequantize-on-load into the GEMM's input dtype).  Weight HBM traffic —
the dominant term of batched decode — halves vs bf16.

Only leaves that flow through ``ops.gemm`` are rewritten (attention and
MLP projections, SSM/RG-LRU projections, lm_head); embeddings (gather),
MoE expert banks (batched einsum) and norms keep their dtype.
"""

from __future__ import annotations

import re
from typing import Tuple

import jax
import jax.numpy as jnp

# leaves consumed via ops.gemm(x, w) with w: (k, n)
QUANT_PATHS = re.compile(
    r"(attn|cross)/w[qkvo]$|mlp/w_(gate|up|down|in|out)$"
    r"|(mixer|rec)/(in|out)_proj$|rec/w_[ri]$|lm_head$")


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "scale"}


def quantize_weight(w: jax.Array) -> dict:
    """Symmetric per-output-channel (axis -2 = k reduced) int8."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_weight(wq: dict, dtype) -> jax.Array:
    return (wq["q"].astype(jnp.float32) * wq["scale"]).astype(dtype)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


def quantize_params(params) -> Tuple[dict, int]:
    """Quantize every GEMM weight leaf.  Returns (params', n_quantized).

    Works on stacked (scan) leaves too — quantization is elementwise
    over the trailing (k, n) dims with per-(…, n) scales.
    """
    count = 0

    def one(path, leaf):
        nonlocal count
        ps = _path_str(path)
        if QUANT_PATHS.search(ps) and leaf.ndim >= 2:
            count += 1
            return quantize_weight(leaf)
        return leaf

    out = jax.tree_util.tree_map_with_path(one, params)
    return out, count


def param_bytes(params) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
