"""Model-vs-measured reporting: join every planned GEMM's modeled cost
(HBM bytes, roofline time) with a measured standalone execution.

The DSE is purely analytic; this module is the measurement half the
ROADMAP's autotuning item needs.  For each :class:`~repro.kernels.api.
GemmPlan` in the plan cache (populated by lowering a model, running a
benchmark, or serving a trace), it synthesizes operands matching the
spec, executes the plan through the public ``execute`` path (jitted,
``block_until_ready``), and reports per spec+shape:

* the *modeled* side — HBM bytes, flops, roofline-predicted time and
  whether the model calls it compute- or memory-bound;
* the *measured* side — mean wall-clock over ``iters`` runs (compile
  excluded by a warm-up call);
* ``achieved`` — modeled-time / measured-time, the fraction of the
  roofline the execution actually reached.

Honesty note: the roofline is a TPU-v5e model.  On a CPU host (the
``ref``/``interpret`` dispatch modes) the measured numbers are XLA-CPU
or interpreter wall-clock, so ``achieved`` is only meaningful for
*relative* comparisons between specs/tiles on the same host — the
absolute fraction says nothing about TPU behavior.  Each row records the
dispatch mode so downstream consumers can tell.

Plans whose padded flops exceed ``max_flops`` are not silently dropped:
they appear as rows with ``note='skipped (flops budget)'`` and no
measured time.
"""

from __future__ import annotations

import time
from typing import List, Optional, Sequence

import numpy as np

from repro import telemetry

#: per-GEMM flop budget for the measured pass — dryrun plan caches
#: contain million-token train GEMMs that would take hours on a CPU host
DEFAULT_MAX_FLOPS = 5e10


def _rand(rng: np.random.Generator, shape, dtype: str):
    import jax.numpy as jnp
    if dtype == "int8":
        return jnp.asarray(
            rng.integers(-127, 128, shape).astype(np.int8))
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32)
                       ).astype(dtype)


def _operands(pl, rng: np.random.Generator) -> dict:
    """Synthesize execute() operands matching the plan's spec."""
    spec, ep = pl.spec, pl.spec.epilogue
    m, k, n = pl.m, pl.k, pl.n

    def weight():
        if spec.b_quant:
            return {"q": _rand(rng, (k, n), "int8"),
                    "scale": _rand(rng, (1, n), "float32") * 0.01 + 0.02}
        return _rand(rng, (k, n), spec.b_dtype)

    return {
        "a": _rand(rng, (m, k), spec.a_dtype),
        "b": weight(),
        "b2": weight() if spec.gated else None,
        "bias": _rand(rng, (n,), spec.a_dtype) if ep.bias else None,
        "residual": (_rand(rng, (m, n), spec.a_dtype)
                     if ep.residual else None),
        "out_scale": 0.05 if ep.out_quant else None,
    }


def measure_plan(pl, *, iters: int = 3,
                 rng: Optional[np.random.Generator] = None) -> float:
    """Mean wall-clock seconds of one plan execution (jit-compiled and
    warmed up first, device-synced per run)."""
    import jax
    from repro.kernels import api
    rng = rng or np.random.default_rng(0)
    ops = _operands(pl, rng)
    out_scale = ops["out_scale"]

    def f(a, b, b2, bias, residual):
        return api.execute(pl, a, b, b2=b2, bias=bias,
                           residual=residual, out_scale=out_scale)

    jitted = jax.jit(f)
    args = (ops["a"], ops["b"], ops["b2"], ops["bias"], ops["residual"])
    jax.block_until_ready(jitted(*args))          # compile + warm-up
    with telemetry.span("measure.gemm", spec=pl.spec.key,
                        m=pl.m, k=pl.k, n=pl.n, iters=iters) as sp:
        t0 = time.perf_counter()
        for _ in range(iters):
            out = jitted(*args)
        sp.sync(out)
        jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) / iters
    return dt


def model_vs_measured(plans: Optional[Sequence] = None, *,
                      max_flops: float = DEFAULT_MAX_FLOPS,
                      iters: int = 3, seed: int = 0) -> List[dict]:
    """One row per plan: the modeled bytes/time next to the measured
    wall-clock.  ``plans`` defaults to every plan resolved so far (the
    plan cache in insertion order)."""
    from repro.kernels import api
    if plans is None:
        plans = api.plans()
    rng = np.random.default_rng(seed)
    mode = api._mode()
    rows: List[dict] = []
    for pl in plans:
        t = pl.tile
        row = {
            "spec": pl.spec.key,
            "m": pl.m, "k": pl.k, "n": pl.n,
            "strategy": t.strategy,
            "tile": f"{t.bm}x{t.bk}x{t.bn}",
            "hbm_mib": round(pl.hbm_bytes / 2**20, 3),
            "flops": pl.flops,
            "bound": pl.traffic.bound,
            "t_model_us": round(pl.traffic.t_model * 1e6, 2),
            "mode": mode,
            "t_measured_us": None,
            "achieved": None,
            "note": "",
        }
        if pl.flops > max_flops:
            row["note"] = "skipped (flops budget)"
        else:
            dt = measure_plan(pl, iters=iters, rng=rng)
            row["t_measured_us"] = round(dt * 1e6, 2)
            row["achieved"] = round(pl.traffic.t_model / dt, 5)
            telemetry.event("gemm.measured", **{
                k: row[k] for k in ("spec", "m", "k", "n", "strategy",
                                    "tile", "hbm_mib", "t_model_us",
                                    "t_measured_us", "achieved", "mode")})
        rows.append(row)
    return rows


def summarize(rows: Sequence[dict]) -> dict:
    measured = [r for r in rows if r["t_measured_us"] is not None]
    skipped = len(rows) - len(measured)
    return {
        "n_plans": len(rows),
        "n_measured": len(measured),
        "n_skipped": skipped,
        "mean_achieved": (round(float(np.mean(
            [r["achieved"] for r in measured])), 5) if measured else None),
    }


def render(rows: Sequence[dict]) -> str:
    """Aligned text table of a model-vs-measured report."""
    cols = ("spec", "shape", "tile", "hbm_mib", "t_model_us",
            "t_measured_us", "achieved", "note")
    table = [cols]
    for r in rows:
        table.append((
            r["spec"], f"{r['m']}x{r['k']}x{r['n']}",
            f"{r['strategy']} {r['tile']}", f"{r['hbm_mib']:.2f}",
            f"{r['t_model_us']:.1f}",
            "-" if r["t_measured_us"] is None
            else f"{r['t_measured_us']:.1f}",
            "-" if r["achieved"] is None else f"{r['achieved']:.3f}",
            r["note"]))
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in table]
    lines.insert(1, "  ".join("-" * w for w in widths))
    s = summarize(rows)
    lines.append(f"[{s['n_measured']}/{s['n_plans']} plans measured, "
                 f"{s['n_skipped']} skipped; mode sees a "
                 f"{rows[0]['mode'] if rows else '?'} dispatch — achieved "
                 "fractions compare hosts, not TPUs]")
    return "\n".join(lines)
