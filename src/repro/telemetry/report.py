"""Model-vs-measured reporting: join every planned GEMM's modeled cost
(HBM bytes, roofline time) with a measured standalone execution.

The DSE is purely analytic; this module is the measurement half the
ROADMAP's autotuning item needs.  For each :class:`~repro.kernels.api.
GemmPlan` in the plan cache (populated by lowering a model, running a
benchmark, or serving a trace), it synthesizes operands matching the
spec, executes the plan through the public ``execute`` path (jitted,
``block_until_ready``), and reports per spec+shape:

* the *modeled* side — HBM bytes, flops, roofline-predicted time and
  whether the model calls it compute- or memory-bound;
* the *measured* side — **median** wall-clock over ``iters``
  device-synced runs after ``warmup`` warm-up calls (compile excluded)
  with MAD outlier rejection, plus the surviving ``spread``
  ((max-min)/median) so a noisy host is visible in the table instead of
  silently folded into a mean — the shared :mod:`repro.tune.measure`
  harness the autotuner uses;
* ``achieved`` — modeled-time / measured-time, the fraction of the
  roofline the execution actually reached.

Honesty note: the roofline is a TPU-v5e model.  On a CPU host (the
``ref``/``interpret`` dispatch modes) the measured numbers are XLA-CPU
or interpreter wall-clock, so ``achieved`` is only meaningful for
*relative* comparisons between specs/tiles on the same host — the
absolute fraction says nothing about TPU behavior.  Each row records the
dispatch mode so downstream consumers can tell.

Plans whose padded flops exceed ``max_flops`` are not silently dropped:
they appear as rows with ``note='skipped (flops budget)'`` and no
measured time.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro import telemetry
from repro.tune import measure as _measure
from repro.tune.measure import (  # noqa: F401  (compat re-exports)
    DEFAULT_MAX_FLOPS,
    Measurement,
    synthesize_operands,
)


def measure_plan(pl, *, iters: int = _measure.DEFAULT_ITERS,
                 warmup: int = _measure.DEFAULT_WARMUP,
                 rng: Optional[np.random.Generator] = None
                 ) -> Measurement:
    """Measure one plan with the shared :mod:`repro.tune.measure`
    harness (jit + explicit warm-up, ``iters`` device-synced samples,
    MAD outlier rejection).  Returns the full :class:`Measurement`;
    use ``.median_s`` for the headline number."""
    return _measure.measure_plan(pl, iters=iters, warmup=warmup, rng=rng)


def model_vs_measured(plans: Optional[Sequence] = None, *,
                      max_flops: float = DEFAULT_MAX_FLOPS,
                      iters: int = _measure.DEFAULT_ITERS,
                      warmup: int = _measure.DEFAULT_WARMUP,
                      seed: int = 0) -> List[dict]:
    """One row per plan: the modeled bytes/time next to the measured
    median wall-clock and its spread.  ``plans`` defaults to every plan
    resolved so far (the plan cache in insertion order)."""
    from repro.kernels import api
    if plans is None:
        plans = api.plans()
    rng = np.random.default_rng(seed)
    mode = api._mode()
    rows: List[dict] = []
    for pl in plans:
        t = pl.tile
        row = {
            "spec": pl.spec.key,
            "m": pl.m, "k": pl.k, "n": pl.n,
            "strategy": t.strategy,
            "tile": f"{t.bm}x{t.bk}x{t.bn}",
            "source": pl.source,
            "hbm_mib": round(pl.hbm_bytes / 2**20, 3),
            "flops": pl.flops,
            "bound": pl.traffic.bound,
            "t_model_us": round(pl.traffic.t_model * 1e6, 2),
            "mode": mode,
            "iters": iters,
            "warmup": warmup,
            "t_measured_us": None,
            "spread": None,
            "achieved": None,
            "note": "",
        }
        if pl.flops > max_flops:
            row["note"] = "skipped (flops budget)"
        else:
            meas = measure_plan(pl, iters=iters, warmup=warmup, rng=rng)
            dt = meas.median_s
            row["t_measured_us"] = round(dt * 1e6, 2)
            row["spread"] = round(meas.spread, 4)
            row["achieved"] = round(pl.traffic.t_model / dt, 5)
            if meas.rejected:
                row["note"] = f"{meas.rejected} outlier(s) rejected"
            telemetry.event("gemm.measured", **{
                k: row[k] for k in ("spec", "m", "k", "n", "strategy",
                                    "tile", "source", "hbm_mib",
                                    "t_model_us", "t_measured_us",
                                    "spread", "achieved", "mode")})
        rows.append(row)
    return rows


def summarize(rows: Sequence[dict]) -> dict:
    measured = [r for r in rows if r["t_measured_us"] is not None]
    skipped = len(rows) - len(measured)
    return {
        "n_plans": len(rows),
        "n_measured": len(measured),
        "n_skipped": skipped,
        "mean_achieved": (round(float(np.mean(
            [r["achieved"] for r in measured])), 5) if measured else None),
    }


def render(rows: Sequence[dict]) -> str:
    """Aligned text table of a model-vs-measured report."""
    cols = ("spec", "shape", "tile", "src", "hbm_mib", "t_model_us",
            "t_measured_us", "spread", "achieved", "note")
    table = [cols]
    for r in rows:
        table.append((
            r["spec"], f"{r['m']}x{r['k']}x{r['n']}",
            f"{r['strategy']} {r['tile']}",
            r.get("source", "analytic"), f"{r['hbm_mib']:.2f}",
            f"{r['t_model_us']:.1f}",
            "-" if r["t_measured_us"] is None
            else f"{r['t_measured_us']:.1f}",
            "-" if r.get("spread") is None else f"{r['spread'] * 100:.0f}%",
            "-" if r["achieved"] is None else f"{r['achieved']:.3f}",
            r["note"]))
    widths = [max(len(row[i]) for row in table) for i in range(len(cols))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(row, widths)).rstrip()
             for row in table]
    lines.insert(1, "  ".join("-" * w for w in widths))
    s = summarize(rows)
    lines.append(f"[{s['n_measured']}/{s['n_plans']} plans measured, "
                 f"{s['n_skipped']} skipped; mode sees a "
                 f"{rows[0]['mode'] if rows else '?'} dispatch — achieved "
                 "fractions compare hosts, not TPUs]")
    return "\n".join(lines)
