"""``repro.telemetry`` — spans, counters and trace export for the whole
stack, zero-cost when disabled.

The paper's framework is *model-driven*: every GEMM decision is ranked
by modeled HBM bytes and roofline time.  BENCH_gemm already shows where
that model and reality diverge (the fused SwiGLU models a 0.47
activation-traffic ratio yet wall-clock is a wash), and closing that gap
needs the measurement half of the loop: a way to see, per planned GEMM
and per serve request, what was *modeled* and what actually *happened*.
This module is that layer:

* :func:`span` — hierarchical wall-clock spans (``perf_counter``), used
  as context managers.  Device work is asynchronous under jax, so a span
  can register arrays via ``sp.sync(x)`` and its exit calls
  ``jax.block_until_ready`` on them — the device time is billed to the
  span that launched it, not to whichever later host line happens to
  block.
* :func:`event` / :func:`complete_span` — instant events and
  retroactively-timed spans (for lifecycles that cross host loop
  iterations, e.g. one serve request from queued to finished).
* :func:`counter` / :func:`gauge` — typed metric registries.  Counters
  accumulate (snapshot-only); every gauge ``set`` also records a
  timeline sample, which the Chrome-trace export renders as a counter
  track (the serve engine's slot-occupancy timeline).
* :class:`Recorder` — the process-global event sink.  Exports (a)
  structured JSONL (one self-contained JSON object per line, leading
  ``meta`` line carries the schema version and a final metric snapshot)
  and (b) Chrome-trace/Perfetto JSON loadable in ``chrome://tracing`` or
  ``ui.perfetto.dev``.

Disabled mode (the default) is a hard no-op: module functions read ONE
module global and hand back shared stateless singletons — no recorder,
span, dict or list is ever allocated, so instrumented hot paths cost a
predicate.  Enable with :func:`enable` (or the launch entrypoints'
``--telemetry PATH`` / the benchmarks' ``REPRO_TELEMETRY`` env var).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Recorder", "Span", "SCHEMA_VERSION",
    "complete_span", "counter", "disable", "enable", "enabled", "event",
    "export", "gauge", "recorder", "snapshot", "span",
]

#: bump when the JSONL event schema changes shape
SCHEMA_VERSION = 1

#: explicit-tid tracks (e.g. one row per serve request) are offset past
#: this base so they never collide with interned host-thread tids
TRACK_TID_BASE = 1000

_recorder: Optional["Recorder"] = None


# ---------------------------------------------------------------------------
# Disabled-mode singletons: stateless, shared, allocation-free
# ---------------------------------------------------------------------------

class _NoopSpan:
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def sync(self, value):
        return value


class _NoopCounter:
    __slots__ = ()
    value = 0

    def add(self, n: float = 1) -> None:
        pass


class _NoopGauge:
    __slots__ = ()
    value = 0.0

    def set(self, value: float) -> None:
        pass


_NOOP_SPAN = _NoopSpan()
_NOOP_COUNTER = _NoopCounter()
_NOOP_GAUGE = _NoopGauge()


# ---------------------------------------------------------------------------
# Live metric types
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic accumulator; final value rides the snapshot."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0

    def add(self, n: float = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value; ``set`` records a timeline sample whenever
    the value *changes* (counter tracks are step functions — emitting
    unchanged values would only bloat the trace, e.g. from a serve
    engine's idle poll loop), and the Chrome-trace export draws the
    samples as a counter track."""

    __slots__ = ("name", "value", "_rec", "_set_once")

    def __init__(self, name: str, rec: "Recorder"):
        self.name = name
        self.value: float = 0.0
        self._rec = rec
        self._set_once = False

    def set(self, value: float) -> None:
        value = float(value)
        if self._set_once and value == self.value:
            return
        self._set_once = True
        self.value = value
        self._rec._emit({"type": "gauge", "name": self.name,
                         "ts": self._rec._now(), "value": self.value})


class Span:
    """One live hierarchical span.  Use as a context manager; ``set``
    attaches attributes, ``sync(x)`` registers a jax value to
    ``block_until_ready`` at exit (so asynchronously dispatched device
    work is billed to this span)."""

    __slots__ = ("name", "attrs", "_rec", "_t0", "_t1", "_syncs",
                 "sid", "parent", "depth")

    def __init__(self, rec: "Recorder", name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self._rec = rec
        self._syncs: List[Any] = []
        self.sid = -1
        self.parent: Optional[int] = None
        self.depth = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def sync(self, value):
        self._syncs.append(value)
        return value

    def __enter__(self) -> "Span":
        st = self._rec._stack()
        self.parent = st[-1].sid if st else None
        self.depth = len(st)
        self.sid = self._rec._new_sid()
        st.append(self)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        if self._syncs:
            import jax
            jax.block_until_ready(self._syncs)
            self._syncs = []
        self._t1 = time.perf_counter()
        self._rec._pop(self)
        return False


# ---------------------------------------------------------------------------
# Recorder
# ---------------------------------------------------------------------------

class Recorder:
    """Process-global event sink: spans, instant events, gauge samples,
    plus the counter/gauge registries.  Timestamps are seconds since the
    recorder was created (``perf_counter`` deltas)."""

    def __init__(self):
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._tls = threading.local()
        self.events: List[dict] = []
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._next_sid = 0
        self._tids: Dict[int, int] = {}

    # ----------------------------------------------------------- internals

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
        return tid

    def _emit(self, ev: dict) -> None:
        with self._lock:
            self.events.append(ev)

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _new_sid(self) -> int:
        with self._lock:
            sid = self._next_sid
            self._next_sid += 1
        return sid

    def _pop(self, sp: Span) -> None:
        st = self._stack()
        # tolerate exits out of order (an exception unwound past a span)
        while st and st[-1].sid != sp.sid:
            st.pop()
        if st:
            st.pop()
        self._emit({"type": "span", "name": sp.name,
                    "ts": sp._t0 - self._t0, "dur": sp._t1 - sp._t0,
                    "sid": sp.sid, "parent": sp.parent,
                    "depth": sp.depth, "tid": self._tid(),
                    "attrs": sp.attrs})

    # ----------------------------------------------------------- public API

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        self._emit({"type": "event", "name": name, "ts": self._now(),
                    "tid": self._tid(), "attrs": attrs})

    def complete_span(self, name: str, t_start: float, t_end: float, *,
                      tid: Optional[int] = None, **attrs) -> None:
        """Record a span from absolute ``perf_counter`` endpoints —
        for lifecycles that cross host loop iterations.  An explicit
        ``tid`` gets its own Chrome-trace track (offset past host-thread
        tids), e.g. one row per serve request."""
        self._emit({"type": "span", "name": name,
                    "ts": t_start - self._t0,
                    "dur": max(t_end - t_start, 0.0),
                    "sid": None, "parent": None, "depth": 0,
                    "tid": self._tid() if tid is None
                    else TRACK_TID_BASE + tid,
                    "attrs": attrs})

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name, self))
        return g

    def snapshot(self) -> dict:
        """Point-in-time metric state: counter/gauge values, event
        volume, and the GEMM plan-cache + tuning-cache stats (every
        snapshot carries them — the cache hit/miss trajectory is a
        first-class telemetry signal)."""
        from repro.kernels import api as _api  # runtime import: no cycle
        from repro.tune import cache as _tcache
        return {
            "elapsed_s": self._now(),
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "plan_cache": _api.plan_cache_info()._asdict(),
            "tuning_cache": _tcache.tuning_cache_info()._asdict(),
            "n_events": len(self.events),
        }

    # -------------------------------------------------------------- export

    def export_jsonl(self, path: str) -> str:
        """One JSON object per line.  Line 1 is ``{"type": "meta", ...}``
        with the schema version and a final snapshot; every following
        line is an event: spans carry ``(type, name, ts, dur, attrs)``,
        instants ``(type, name, ts, attrs)``, gauge samples
        ``(type, name, ts, value)``.  ``ts``/``dur`` are seconds."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            meta = {"type": "meta", "schema_version": SCHEMA_VERSION,
                    "pid": os.getpid(), "snapshot": self.snapshot()}
            f.write(json.dumps(meta) + "\n")
            for ev in self.events:
                f.write(json.dumps(ev, default=str) + "\n")
        return path

    def chrome_trace(self) -> dict:
        """The trace as a Chrome/Perfetto ``traceEvents`` object —
        spans as complete ('X') events, instants as 'i', gauge samples
        as counter ('C') tracks; timestamps in microseconds."""
        pid = os.getpid()
        out: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": pid,
             "args": {"name": "repro"}},
        ]
        named_tracks = set()
        for ev in self.events:
            ts_us = ev["ts"] * 1e6
            if ev["type"] == "span":
                tid = ev.get("tid", 0)
                if tid >= TRACK_TID_BASE and tid not in named_tracks:
                    named_tracks.add(tid)
                    out.append({"ph": "M", "name": "thread_name",
                                "pid": pid, "tid": tid,
                                "args": {"name": f"request "
                                         f"{tid - TRACK_TID_BASE}"}})
                out.append({"ph": "X", "name": ev["name"], "cat": "repro",
                            "ts": ts_us, "dur": ev["dur"] * 1e6,
                            "pid": pid, "tid": tid,
                            "args": ev.get("attrs", {})})
            elif ev["type"] == "event":
                out.append({"ph": "i", "name": ev["name"], "cat": "repro",
                            "ts": ts_us, "s": "t", "pid": pid,
                            "tid": ev.get("tid", 0),
                            "args": ev.get("attrs", {})})
            elif ev["type"] == "gauge":
                out.append({"ph": "C", "name": ev["name"], "ts": ts_us,
                            "pid": pid, "tid": 0,
                            "args": {"value": ev["value"]}})
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, default=str)
        return path

    def export(self, base: str) -> Tuple[str, str]:
        """Write both artifacts next to each other: ``{base}.jsonl`` and
        ``{base}.trace.json``; returns their paths."""
        return (self.export_jsonl(base + ".jsonl"),
                self.export_chrome_trace(base + ".trace.json"))


# ---------------------------------------------------------------------------
# Module-level API (reads one global; no-op singletons when disabled)
# ---------------------------------------------------------------------------

def enabled() -> bool:
    return _recorder is not None


def recorder() -> Optional[Recorder]:
    return _recorder


def enable(rec: Optional[Recorder] = None) -> Recorder:
    """Install (and return) the process-global recorder.  Idempotent:
    enabling while enabled keeps the existing recorder unless a new one
    is passed explicitly."""
    global _recorder
    if rec is not None:
        _recorder = rec
    elif _recorder is None:
        _recorder = Recorder()
    return _recorder


def disable() -> Optional[Recorder]:
    """Uninstall and return the recorder (so callers can still export
    after turning instrumentation off)."""
    global _recorder
    rec, _recorder = _recorder, None
    return rec


def span(name: str, **attrs):
    rec = _recorder
    if rec is None:
        return _NOOP_SPAN
    return Span(rec, name, attrs)


def event(name: str, **attrs) -> None:
    rec = _recorder
    if rec is not None:
        rec.event(name, **attrs)


def complete_span(name: str, t_start: float, t_end: float, *,
                  tid: Optional[int] = None, **attrs) -> None:
    rec = _recorder
    if rec is not None:
        rec.complete_span(name, t_start, t_end, tid=tid, **attrs)


def counter(name: str):
    rec = _recorder
    if rec is None:
        return _NOOP_COUNTER
    return rec.counter(name)


def gauge(name: str):
    rec = _recorder
    if rec is None:
        return _NOOP_GAUGE
    return rec.gauge(name)


def snapshot() -> Optional[dict]:
    rec = _recorder
    return rec.snapshot() if rec is not None else None


def export(base: str) -> Optional[Tuple[str, str]]:
    rec = _recorder
    return rec.export(base) if rec is not None else None
