"""``repro.telemetry`` — zero-cost-when-disabled tracing + metrics for
the whole stack (kernel plans, serve lifecycles, train steps), plus the
model-vs-measured report that joins planned GEMM decisions with measured
wall-clock (:mod:`repro.telemetry.report`)."""

from repro.telemetry.telemetry import (  # noqa: F401
    SCHEMA_VERSION,
    TRACK_TID_BASE,
    Counter,
    Gauge,
    Recorder,
    Span,
    complete_span,
    counter,
    disable,
    enable,
    enabled,
    event,
    export,
    gauge,
    recorder,
    snapshot,
    span,
)
