"""Serving-path tests: continuous-batching engine semantics (ragged
traces bit-identical to solo batch-1 decode, slot-targeted prefill,
EOS masking, scheduler invariants), greedy consistency, and ring-buffer
windowed decode far past the window."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.kernels import ref
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.engine import (ACCEPTANCE_TRACE, DecodeEngine, Request,
                                SlotScheduler, acceptance_requests,
                                solo_greedy)


def test_engine_greedy_matches_forward_argmax():
    """Engine's first generated token == argmax over the full-sequence
    forward logits at the last prompt position."""
    cfg = get_smoke_config("minitron-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab)
    engine = DecodeEngine(params, cfg, batch=2, max_len=24)
    res = engine.generate(prompts, n_steps=4)

    h, _ = T.forward(params, cfg, prompts)
    logits = h[:, -1] @ params["lm_head"]
    want = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(res.tokens[:, 0], want)


def test_engine_eos_stops_early():
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    # every token is "EOS" under this id -> stops after first sample
    h, _ = T.forward(params, cfg, prompts)
    first = int(jnp.argmax(h[:, -1] @ params["lm_head"], -1)[0])
    engine = DecodeEngine(params, cfg, batch=2, max_len=16,
                          eos_id=first)
    res = engine.generate(prompts, n_steps=8)
    assert res.steps <= 8


def test_ring_buffer_decode_past_window():
    """h2o-danube-style SWA: decode 3x past the window with a ring
    cache of window slots; logits must match a reference decode that
    keeps the FULL history."""
    cfg = get_smoke_config("h2o-danube-3-4b")          # window=32
    assert cfg.window == 32
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    total = 3 * cfg.window + 7
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, total), 0,
                              cfg.vocab)

    # ring path: cache bounded to `window` slots
    cache = T.init_cache(cfg, 1, max_len=total)
    k_shape = jax.tree.leaves(cache["layers"])[0].shape
    logits, cache = T.prefill(params, cfg, toks[:, :16], cache)
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    ring_logits = []
    for i in range(16, total):
        logits, cache = step(params, toks[:, i:i + 1], cache)
        ring_logits.append(np.asarray(logits))

    # reference: full forward at each prefix (windowed attention over
    # complete history)
    h, _ = T.forward(params, cfg, toks)
    full = np.asarray(h @ params["lm_head"])
    for j, i in enumerate(range(16, total)):
        np.testing.assert_allclose(
            ring_logits[j][0], full[0, i], rtol=2e-3, atol=2e-3)


def test_windowed_cache_is_bounded():
    cfg = get_smoke_config("h2o-danube-3-4b")
    cache = T.init_cache(cfg, 1, max_len=4096)
    k = cache["layers"]["u0"]["k"]
    assert k.shape[2] == cfg.window        # ring buffer, not 4096


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

def test_ragged_trace_bit_identical_to_solo_batch1():
    """The acceptance trace (prompt lens 4/16/8/32, max_tokens
    8/32/16/4) on a 2-slot continuous engine: every request's tokens
    are bit-identical to running it alone at batch 1 (greedy), and
    slots turn over (4 requests through 2 slots)."""
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(p + mt for p, mt in ACCEPTANCE_TRACE) + 1
    reqs = acceptance_requests(cfg.vocab)
    engine = DecodeEngine(params, cfg, batch=2, max_len=max_len)
    results = {r.rid: r for r in engine.run(reqs)}
    assert len(results) == len(reqs)
    for req in reqs:
        want = solo_greedy(params, cfg, req.prompt, req.max_tokens,
                            max_len)
        got = results[req.rid].tokens
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"rid {req.rid}")
    # occupancy beats lockstep-with-2-slots on this trace
    assert engine.occupancy() > 0.8
    assert engine.metrics["prefill_tokens"] == \
        sum(p for p, _ in ACCEPTANCE_TRACE)


def test_ragged_trace_windowed_ring_bit_identical():
    """Per-slot positions through the ring-buffer windowed cache: two
    requests of different lengths decode past the window together on a
    2-slot engine, each bit-identical to its solo batch-1 run (each row
    writes at its own ring offset and masks at its own fill level)."""
    cfg = get_smoke_config("h2o-danube-3-4b")           # window = 32
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    lens, mts = (8, 24), (40, 20)                       # 8+40 > window
    max_len = 72
    reqs = [Request(prompt=rng.integers(0, cfg.vocab, (p,))
                    .astype(np.int32), max_tokens=mt)
            for p, mt in zip(lens, mts)]
    engine = DecodeEngine(params, cfg, batch=2, max_len=max_len)
    results = {r.rid: r for r in engine.run(reqs)}
    for req in reqs:
        want = solo_greedy(params, cfg, req.prompt, req.max_tokens,
                            max_len)
        np.testing.assert_array_equal(results[req.rid].tokens, want,
                                      err_msg=f"rid {req.rid}")


def test_prefill_into_slot_preserves_resident_slots():
    """Admitting into slot 1 must not perturb slot 0's cache rows or
    position."""
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    cache = T.init_cache(cfg, 2, 32)
    p0 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)
    p1 = jnp.asarray(rng.integers(0, cfg.vocab, (1, 12)), jnp.int32)
    _, cache = T.prefill_into_slot(params, cfg, p0, cache, 0, max_len=32)
    before = jax.tree.map(lambda x: np.asarray(x), cache)
    _, cache = T.prefill_into_slot(params, cfg, p1, cache, 1, max_len=32)
    after = jax.tree.map(lambda x: np.asarray(x), cache)
    assert int(after["pos"][0]) == 8 and int(after["pos"][1]) == 12
    k_b, k_a = before["layers"]["u0"]["k"], after["layers"]["u0"]["k"]
    np.testing.assert_array_equal(k_b[:, 0], k_a[:, 0])   # slot 0 intact
    assert np.any(k_a[:, 1] != k_b[:, 1])                 # slot 1 written


def test_post_eos_tokens_are_masked():
    """A slot decodes past EOS until the burst boundary; the returned
    tokens must stop at EOS (satellite: no post-EOS garbage) and the
    compat (b, steps) array pads with eos_id."""
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    h, _ = T.forward(params, cfg, prompts)
    eos = int(jnp.argmax(h[:, -1] @ params["lm_head"], -1)[0])
    engine = DecodeEngine(params, cfg, batch=2, max_len=32, eos_id=eos)
    reqs = [Request(prompt=np.asarray(prompts[i]), max_tokens=12,
                    eos_id=eos) for i in range(2)]
    results = {r.rid: r for r in engine.run(reqs)}
    r0 = results[reqs[0].rid].tokens
    assert r0[-1] == eos and eos not in r0[:-1]
    # compat path: rows finishing early pad with eos_id, never garbage
    res = engine.generate(prompts, 12)
    row = res.tokens[0]
    first_eos = int(np.argmax(row == eos))
    assert np.all(row[first_eos:] == eos)


def test_per_slot_sampling_params():
    """Greedy and temperature requests share one batch: the greedy
    slot's tokens stay bit-identical to a solo greedy run."""
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    pg = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    pt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
    engine = DecodeEngine(params, cfg, batch=2, max_len=32)
    reqs = [Request(prompt=pg, max_tokens=6, temperature=0.0),
            Request(prompt=pt, max_tokens=6, temperature=1.0)]
    results = {r.rid: r for r in engine.run(reqs)}
    want = solo_greedy(params, cfg, pg, 6, 32)
    np.testing.assert_array_equal(results[reqs[0].rid].tokens, want)
    assert results[reqs[1].rid].n_tokens == 6


# ------------------------------------------------------ scheduler invariants

def test_slot_scheduler_fifo_and_reuse():
    s = SlotScheduler(2)
    for rid in range(4):
        s.submit(rid)
    assert s.admit() == (0, 0) and s.admit() == (1, 1)
    assert s.admit() is None                  # no free slot
    assert s.release(0) == 0
    assert s.admit() == (0, 2)                # lowest free slot, FIFO rid
    s.release(1)
    s.release(0)
    assert s.admit() == (0, 3)
    s.release(0)
    assert not s.has_work()


def test_slot_scheduler_properties():
    """Property (hypothesis): under any interleaving of submissions and
    completions, every queued request is admitted exactly once, no slot
    serves two live requests, and the queue drains."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(n_slots=st.integers(1, 4), n_reqs=st.integers(0, 24),
           data=st.data())
    @settings(max_examples=60, deadline=None)
    def check(n_slots, n_reqs, data):
        sched = SlotScheduler(n_slots)
        admitted, completed = [], []
        submitted = 0
        while len(completed) < n_reqs:
            can_submit = submitted < n_reqs
            act = data.draw(st.sampled_from(
                (["submit"] if can_submit else [])
                + ["admit"] + (["release"] if sched.n_active else [])))
            if act == "submit":
                sched.submit(submitted)
                submitted += 1
            elif act == "admit":
                got = sched.admit()
                if got is not None:
                    slot, rid = got
                    admitted.append(rid)
                    # no slot serves two live requests
                    live = [r for r in sched.slot_rid if r is not None]
                    assert len(live) == len(set(live))
            else:
                slot = data.draw(st.sampled_from(sched.active_slots))
                completed.append(sched.release(slot))
            # drain: force progress when everything is submitted
            if submitted == n_reqs and not sched.queue \
                    and sched.n_active == 0 and len(completed) < n_reqs:
                break
        # every submission is admitted exactly once, FIFO
        while sched.has_work():
            got = sched.admit()
            if got is not None:
                admitted.append(got[1])
                completed.append(sched.release(got[0]))
            elif sched.n_active:
                completed.append(sched.release(sched.active_slots[0]))
        assert sorted(admitted) == list(range(submitted))
        assert len(admitted) == len(set(admitted))
        assert sorted(completed) == list(range(submitted))

    check()


def test_decode_attention_ref_vs_full_attention():
    """decode_attention_ref == attention_ref evaluated at the last
    position of a causal sequence."""
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 24, 6, 2, 16
    q_all = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    full = ref.attention_ref(q_all, k, v, causal=True)
    dec = ref.decode_attention_ref(q_all[:, -1], k, v,
                                   jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(dec, full[:, -1], rtol=1e-5, atol=1e-5)
