"""Serving-path tests: batched engine semantics, greedy consistency,
EOS masking, and ring-buffer windowed decode far past the window."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.kernels import ref
from repro.models import layers as L
from repro.models import transformer as T
from repro.serve.engine import DecodeEngine


def test_engine_greedy_matches_forward_argmax():
    """Engine's first generated token == argmax over the full-sequence
    forward logits at the last prompt position."""
    cfg = get_smoke_config("minitron-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab)
    engine = DecodeEngine(params, cfg, batch=2, max_len=24)
    res = engine.generate(prompts, n_steps=4)

    h, _ = T.forward(params, cfg, prompts)
    logits = h[:, -1] @ params["lm_head"]
    want = np.asarray(jnp.argmax(logits, axis=-1))
    np.testing.assert_array_equal(res.tokens[:, 0], want)


def test_engine_eos_stops_early():
    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab)
    # every token is "EOS" under this id -> stops after first sample
    h, _ = T.forward(params, cfg, prompts)
    first = int(jnp.argmax(h[:, -1] @ params["lm_head"], -1)[0])
    engine = DecodeEngine(params, cfg, batch=2, max_len=16,
                          eos_id=first)
    res = engine.generate(prompts, n_steps=8)
    assert res.steps <= 8


def test_ring_buffer_decode_past_window():
    """h2o-danube-style SWA: decode 3x past the window with a ring
    cache of window slots; logits must match a reference decode that
    keeps the FULL history."""
    cfg = get_smoke_config("h2o-danube-3-4b")          # window=32
    assert cfg.window == 32
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    total = 3 * cfg.window + 7
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, total), 0,
                              cfg.vocab)

    # ring path: cache bounded to `window` slots
    cache = T.init_cache(cfg, 1, max_len=total)
    k_shape = jax.tree.leaves(cache["layers"])[0].shape
    logits, cache = T.prefill(params, cfg, toks[:, :16], cache)
    step = jax.jit(lambda p, t, c: T.decode_step(p, cfg, t, c))
    ring_logits = []
    for i in range(16, total):
        logits, cache = step(params, toks[:, i:i + 1], cache)
        ring_logits.append(np.asarray(logits))

    # reference: full forward at each prefix (windowed attention over
    # complete history)
    h, _ = T.forward(params, cfg, toks)
    full = np.asarray(h @ params["lm_head"])
    for j, i in enumerate(range(16, total)):
        np.testing.assert_allclose(
            ring_logits[j][0], full[0, i], rtol=2e-3, atol=2e-3)


def test_windowed_cache_is_bounded():
    cfg = get_smoke_config("h2o-danube-3-4b")
    cache = T.init_cache(cfg, 1, max_len=4096)
    k = cache["layers"]["u0"]["k"]
    assert k.shape[2] == cfg.window        # ring buffer, not 4096


def test_decode_attention_ref_vs_full_attention():
    """decode_attention_ref == attention_ref evaluated at the last
    position of a causal sequence."""
    rng = np.random.default_rng(0)
    b, s, hq, hkv, d = 2, 24, 6, 2, 16
    q_all = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    full = ref.attention_ref(q_all, k, v, causal=True)
    dec = ref.decode_attention_ref(q_all[:, -1], k, v,
                                   jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(dec, full[:, -1], rtol=1e-5, atol=1e-5)
