"""End-to-end dry-run CLI smoke: one cell per step kind on a small
debug mesh in a subprocess (fresh jax with forced device count)."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_cell(arch, shape, out):
    env = dict(os.environ)
    env["REPRO_DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = "src"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", "single", "--debug-mesh", "2,4",
           "--out", out]
    r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                       cwd=REPO, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    rec = json.load(open(os.path.join(out, "single",
                                      f"{arch}__{shape}.json")))
    return rec


@pytest.mark.parametrize("arch,shape", [
    ("smollm-360m", "train_4k"),          # train kind
    ("smollm-360m", "decode_32k"),        # decode kind
    ("mamba2-370m", "prefill_32k"),       # prefill kind, SSM family
])
def test_dryrun_cell(arch, shape):
    with tempfile.TemporaryDirectory() as d:
        rec = _run_cell(arch, shape, d)
    assert rec["ok"]
    r = rec["roofline"]
    assert r["flops_per_device"] > 0
    assert r["hbm_bytes_per_device"] > 0
    assert r["dominant"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["available"]
    assert rec["memory_analysis"]["peak_bytes_per_device"] > 0
    # loop correction engaged: scanned models must beat XLA's
    # loops-counted-once number (decode has a large loop-external
    # lm_head GEMM, so its ratio is smaller)
    floor = 2.0 if shape == "train_4k" else 1.2
    assert r["flops_per_device"] > floor * r["xla_flops_raw"]


def test_dryrun_records_skip():
    """long_500k on a pure full-attention arch is a documented skip."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        env["REPRO_DRYRUN_DEVICES"] = "8"
        env["PYTHONPATH"] = "src"
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--all",
               "--mesh", "single", "--archs", "minitron-8b",
               "--shapes", "long_500k", "--out", d]
        r = subprocess.run(cmd, capture_output=True, text=True, env=env,
                           cwd=REPO, timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        rec = json.load(open(os.path.join(
            d, "single", "minitron-8b__long_500k.json")))
    assert rec["ok"] and rec["skipped"]
    assert "quadratic" in rec["skip_reason"]
