"""Fused-epilogue GEMM subsystem: interpret-mode kernel parity vs the
unfused reference compositions for every epilogue variant
(bias/activation/residual x bf16/W8A16/W8A8), dual-B gated-kernel parity
vs the unfused SwiGLU composition (including grads through both custom
VJPs), the traffic-aware DSE extensions, and the tb feasibility
fallback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.core import dse
from repro.core.bandwidth import hbm_traffic_bytes
from repro.core.hardware import TPU_V5E
from repro.core.memory_model import fits_vmem, vmem_footprint
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import ops, ref
from repro.kernels.epilogue import ACTIVATIONS, Epilogue, apply_epilogue
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_gated import gemm_gated
from repro.kernels.gemm_tb import feasible_bk, gemm_tb

# These suites exercise the deprecated legacy entrypoints on purpose
# (old-vs-new parity is the point); the -W error::DeprecationWarning
# CI invocation must not fail them.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")



M, K, N = 64, 256, 128


def _operands(mode: str, key=0):
    """(a, b, b_scale) for one precision mode."""
    ka, kb = jax.random.split(jax.random.PRNGKey(key))
    w = jax.random.normal(kb, (K, N), jnp.float32)
    if mode == "bf16":
        return (jax.random.normal(ka, (M, K), jnp.bfloat16),
                w.astype(jnp.bfloat16), None)
    wq = quant.quantize_weight(w)
    if mode == "w8a16":
        return (jax.random.normal(ka, (M, K), jnp.bfloat16),
                wq["q"], wq["scale"])
    assert mode == "w8a8"
    a_q, _ = ref.quantize_int8(jax.random.normal(ka, (M, K), jnp.float32),
                               axis=-1)
    return a_q, wq["q"], wq["scale"]


EP_VARIANTS = {
    "bias": dict(bias=True),
    "silu": dict(activation="silu"),
    "gelu": dict(activation="gelu"),
    "relu": dict(activation="relu"),
    "res": dict(residual=True),
    "bias+silu+res": dict(bias=True, activation="silu", residual=True),
}


def _ep_operands(flags, key=7):
    bias = res = None
    if flags.get("bias"):
        bias = jax.random.normal(jax.random.PRNGKey(key), (1, N),
                                 jnp.float32)
    if flags.get("residual"):
        res = jax.random.normal(jax.random.PRNGKey(key + 1), (M, N),
                                jnp.float32)
    return bias, res


# ----------------------------------------------------- spec round-trip

def test_epilogue_spec_roundtrip_and_validation():
    for flags in EP_VARIANTS.values():
        ep = Epilogue(bias=flags.get("bias", False),
                      activation=flags.get("activation"),
                      residual=flags.get("residual", False))
        assert Epilogue.parse(ep.key) == ep
        assert bool(ep)
    assert Epilogue.parse("") == Epilogue() and not Epilogue()
    assert Epilogue(out_quant=True).key == "q8"
    with pytest.raises(ValueError):
        Epilogue(activation="tanh")
    with pytest.raises(ValueError):
        Epilogue.parse("bias+nonsense")


# ------------------------------------------- kernel-level parity sweep

@pytest.mark.parametrize("strategy", ["aie", "tb"])
@pytest.mark.parametrize("mode", ["bf16", "w8a16", "w8a8"])
@pytest.mark.parametrize("variant", sorted(EP_VARIANTS), ids=str)
def test_kernel_epilogue_matches_unfused_composition(strategy, mode,
                                                     variant):
    flags = EP_VARIANTS[variant]
    a, b, b_scale = _operands(mode)
    bias, res = _ep_operands(flags)
    tile = TileConfig(32, 128, 128, strategy)
    fn = gemm_aie if strategy == "aie" else gemm_tb
    got = fn(a, b, tile=tile, b_scale=b_scale, bias=bias, residual=res,
             activation=flags.get("activation"), out_dtype=jnp.float32,
             interpret=True)

    # unfused composition: plain GEMM (+ explicit dequant), then the
    # epilogue as separate XLA ops in fp32
    if b_scale is None:
        z = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    else:
        z = ref.gemm_fused_ref(a, b, b_scale, out_dtype=jnp.float32)
    want = apply_epilogue(z, activation=flags.get("activation"),
                          bias=bias, residual=res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("strategy", ["aie", "tb"])
def test_kernel_out_quant_epilogue(strategy):
    """Optional quantized output: the flush divides by the given scale,
    rounds and clips to int8."""
    a, b, _ = _operands("bf16")
    osc = jnp.asarray([[0.05]], jnp.float32)
    tile = TileConfig(32, 128, 128, strategy)
    fn = gemm_aie if strategy == "aie" else gemm_tb
    got = fn(a, b, tile=tile, activation="relu", out_scale=osc,
             out_dtype=jnp.int8, interpret=True)
    z = ref.gemm_ref(a, b, out_dtype=jnp.float32)
    want = jnp.clip(jnp.round(jax.nn.relu(z) / 0.05), -127, 127) \
        .astype(jnp.int8)
    assert got.dtype == jnp.int8
    # bf16 accumulation noise may flip a borderline rounding by 1 LSB
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert diff.max() <= 1


# ------------------------------------------------------- gated kernel

@pytest.mark.parametrize("mode", ["bf16", "w8a16", "w8a8"])
def test_gated_kernel_matches_unfused_swiglu(mode):
    a, bg, sg = _operands(mode, key=0)
    _, bu, su = _operands(mode, key=1)
    tile = TileConfig(32, 128, 128, "aie")
    got = gemm_gated(a, bg, bu, tile=tile, bg_scale=sg, bu_scale=su,
                     out_dtype=jnp.float32, interpret=True)
    # unfused: two separate GEMMs, silu and multiply in XLA
    if sg is None:
        zg = ref.gemm_ref(a, bg, out_dtype=jnp.float32)
        zu = ref.gemm_ref(a, bu, out_dtype=jnp.float32)
    else:
        zg = ref.gemm_fused_ref(a, bg, sg, out_dtype=jnp.float32)
        zu = ref.gemm_fused_ref(a, bu, su, out_dtype=jnp.float32)
    want = jax.nn.silu(zg) * zu
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_ops_gemm_gated_interpret_matches_model_swiglu(monkeypatch):
    """ops-level gated dispatch (interpret) vs the unfused model-layer
    composition it replaced, on a (b, s, d) activation."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 192),
                          jnp.bfloat16)
    wg = jax.random.normal(jax.random.PRNGKey(1), (192, 256),
                           jnp.bfloat16)
    wu = jax.random.normal(jax.random.PRNGKey(2), (192, 256),
                           jnp.bfloat16)
    got = ops.gemm_gated(x, wg, wu)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    gate = ops.gemm(x, wg)
    up = ops.gemm(x, wu)
    want = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)
    assert got.shape == (2, 8, 256) and got.dtype == x.dtype


def test_ops_gemm_fused_quant_struct_interpret(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 256), jnp.bfloat16)
    wq = quant.quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32))
    bias = jax.random.normal(jax.random.PRNGKey(2), (128,), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(3), (16, 128),
                            jnp.bfloat16)
    got = ops.gemm_fused(a, wq, bias=bias, activation="silu",
                         residual=res, out_dtype=jnp.float32)
    w = quant.dequantize_weight(wq, jnp.float32)
    want = jax.nn.silu(a.astype(jnp.float32) @ w + bias) \
        + res.astype(jnp.float32)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 2e-2, rel


# ------------------------------------------------------------- grads

def test_gemm_fused_grads_match_unfused_composition():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    bias = jax.random.normal(jax.random.PRNGKey(2), (32,), jnp.float32)
    res = jax.random.normal(jax.random.PRNGKey(3), (16, 32), jnp.float32)

    def fused(a, b, bias, res):
        return jnp.sum(ops.gemm_fused(a, b, bias=bias, activation="silu",
                                      residual=res) ** 2)

    def unfused(a, b, bias, res):
        return jnp.sum((jax.nn.silu(a @ b + bias) + res) ** 2)

    got = jax.grad(fused, argnums=(0, 1, 2, 3))(a, b, bias, res)
    want = jax.grad(unfused, argnums=(0, 1, 2, 3))(a, b, bias, res)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_gemm_gated_grads_match_unfused_composition():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    bg = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    bu = jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32)

    def fused(a, bg, bu):
        return jnp.sum(ops.gemm_gated(a, bg, bu) ** 2)

    def unfused(a, bg, bu):
        return jnp.sum((jax.nn.silu(a @ bg) * (a @ bu)) ** 2)

    got = jax.grad(fused, argnums=(0, 1, 2))(a, bg, bu)
    want = jax.grad(unfused, argnums=(0, 1, 2))(a, bg, bu)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_gemm_fused_quant_grad_dequantizes_only_in_backward():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    wq = quant.quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32))
    wd = quant.dequantize_weight(wq, jnp.float32)
    bias = jnp.zeros((32,), jnp.float32)
    ga = jax.grad(lambda x: jnp.sum(ops.gemm_fused(
        x, wq, bias=bias, activation="gelu") ** 2))(a)
    want = jax.grad(lambda x: jnp.sum(jax.nn.gelu(x @ wd) ** 2))(a)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_gated_quant_grad():
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    wgq = quant.quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32))
    wuq = quant.quantize_weight(
        jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32))
    wg = quant.dequantize_weight(wgq, jnp.float32)
    wu = quant.dequantize_weight(wuq, jnp.float32)
    ga = jax.grad(lambda x: jnp.sum(ops.gemm_gated(x, wgq, wuq) ** 2))(a)
    want = jax.grad(
        lambda x: jnp.sum((jax.nn.silu(x @ wg) * (x @ wu)) ** 2))(a)
    # fused-int8 dot vs dequantize-first dot: identical math, different
    # reduction order -> ~1e-3 relative float noise
    np.testing.assert_allclose(np.asarray(ga), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------- model layers

def test_swiglu_residual_fusion_matches_old_composition():
    from repro.models import layers as L
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 96), jnp.float32)
    params = L.init_swiglu(jax.random.PRNGKey(1), 96, 192, jnp.float32)
    got = L.swiglu(params, x, residual=x)
    gate = ops.gemm(x, params["w_gate"])
    up = ops.gemm(x, params["w_up"])
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    want = x + ops.gemm(h, params["w_down"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_attention_block_residual_fusion():
    from repro.models import layers as L
    spec = L.AttnLayerSpec(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    params = L.init_attention(jax.random.PRNGKey(0), spec, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.float32)
    got = L.attention_block(params, x, spec, residual=x)
    want = x + L.attention_block(params, x, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- cost model / DSE

def test_vmem_footprint_bills_epilogue_and_second_b():
    t = TileConfig(128, 512, 512, "aie")
    base = vmem_footprint(t, GemmProblem(128, 2048, 2048))
    ep = vmem_footprint(
        t, GemmProblem(128, 2048, 2048, epilogue="bias+silu+res"))
    assert ep.bias_bytes > 0 and ep.residual_bytes > 0
    assert ep.total > base.total
    gated = vmem_footprint(
        t, GemmProblem(128, 2048, 2048, epilogue="silu", n_b_operands=2))
    assert gated.b_bytes == 2 * base.b_bytes
    assert gated.acc_bytes == 2 * base.acc_bytes


def test_hbm_traffic_bills_dual_b_and_residual():
    t = TileConfig(16, 512, 512, "aie")
    p1 = GemmProblem(16, 4096, 4096)
    p2 = GemmProblem(16, 4096, 4096, epilogue="silu", n_b_operands=2)
    extra = hbm_traffic_bytes(t, p2) - hbm_traffic_bytes(t, p1)
    assert extra == pytest.approx(p1.b_bytes, rel=1e-6)  # second B once
    pres = GemmProblem(16, 4096, 4096, epilogue="res")
    assert hbm_traffic_bytes(t, pres) - hbm_traffic_bytes(t, p1) \
        == pytest.approx(16 * 4096 * 2)                  # residual read


def test_dse_gated_search_is_aie_only_and_feasible():
    for d in dse.solve(GemmProblem(16, 4096, 14336, epilogue="silu",
                                   n_b_operands=2)):
        assert d.tile.strategy == "aie"
        assert fits_vmem(d.tile,
                         GemmProblem(16, 4096, 14336, epilogue="silu",
                                     n_b_operands=2), TPU_V5E)
    t = dse.best_tile(16, 4096, 14336, epilogue="silu", n_b_operands=2)
    assert t.strategy == "aie"


def test_dse_cache_distinguishes_epilogue():
    a = dse.solve(GemmProblem(64, 1024, 1024), top=1)[0]
    b = dse.solve(GemmProblem(64, 1024, 1024, epilogue="res"), top=1)[0]
    assert b.traffic.hbm_bytes > a.traffic.hbm_bytes


def test_plan_explain_agrees_with_cost_model():
    """GemmPlan carries exactly the DSE/traffic-model numbers: the tile
    is ``dse.best_tile``'s winner and the modeled bytes are
    ``hbm_traffic_bytes`` at that tile, for the decode- and train-shaped
    cases asserted throughout this module."""
    from repro.kernels import api
    # decode-shaped gated SwiGLU up-projection (16 x 4096 x ff 14336)
    pl = api.plan(api.GemmSpec(gated=True, epilogue="silu"),
                  (16, 4096, 14336))
    assert pl.tile == dse.best_tile(16, 4096, 14336, epilogue="silu",
                                    n_b_operands=2)
    assert pl.hbm_bytes == hbm_traffic_bytes(pl.tile, pl.problem)
    assert f"{pl.hbm_bytes / 2**20:.2f} MiB" in pl.explain()
    # train-shaped residual down-projection (8192 x 14336 x 4096)
    pl2 = api.plan(api.GemmSpec(epilogue="res"), (8192, 14336, 4096))
    assert pl2.tile == dse.best_tile(8192, 14336, 4096, epilogue="res")
    assert pl2.hbm_bytes == hbm_traffic_bytes(pl2.tile, pl2.problem)
    assert pl2.flops == pl2.traffic.flops
    assert pl2.vmem_bytes == pl2.vmem.total


def test_decode_swiglu_modeled_hbm_drop():
    """Acceptance criterion: decode-shaped SwiGLU (16x4096, d_ff 14336).
    The weight stream is an irreducible floor both sides share, so the
    fusion credit lands on the activation/intermediate traffic: >= 30%
    modeled drop (measured ~53%)."""
    fused = dse.mlp_traffic(16, 4096, 14336, fused=True)
    unfused = dse.mlp_traffic(16, 4096, 14336, fused=False)
    assert fused["weights"] == unfused["weights"]        # same floor
    assert fused["activations"] <= 0.7 * unfused["activations"], \
        (fused, unfused)
    assert fused["total"] < unfused["total"]


def test_train_swiglu_modeled_hbm_drop_total():
    """At train/prefill shapes the (m, d_ff) intermediates dominate and
    the >= 30% drop holds on TOTAL modeled layer bytes (measured ~35%)."""
    fused = dse.mlp_traffic(8192, 4096, 14336, fused=True, residual=True)
    unfused = dse.mlp_traffic(8192, 4096, 14336, fused=False,
                              residual=True)
    assert fused["total"] <= 0.7 * unfused["total"], (fused, unfused)


# --------------------------------------------- tb feasibility satellite

def test_feasible_bk_shrinks_oversized_k_chunk():
    # (2048, 2048) f32 A resident + B streams + rmw C streams: ~112 MiB,
    # over the 0.75 * 128 MiB budget — the k-chunk must refine
    big = TileConfig(2048, 2048, 2048, "tb")
    p = GemmProblem(2048, 8192, 2048, "float32", "float32")
    assert not fits_vmem(big, p)
    bk = feasible_bk(2048, 8192, 2048, big, jnp.float32, jnp.float32,
                     jnp.float32, jnp.float32)
    assert 0 < bk < 2048
    assert 8192 % bk == 0
    assert fits_vmem(TileConfig(2048, bk, 2048, "tb"), p)


def test_gemm_tb_refines_infeasible_bk_and_stays_correct():
    m, k, n = 256, 1024, 256
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    # tiny budget forces the refinement path deterministically: monkey-
    # patching is avoided by picking a tile that is feasible (so no
    # error) — correctness must be identical whatever bk is used
    got = gemm_tb(a, b, tile=TileConfig(256, 1024, 256, "tb"),
                  interpret=True)
    want = ref.gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gemm_tb_raises_when_blocks_cannot_fit(monkeypatch):
    from repro.core import memory_model
    monkeypatch.setattr(memory_model, "fits_vmem",
                        lambda *a, **kw: False)
    # shapes unique to this test: gemm_tb is jit-cached on the static
    # (shape, tile) signature, and a hit would skip the trace-time check
    a = jnp.zeros((128, 640), jnp.float32)
    b = jnp.zeros((640, 128), jnp.float32)
    with pytest.raises(ValueError, match="infeasible"):
        gemm_tb(a, b, tile=TileConfig(128, 128, 128, "tb"),
                interpret=True)


def test_explicit_infeasible_tb_tile_raises(monkeypatch):
    """The plan-level gate: an explicit tile= override is honored
    verbatim, and one that can never fit raises at plan time instead of
    being silently replaced by another kernel's tile."""
    from repro.kernels import api
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.setattr(api, "feasible_bk", lambda *a, **kw: 0)
    api.plan_cache_clear()
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.bfloat16)
    try:
        with pytest.raises(ValueError, match="infeasible"):
            ops.gemm(a, b, tile=TileConfig(64, 128, 128, "tb"))
    finally:
        api.plan_cache_clear()


def test_dse_tb_winner_falls_back_to_aie_with_reason(monkeypatch):
    """A strategy='tb' *hint* (no explicit tile) whose DSE winner fails
    the post-clamp viability recheck re-routes to the aie winner and the
    plan records why — the old silent fallback, now introspectable."""
    from repro.kernels import api
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    monkeypatch.setattr(api, "feasible_bk", lambda *a, **kw: 0)
    api.plan_cache_clear()
    a = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.bfloat16)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.bfloat16)
    try:
        spec = api.GemmSpec.for_operands(a, b, strategy="tb")
        pl = api.plan(spec, api.gemm_shapes(a, b))
        assert pl.tile.strategy == "aie"
        assert pl.fallback_reason and "aie" in pl.fallback_reason
        assert "fallback" in pl.explain()
        got = api.execute(pl, a, b)
        want = ref.gemm_ref(a, b, out_dtype=jnp.bfloat16)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)
    finally:
        api.plan_cache_clear()


# ------------------------------------- xent fp32 emission satellite

def test_gemm_ref_keeps_operands_at_storage_dtype():
    """The fp32-upcast-round-trip fix: fp32 logits must come from
    preferred_element_type accumulation, not from pre-cast fp32 copies of
    the bf16 operands (k*V extra HBM bytes on the lm_head hot path)."""
    a = jnp.zeros((8, 64), jnp.bfloat16)
    b = jnp.zeros((64, 32), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(
        lambda a, b: ref.gemm_ref(a, b, out_dtype=jnp.float32))(a, b)
    prims = [e.primitive.name for e in jaxpr.eqns]
    assert "convert_element_type" not in prims, prims
    dot = [e for e in jaxpr.eqns if e.primitive.name == "dot_general"][0]
    assert dot.params["preferred_element_type"] == jnp.float32


def test_w8a8_mode_keeps_int8_path_for_linear_epilogue():
    """Residual/bias-only epilogues commute with the per-row activation
    scale, so w8a8 mode must keep the int8 x int8 MXU path (epilogue
    applied outside); nonlinear epilogues fall back to fused W8A16."""
    a = jax.random.normal(jax.random.PRNGKey(0), (8, 128), jnp.float32)
    wq = quant.quantize_weight(
        jax.random.normal(jax.random.PRNGKey(1), (128, 64), jnp.float32))
    res = jax.random.normal(jax.random.PRNGKey(2), (8, 64), jnp.float32)
    quant.set_activation_mode("w8a8")
    try:
        lin = ops.gemm_fused(a, wq, residual=res)
        # int8 x int8 GEMM + residual outside == w8a8 gemm + res
        want = ops.gemm(a, wq, out_dtype=jnp.float32) + res
        np.testing.assert_allclose(np.asarray(lin), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
        nonlin = ops.gemm_fused(a, wq, activation="silu")
    finally:
        quant.set_activation_mode("none")
    # nonlinear: W8A16 (no activation quant) — matches the plain ref
    want_nl = jax.nn.silu(a @ quant.dequantize_weight(wq, jnp.float32))
    rel = float(jnp.linalg.norm(nonlin - want_nl)
                / jnp.linalg.norm(want_nl))
    assert rel < 2e-2, rel
    # and the w8a8 quantization error is visible in the linear path
    exact = a @ quant.dequantize_weight(wq, jnp.float32) + res
    assert float(jnp.linalg.norm(lin - exact)
                 / jnp.linalg.norm(exact)) < 0.05


def test_activation_table_matches_model_functions():
    z = jnp.linspace(-3, 3, 64)
    np.testing.assert_allclose(np.asarray(ACTIVATIONS["silu"](z)),
                               np.asarray(jax.nn.silu(z)))
    np.testing.assert_allclose(np.asarray(ACTIVATIONS["gelu"](z)),
                               np.asarray(jax.nn.gelu(z)))
