"""Grouped ragged GEMM: kernel-vs-oracle bitwise parity, the planned
``ops.gemm_grouped`` dispatch (ref / interpret x plain / W8A16 /
epilogue), the grouped VJP, per-group plan billing, and the MoE layer
riding it (pjit + quantized banks + telemetry counters).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops, quant, telemetry
from repro.kernels import api
from repro.kernels.gemm_grouped import (
    gemm_grouped_blocked_ref,
    group_metadata,
)


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    api.plan_cache_clear()
    yield
    api.plan_cache_clear()


def _rand(shape, dtype=jnp.bfloat16, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return (x * scale).astype(dtype)


SIZES = np.array([100, 0, 37, 60], np.int32)       # ragged + empty group
E, K, N = 4, 256, 256
M = int(SIZES.sum())
A = _rand((M, K), seed=0)
B = _rand((E, K, N), seed=1, scale=0.1)
GS = jnp.asarray(SIZES)
BQ = quant.quantize_weight(np.asarray(
    jax.random.normal(jax.random.PRNGKey(2), (E, K, N), jnp.float32)))
BIAS = _rand((E, N), jnp.float32, seed=3)


def _numpy_oracle(a, b, sizes, bias=None, activation=None):
    gid = np.repeat(np.arange(len(sizes)), np.asarray(sizes))
    an = np.asarray(a, np.float32)
    bn = np.asarray(b, np.float32)
    out = np.zeros((an.shape[0], bn.shape[-1]), np.float32)
    for g in range(bn.shape[0]):
        sel = gid == g
        z = an[sel] @ bn[g]
        if bias is not None:
            z = z + np.asarray(bias, np.float32)[g]
        if activation == "silu":
            z = z / (1.0 + np.exp(-np.clip(z, -60, 60)))
        out[sel] = z
    return out


# ---------------------------------------------------------------------------
# group metadata
# ---------------------------------------------------------------------------

def test_group_metadata_instances_and_tables():
    (offs, gids, tids), n_inst = group_metadata(GS, 256, 64)
    offs, gids, tids = map(np.asarray, (offs, gids, tids))
    assert list(offs) == [0, 100, 100, 137, 197]
    n = int(n_inst)
    # every live (group, m-tile) pair appears exactly once, in order
    pairs = list(zip(gids[:n], tids[:n]))
    assert pairs == sorted(set(pairs), key=lambda p: (p[1], p[0]))
    for g, t in pairs:
        lo, hi = offs[g], offs[g + 1]
        assert lo < hi, "empty group emitted an instance"
        assert lo < (t + 1) * 64 and hi > t * 64, "instance off its rows"
    # static table length is tiles_m + E - 1 regardless of raggedness
    assert gids.shape == tids.shape == (256 // 64 + len(SIZES) - 1,)


def test_group_metadata_all_empty():
    (offs, _, _), n_inst = group_metadata(
        jnp.zeros((4,), jnp.int32), 128, 64)
    assert int(n_inst) == 0 and int(np.asarray(offs)[-1]) == 0


# ---------------------------------------------------------------------------
# interpret-mode kernel == blocked XLA oracle, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sizes", [SIZES, [0, 0, 197, 0], [64, 64, 64, 5]])
def test_kernel_bitwise_vs_blocked_ref(monkeypatch, sizes):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    gs = jnp.asarray(np.asarray(sizes, np.int32))
    y = ops.gemm_grouped(A, B, gs)
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                        grouped=True)
    pl = ops.plan(spec, ops.gemm_grouped_shapes(A, B))
    t = pl.tile
    pad = lambda d, bd: (-(-d // bd)) * bd - d
    ap = jnp.pad(A, ((0, pad(M, t.bm)), (0, pad(K, t.bk))))
    bp = jnp.pad(B, ((0, 0), (0, pad(K, t.bk)), (0, pad(N, t.bn))))
    ref = gemm_grouped_blocked_ref(ap, bp, gs, tile=t,
                                   out_dtype=y.dtype)[:M, :N]
    assert jnp.all(y == ref), "interpret kernel diverged from oracle"


def test_kernel_bitwise_vs_blocked_ref_fused(monkeypatch):
    """The fused W8A16 + bias + silu flush must also match bitwise."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    y = ops.gemm_grouped(A, BQ, GS, bias=BIAS, activation="silu")
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="int8", b_quant=True,
                        grouped=True,
                        epilogue=ops.Epilogue(bias=True, activation="silu"))
    pl = ops.plan(spec, ops.gemm_grouped_shapes(A, BQ))
    t = pl.tile
    pad = lambda d, bd: (-(-d // bd)) * bd - d
    ap = jnp.pad(A, ((0, pad(M, t.bm)), (0, pad(K, t.bk))))
    qp = jnp.pad(BQ["q"], ((0, 0), (0, pad(K, t.bk)), (0, pad(N, t.bn))))
    sp = jnp.pad(BQ["scale"], ((0, 0), (0, 0), (0, pad(N, t.bn))),
                 constant_values=1.0)
    bp = jnp.pad(BIAS.reshape(E, 1, N),
                 ((0, 0), (0, 0), (0, pad(N, t.bn))))
    ref = gemm_grouped_blocked_ref(ap, qp, GS, tile=t, b_scale=sp,
                                   bias=bp, activation="silu",
                                   out_dtype=y.dtype)[:M, :N]
    assert jnp.all(y == ref)


# ---------------------------------------------------------------------------
# dispatch numerics (both modes) vs the per-group numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_dispatch_matches_numpy(monkeypatch, mode):
    monkeypatch.setenv("REPRO_KERNELS", mode)
    y = np.asarray(ops.gemm_grouped(A, B, GS), np.float32)
    want = _numpy_oracle(A, B, SIZES)
    np.testing.assert_allclose(y, want, atol=0.05, rtol=0.05)


@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_dispatch_quant_epilogue_matches_numpy(monkeypatch, mode):
    monkeypatch.setenv("REPRO_KERNELS", mode)
    y = np.asarray(ops.gemm_grouped(A, BQ, GS, bias=BIAS,
                                    activation="silu"), np.float32)
    want = _numpy_oracle(
        A, np.asarray(BQ["q"], np.float32) * np.asarray(BQ["scale"]),
        SIZES, bias=BIAS, activation="silu")
    tol = 0.05 * (np.abs(want).max() + 1)
    assert np.max(np.abs(y - want)) < tol


def test_empty_groups_give_zeros():
    y = ops.gemm_grouped(A, B, jnp.zeros((E,), jnp.int32))
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32)))) == 0.0


# ---------------------------------------------------------------------------
# grouped VJP — grad parity with the dense masked composition
# ---------------------------------------------------------------------------

def test_vjp_matches_dense_composition():
    af = _rand((M, K), jnp.float32, seed=5)
    bf = _rand((E, K, N), jnp.float32, seed=6, scale=0.1)
    biasf = _rand((E, N), jnp.float32, seed=7)
    gid = jnp.asarray(np.repeat(np.arange(E), SIZES))

    def f_grouped(a, b, bias):
        y = ops.gemm_grouped(a, b, GS, bias=bias, activation="gelu",
                             out_dtype=jnp.float32)
        return jnp.sum(y ** 2)

    def f_dense(a, b, bias):
        z = jnp.einsum("rk,rkn->rn", a, b[gid]) + bias[gid]
        return jnp.sum(jax.nn.gelu(z, approximate=True) ** 2)

    got = jax.grad(f_grouped, argnums=(0, 1, 2))(af, bf, biasf)
    want = jax.grad(f_dense, argnums=(0, 1, 2))(af, bf, biasf)
    for name, g, w in zip("a b bias".split(), got, want):
        rel = float(jnp.max(jnp.abs(g - w))
                    / (jnp.max(jnp.abs(w)) + 1e-6))
        assert rel < 2e-4, (name, rel)


def test_vjp_quant_grads_activations_only():
    """W8A16 backward: dA flows (through dequantized panels), the int8
    bank gets no cotangent."""
    af = _rand((M, K), jnp.float32, seed=8)
    g = jax.grad(lambda a: jnp.sum(ops.gemm_grouped(a, BQ, GS) ** 2))(af)
    assert g.shape == af.shape and bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# spec/plan: validation, per-group billing, padding-FLOPs saving
# ---------------------------------------------------------------------------

def test_grouped_spec_rejects_gated_and_tb():
    with pytest.raises(ValueError, match="gated"):
        ops.GemmSpec(grouped=True, gated=True,
                     epilogue=ops.Epilogue(activation="silu"))
    with pytest.raises(ValueError, match="grouped"):
        ops.GemmSpec(grouped=True, strategy="tb")
    with pytest.raises(ValueError, match="grouped"):
        ops.GemmSpec(grouped=True, epilogue=ops.Epilogue(residual=True))


def test_execute_validates_group_sizes():
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                        grouped=True)
    pl = ops.plan(spec, ops.gemm_grouped_shapes(A, B))
    with pytest.raises(ValueError, match="group_sizes"):
        ops.execute(pl, A, B)                   # grouped without sizes
    dense = ops.plan(ops.GemmSpec(), ops.gemm_shapes(A, B[0]))
    with pytest.raises(ValueError, match="group_sizes"):
        ops.execute(dense, A, B[0], group_sizes=GS)


def test_explain_reports_group_billing_and_padding(monkeypatch):
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                        grouped=True)
    # an imbalanced MoE shape: 2304 routed rows vs 5120 dense capacity
    pl = ops.plan(spec, (2304, 512, 1024, 8, 5120))
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    txt = pl.explain()
    assert "gemm_grouped" in txt
    assert "E=8 groups" in txt and "2304 of 5120 dense-capacity" in txt
    assert "padding" in txt and "saved" in txt
    # executed FLOPs sit between true-rows and dense-capacity work
    true_f = 2.0 * 2304 * 512 * 1024
    dense_f = 2.0 * 5120 * 512 * 1024
    assert true_f <= pl.flops < dense_f


def test_grouped_billed_at_true_rows_not_capacity():
    """A/HBM billing follows the true routed rows: the same grouped
    problem at the E*C dense-capacity row count must model strictly
    more traffic and more executed FLOPs."""
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                        grouped=True)
    pl = ops.plan(spec, (2304, 512, 1024, 8, 5120))
    cap = ops.plan(spec, (5120, 512, 1024, 8, 5120))
    assert pl.hbm_bytes < cap.hbm_bytes
    assert pl.flops < cap.flops


def test_plan_cache_keys_on_group_count():
    spec = ops.GemmSpec(a_dtype="bfloat16", b_dtype="bfloat16",
                        grouped=True)
    p1 = ops.plan(spec, (256, 256, 256, 4))
    p2 = ops.plan(spec, (256, 256, 256, 8))
    p3 = ops.plan(spec, (256, 256, 256, 4))
    assert p1 is p3 and p1 is not p2


# ---------------------------------------------------------------------------
# the MoE layer on top (pjit path; EP lives in test_moe_ep.py)
# ---------------------------------------------------------------------------

def _moe_setup(dtype=jnp.float32, seed=0):
    import repro.models.moe as MOE
    key = jax.random.PRNGKey(seed)
    p = MOE.init_moe(key, 32, 64, 8, dtype)
    x = jax.random.normal(key, (2, 16, 32), dtype)
    return MOE, p, x


def test_moe_grouped_matches_dense_ref():
    MOE, p, x = _moe_setup()
    y, aux = MOE._moe_ffn_pjit(p, x, top_k=2, capacity_factor=16.0)
    want = MOE.moe_ffn_dense_ref(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_grouped_matches_dense_fallback(monkeypatch):
    """REPRO_MOE_GROUPED=0 (padded einsum) and the grouped path are the
    same layer at fp tolerance — drops included (tight capacity)."""
    MOE, p, x = _moe_setup(seed=3)
    y1, _ = jax.jit(lambda p, x: MOE._moe_ffn_pjit(
        p, x, top_k=2, capacity_factor=1.0))(p, x)
    monkeypatch.setenv("REPRO_MOE_GROUPED", "0")
    y0, _ = jax.jit(lambda p, x: MOE._moe_ffn_pjit(
        p, x, top_k=2, capacity_factor=1.0))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)


def test_moe_quantized_banks_through_grouped():
    MOE, p, x = _moe_setup(seed=1)
    qp = dict(p)
    for name in ("w_gate", "w_up", "w_down"):
        qp[name] = quant.quantize_weight(p[name])
    y, _ = MOE._moe_ffn_pjit(qp, x, top_k=2, capacity_factor=16.0)
    want = MOE.moe_ffn_dense_ref(qp, x, top_k=2)   # dequantizes up front
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_moe_grad_through_grouped():
    MOE, p, x = _moe_setup(seed=2)
    g1 = jax.grad(lambda p: jnp.sum(MOE._moe_ffn_pjit(
        p, x, top_k=2, capacity_factor=16.0)[0] ** 2))(p)
    g2 = jax.grad(lambda p: jnp.sum(
        MOE.moe_ffn_dense_ref(p, x, top_k=2) ** 2))(p)
    for k in g1:
        err = float(jnp.max(jnp.abs(g1[k] - g2[k])))
        assert err < 1e-4, (k, err)


def test_moe_telemetry_counters():
    MOE, p, x = _moe_setup(seed=4)
    telemetry.enable()
    try:
        jax.block_until_ready(
            MOE._moe_ffn_pjit(p, x, top_k=2, capacity_factor=0.6)[0])
        jax.effects_barrier()
        snap = telemetry.snapshot()
    finally:
        telemetry.disable()
    routed = snap["counters"]["moe.group_sizes"]
    dropped = snap["counters"]["moe.dropped_tokens"]
    assert routed + dropped == 2 * 16 * 2       # every assignment counted
    assert routed > 0


def test_quant_paths_cover_expert_banks():
    assert quant.QUANT_PATHS.search("layers/u0/moe/w_gate")
    assert quant.QUANT_PATHS.search("layers/u0/moe/w_down")
    assert not quant.QUANT_PATHS.search("layers/u0/moe/router")
