"""Layout engine: param specs, divisibility relaxation, cache specs,
batch specs, multi-pod FSDP resolution."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, get_smoke_config
from repro.dist import layout
from repro.models import transformer as T


class FakeMesh:
    """Duck-typed mesh (axis names + shape) for spec-level tests."""

    def __init__(self, shape, names):
        import numpy as np
        self.devices = np.empty(shape, dtype=object)
        self.axis_names = names


MESH = FakeMesh((16, 16), ("data", "model"))
MESH_POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_tp_specs_shard_ffn_and_vocab():
    s = layout.spec_for("layers/u0/mlp/w_gate", (32, 4096, 16384), "tp",
                        {"data": 16, "model": 16})
    assert s == P(None, None, "model")
    s = layout.spec_for("lm_head", (4096, 256000), "tp",
                        {"data": 16, "model": 16})
    assert s == P(None, "model")


def test_divisibility_relaxation():
    # projection dim not divisible by the 16-way model axis -> that dim
    # relaxes to replicated while the divisible data dim stays sharded
    s = layout.spec_for("layers/u0/attn/wq", (32, 960, 950), "fsdp_tp",
                        {"data": 16, "model": 16})
    assert s == P(None, "data", None)
    # smollm's 960 = 60*16 divides: weights shard even with 15 heads
    # (the replication cost shows up at the head reshape, not here)
    s = layout.spec_for("layers/u0/attn/wq", (32, 960, 960), "fsdp_tp",
                        {"data": 16, "model": 16})
    assert s == P(None, "data", "model")


def test_fsdp_resolves_pod_data_on_multipod():
    s = layout.spec_for("layers/u0/mlp/w_gate", (61, 7168, 2048),
                        "fsdp_tp", {"pod": 2, "data": 16, "model": 16})
    assert s == P(None, ("pod", "data"), "model")
    # and falls back to ('data',) when pod doesn't divide
    s = layout.spec_for("layers/u0/mlp/w_gate", (61, 7168 + 16, 2048),
                        "fsdp_tp", {"pod": 2, "data": 16, "model": 16})
    assert s[1] is None or s[1] == "data" or s[1] == ("pod", "data")


def test_choose_layout_by_size():
    assert layout.choose_layout(get_config("smollm-360m")) == "tp"
    assert layout.choose_layout(get_config("deepseek-67b")) == "fsdp_tp"
    assert layout.choose_layout(get_config("kimi-k2-1t-a32b")) \
        == "fsdp_tp"


def test_param_specs_cover_tree():
    cfg = get_smoke_config("qwen3-moe-235b-a22b")
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = layout.param_specs(params, cfg, MESH, "tp")
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs,
                             is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape)   # full-rank specs


def test_batch_specs_shard_rows():
    tree = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    specs = layout.batch_specs(tree, MESH_POD)
    assert specs["tokens"] == P(("pod", "data"), None)
    # batch=1 (long_500k): replicate rather than fail
    tree = {"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32)}
    specs = layout.batch_specs(tree, MESH_POD)
    assert specs["tokens"] == P(None, None)


def test_cache_specs_shard_seq_over_model():
    cfg = get_config("minitron-8b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 32768))
    specs = layout.cache_specs(cache, MESH)
    k_spec = specs["layers"]["u0"]["k"]
    # (repeats, batch, seq, kv_heads, head_dim)
    assert k_spec == P(None, "data", "model", None, None)
    # per-slot (batch,) decode positions row-shard with their slots
    assert specs["pos"] == P("data")


def test_cache_specs_tail_unstacked():
    cfg = get_config("recurrentgemma-9b")
    cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 32768))
    specs = layout.cache_specs(cache, MESH)
    tail_kinds = cfg.tail_pattern
    assert tail_kinds == ("rec", "rec")
    conv = specs["tail"]["t0"]["conv"]
    assert conv[0] == "data"             # batch at axis 0 for tail
    # scanned local-attn cache still (repeats, batch, seq, ...)
    k_spec = specs["layers"]["u2"]["k"]
    assert k_spec[1] == "data" and k_spec[2] == "model"


def test_cache_specs_paged_pool_shards_kv_heads():
    """Block-paged cache: k/v pool leaves have no batch dim (any page
    serves any slot), so they shard kv-heads over 'model' instead;
    pos/page_table row-shard with the slots they index."""
    cfg = get_config("minitron-8b")          # n_kv_heads=8
    mesh = FakeMesh((2, 8), ("data", "model"))
    cache = jax.eval_shape(lambda: T.init_paged_cache(
        cfg, 128, n_pages=1024, page_size=64, max_pages=512))
    specs = layout.cache_specs(cache, mesh)
    # (repeats, n_pages, page_size, kv_heads, head_dim)
    assert specs["layers"]["u0"]["k"] == P(None, None, None, "model",
                                           None)
    assert specs["layers"]["u0"]["v"] == P(None, None, None, "model",
                                           None)
    assert specs["pos"] == P("data")
    assert specs["page_table"] == P("data", None)
    # kv heads that don't divide 'model' relax to replicated
    specs16 = layout.cache_specs(cache, MESH)
    assert specs16["layers"]["u0"]["k"] == P(None, None, None, None,
                                             None)
