"""Validation of the loop-corrected HLO cost parser.

Ground truth: ``compiled.cost_analysis()`` is exact on modules WITHOUT
while loops (fully unrolled) — the parser must agree there.  On scanned
modules XLA counts loop bodies once; the parser must recover the
trip-count-scaled totals.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import hlo_cost

MM = 2 * 256 ** 3      # flops of one 256^3 matmul


def _compile(f, *structs):
    return jax.jit(f).lower(*structs).compile()


def _structs(*shapes, dtype=jnp.float32):
    return [jax.ShapeDtypeStruct(s, dtype) for s in shapes]


def step(c, w):
    return jnp.tanh(c @ w), None


class TestFlops:
    def test_unrolled_matches_xla(self):
        def f(x, ws):
            y, _ = jax.lax.scan(step, x, ws, unroll=5)
            return y
        c = _compile(f, *_structs((256, 256), (5, 256, 256)))
        got = hlo_cost.analyze_text(c.as_text()).flops
        want = hlo_cost.xla_cost(c)["flops"]
        assert got == pytest.approx(want, rel=0.05)

    def test_scan_scales_by_trip_count(self):
        def f(x, ws):
            y, _ = jax.lax.scan(step, x, ws)
            return y
        c = _compile(f, *_structs((256, 256), (7, 256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        assert cost.flops == pytest.approx(7 * MM, rel=0.01)
        # XLA's own count misses the loop:
        assert hlo_cost.xla_cost(c)["flops"] == pytest.approx(MM, rel=0.01)

    def test_nested_scan_multiplies(self):
        def inner(c, w):
            y, _ = jax.lax.scan(step, c, w)
            return y, None

        def f(x, ws):
            y, _ = jax.lax.scan(inner, x, ws)
            return y
        c = _compile(f, *_structs((256, 256), (3, 4, 256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        assert cost.flops == pytest.approx(12 * MM, rel=0.01)

    def test_grad_scan(self):
        def loss(x, ws):
            y, _ = jax.lax.scan(step, x, ws)
            return y.sum()
        c = _compile(jax.grad(loss), *_structs((256, 256),
                                               (5, 256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        # fwd 5 + bwd d/dx 5 (grad wrt arg0 only)
        assert cost.flops == pytest.approx(10 * MM, rel=0.05)

    def test_dot_general_contracting_dims(self):
        def f(a, b):
            return jnp.einsum("bik,bkj->bij", a, b)
        c = _compile(f, *_structs((4, 64, 128), (4, 128, 32)))
        cost = hlo_cost.analyze_text(c.as_text())
        assert cost.flops == pytest.approx(2 * 4 * 64 * 128 * 32,
                                           rel=0.01)


class TestBytes:
    def test_unrolled_within_2x_of_xla(self):
        def f(x, ws):
            y, _ = jax.lax.scan(step, x, ws, unroll=5)
            return y
        c = _compile(f, *_structs((256, 256), (5, 256, 256)))
        got = hlo_cost.analyze_text(c.as_text()).bytes_accessed
        want = hlo_cost.xla_cost(c)["bytes accessed"]
        assert want * 0.5 <= got <= want * 2.5

    def test_scan_weight_reads_not_overcounted(self):
        # a scan slicing one (256,256) weight per step must charge ~1
        # slice per iteration, not the whole (N,256,256) stack
        n = 16
        def f(x, ws):
            y, _ = jax.lax.scan(step, x, ws)
            return y
        c = _compile(f, *_structs((256, 256), (n, 256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        stack_bytes = n * 256 * 256 * 4
        # each iteration touches ~7 slice-sized tensors (dot operands,
        # tanh, carry copies) = ~7/16 stack; charging the FULL stack per
        # iteration would be ~16 stacks — assert we're far below that
        assert cost.bytes_accessed < 8 * stack_bytes


class TestCollectives:
    def test_psum_in_scan_scales(self):
        if len(jax.devices()) < 1:
            pytest.skip("needs devices")
        from repro.dist import sharding as shd
        mesh = shd.make_mesh((1,), ("x",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        def f(x):
            def body(c, _):
                # c + psum keeps the carry 'varying' under shard_map's
                # replication typing
                return (c + jax.lax.psum(c, "x")) * 0.5, None
            y, _ = jax.lax.scan(body, x, None, length=9)
            return y

        g = shd.shard_map(f, mesh=mesh, in_specs=P("x"),
                          out_specs=P("x"), check=True)
        c = jax.jit(g).lower(
            jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile()
        cost = hlo_cost.analyze_text(c.as_text())
        ar = cost.collective_bytes["all-reduce"]
        assert ar == pytest.approx(9 * 8 * 128 * 4, rel=0.01)

    def test_trip_counts_recovered(self):
        def f(x, ws):
            y, _ = jax.lax.scan(step, x, ws)
            return y
        c = _compile(f, *_structs((256, 256), (11, 256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        assert 11 in cost.trip_counts.values()


class TestScopes:
    def test_named_scope_attribution(self):
        @jax.jit
        def inner_fn(a, b):
            return a @ b

        def f(a, b):
            # second matmul must differ or XLA CSEs the two dots
            return inner_fn(a, b) + a @ b.T
        c = _compile(f, *_structs((256, 256), (256, 256)))
        cost = hlo_cost.analyze_text(c.as_text())
        assert cost.flops == pytest.approx(2 * MM, rel=0.01)
        assert "inner_fn" in cost.flops_by_scope
        assert cost.flops_by_scope["inner_fn"] == pytest.approx(
            MM, rel=0.01)
