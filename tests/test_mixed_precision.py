"""Mixed-precision GEMM end-to-end: per-operand dtypes through the cost
model (W8A16 halves modeled weight traffic) and fused int8-weight Pallas
kernels (interpret-mode parity vs dequantize-first references)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.core import dse
from repro.core.bandwidth import estimate
from repro.core.hardware import TPU_V5E
from repro.core.memory_model import fits_vmem, vmem_footprint
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import ops, ref
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_tb import gemm_tb

# These suites exercise the deprecated legacy entrypoints on purpose
# (old-vs-new parity is the point); the -W error::DeprecationWarning
# CI invocation must not fail them.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")



# --------------------------------------------------- cost-model layer

def test_gemm_problem_per_operand_dtypes_and_compat():
    p = GemmProblem(16, 4096, 4096, "bfloat16", "bfloat16", "float32",
                    "int8")
    assert p.mixed
    assert p.a_dtype == "bfloat16" and p.b_dtype == "int8"
    assert p.in_dtype == "bfloat16"          # compat property = A dtype
    assert p.a_bytes == 16 * 4096 * 2
    assert p.b_bytes == 4096 * 4096          # one byte/element
    # b_dtype=None means uniform precision (old constructor semantics)
    u = GemmProblem(64, 64, 64, "int8", "int8", "int32")
    assert u.b_dtype == "int8" and not u.mixed


def test_vmem_footprint_bills_b_at_its_own_width():
    p16 = GemmProblem(128, 2048, 2048, "bfloat16", "bfloat16")
    p8 = GemmProblem(128, 2048, 2048, "bfloat16", "bfloat16",
                     "float32", "int8")
    for strategy in ("aie", "tb"):
        t = TileConfig(128, 512, 512, strategy)
        f16 = vmem_footprint(t, p16, TPU_V5E)
        f8 = vmem_footprint(t, p8, TPU_V5E)
        assert f8.b_bytes * 2 == f16.b_bytes
        assert f8.a_bytes == f16.a_bytes
        assert f8.scale_bytes > 0            # fused scale-vector block


def test_int8_b_roughly_doubles_feasible_bk():
    """The DSE's capacity constraint admits ~2x deeper k-blocks when B
    streams at one byte/element (the fused-dequant win).  A tight budget
    fraction makes the constraint binding at candidate-grid sizes."""
    m, k, n = 16, 8192, 8192
    budget = 0.01                             # ~1.3 MiB: B-block bound

    def max_bk(b_dtype):
        best = 0
        for bk in (128, 256, 512, 1024, 2048):
            t = TileConfig(16, bk, 512, "aie")
            p = GemmProblem(m, k, n, "bfloat16", "bfloat16", "float32",
                            b_dtype)
            if fits_vmem(t, p, TPU_V5E, budget):
                best = bk
        return best

    assert max_bk("int8") == 2 * max_bk("bfloat16") > 0


def test_w8a16_decode_traffic_under_60_percent():
    """Acceptance criterion: decode-shaped W8A16 (m=16, k=n=4096) HBM
    traffic <= 60% of the bf16-weights design."""
    t8 = dse.best_tile(16, 4096, 4096, "bfloat16", b_dtype="int8")
    t16 = dse.best_tile(16, 4096, 4096, "bfloat16")
    p8 = GemmProblem(16, 4096, 4096, "bfloat16", "bfloat16", "float32",
                     "int8")
    p16 = GemmProblem(16, 4096, 4096, "bfloat16", "bfloat16")
    hbm8 = estimate(t8, p8, TPU_V5E).hbm_bytes
    hbm16 = estimate(t16, p16, TPU_V5E).hbm_bytes
    assert hbm8 <= 0.6 * hbm16, (hbm8, hbm16)


def test_w8a16_compute_peak_is_bf16_w8a8_is_int8():
    t = TileConfig(128, 512, 512, "aie")
    mixed = estimate(t, GemmProblem(128, 4096, 4096, "bfloat16",
                                    "bfloat16", "float32", "int8"))
    both8 = estimate(t, GemmProblem(128, 4096, 4096, "int8", "int32",
                                    "int32"))
    # same padded flops; int8 x int8 runs at 2x the MXU rate
    assert mixed.t_compute == pytest.approx(2 * both8.t_compute)


def test_gemm_int8_cost_model_bills_int32_output():
    """Satellite fix: the gemm_int8 DSE query must bill C at 4 bytes
    (the kernel writes the int32 accumulator)."""
    p = GemmProblem(512, 512, 512, "int8", "int32", "int32")
    for d in dse.solve(p, top=3):
        # real footprint of the tile the DSE scored, re-billed at the
        # int32 output the kernel writes, stays within budget
        assert fits_vmem(d.tile, p, TPU_V5E)
        assert d.traffic.hbm_bytes >= p.out_bytes   # 4-byte C counted
    assert p.out_bytes == 512 * 512 * 4


def test_solve_cache_distinguishes_b_dtype():
    a = dse.solve(GemmProblem(64, 1024, 1024, "bfloat16"), top=1)[0]
    b = dse.solve(GemmProblem(64, 1024, 1024, "bfloat16", "bfloat16",
                              "float32", "int8"), top=1)[0]
    assert b.traffic.hbm_bytes < a.traffic.hbm_bytes


# ------------------------------------------------------- kernel layer

@pytest.mark.parametrize("strategy", ["aie", "tb"])
@pytest.mark.parametrize("shape", [(128, 256, 256), (64, 384, 128)],
                         ids=str)
def test_fused_w8a16_matches_dequant_first(strategy, shape):
    m, k, n = shape
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    wq = quant.quantize_weight(w)
    tile = TileConfig(64, 128, 128, strategy)
    fn = gemm_aie if strategy == "aie" else gemm_tb
    got = fn(a, wq["q"], tile=tile, b_scale=wq["scale"], interpret=True)
    want = ref.gemm_ref(a, quant.dequantize_weight(wq, jnp.bfloat16),
                        out_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 5e-3, (strategy, rel)      # int8 roundtrip tolerance


@pytest.mark.parametrize("strategy", ["aie", "tb"])
def test_fused_w8a8_matches_int32_reference(strategy):
    m, k, n = 128, 256, 128
    rng = np.random.default_rng(0)
    a_q, _ = ref.quantize_int8(
        jnp.asarray(rng.standard_normal((m, k)), jnp.float32), axis=-1)
    wq = quant.quantize_weight(
        jnp.asarray(rng.standard_normal((k, n)), jnp.float32))
    tile = TileConfig(64, 128, 128, strategy)
    fn = gemm_aie if strategy == "aie" else gemm_tb
    got = fn(a_q, wq["q"], tile=tile, b_scale=wq["scale"],
             interpret=True)
    want = ref.gemm_fused_ref(a_q, wq["q"], wq["scale"])
    # int32 accumulation + one fp32 scale multiply: bitwise equal
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ops_gemm_quant_struct_interpret_matches_ref(monkeypatch):
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 24, 192),
                          jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (192, 320), jnp.float32)
    wq = quant.quantize_weight(w)
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    got = ops.gemm(a, wq)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    want = ops.gemm(a, wq)
    assert got.shape == (4, 24, 320)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=1e-2)


@pytest.mark.parametrize("strategy", ["aie", "tb"])
def test_ops_gemm_fused_strategies_interpret(monkeypatch, strategy):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 256), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    wq = quant.quantize_weight(w)
    got = ops.gemm(a, wq, strategy=strategy)
    want = a.astype(jnp.float32) @ quant.dequantize_weight(
        wq, jnp.float32)
    rel = float(jnp.linalg.norm(got.astype(jnp.float32) - want)
                / jnp.linalg.norm(want))
    assert rel < 2e-2, (strategy, rel)


def test_ops_gemm_stacked_scan_leaves(monkeypatch):
    """Fused path under jax.lax.scan over a stacked (L, k, n) quantized
    leaf — how scanned model blocks consume per-layer weight slices."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    L, k, n = 3, 192, 128
    w = jax.random.normal(jax.random.PRNGKey(0), (L, k, n), jnp.float32)
    wq = quant.quantize_weight(w)                # (L,k,n) q, (L,1,n) scale
    assert wq["q"].shape == (L, k, n)
    assert wq["scale"].shape == (L, 1, n)
    x0 = jax.random.normal(jax.random.PRNGKey(1), (8, k), jnp.bfloat16)

    def body(x, layer):
        y = ops.gemm(x, layer, out_dtype=jnp.float32)
        return x, y

    _, ys = jax.lax.scan(body, x0, wq)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    _, want = jax.lax.scan(body, x0, wq)
    np.testing.assert_allclose(np.asarray(ys, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=1e-2)


def test_fused_grad_dequantizes_only_in_backward():
    """d/dA of the fused path == d/dA against the dequantized weight."""
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 32), jnp.float32)
    wq = quant.quantize_weight(w)
    wd = quant.dequantize_weight(wq, jnp.float32)
    ga = jax.grad(lambda x: jnp.sum(ops.gemm(x, wq) ** 2))(a)
    want = jax.grad(lambda x: jnp.sum((x @ wd) ** 2))(a)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# --------------------------------------------------------- W8A8 mode

def test_w8a8_activation_mode(monkeypatch):
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 64), jnp.float32)
    wq = quant.quantize_weight(w)
    assert quant.activation_mode() == "none"
    quant.set_activation_mode("w8a8")
    try:
        got = ops.gemm(a, wq)
    finally:
        quant.set_activation_mode("none")
    want = a @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.03                       # W8A8 quantization error
    with pytest.raises(ValueError):
        quant.set_activation_mode("int4")


def test_w8a8_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_W8A8", "1")
    assert quant.activation_mode() == "w8a8"
    monkeypatch.setenv("REPRO_W8A8", "0")
    assert quant.activation_mode() == "none"
    monkeypatch.setenv("REPRO_W8A8", "false")   # strict: not "truthy"
    assert quant.activation_mode() == "none"
    monkeypatch.setenv("REPRO_W8A8", "yes")
    with pytest.raises(ValueError):
        quant.activation_mode()


# --------------------------------------------------- serve reporting

def test_gemm_weight_bytes_halves_under_int8():
    from repro.configs.base import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("minitron-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    dense = quant.gemm_weight_bytes(params)
    qparams, n = quant.quantize_params(params)
    fused = quant.gemm_weight_bytes(qparams)
    assert n > 0 and dense > 0
    # int8 q + f32 scale vs 2-byte (or wider) dense leaves
    assert fused < 0.6 * dense, (fused, dense)
