"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned architecture: one forward/train step asserting output
shapes + no NaNs, and — the strong cache-correctness check — prefill +
decode logits must match the full-sequence forward bit-for-bit-ish
(float32 smoke configs, tol 1e-4).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.data.pipeline import DataConfig, make_batch
from repro.models import transformer as T

BATCH, SEQ = 2, 32


def _setup(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    data = DataConfig(seq_len=SEQ + (cfg.prefix_tokens or 0),
                      global_batch=BATCH, seed=1)
    batch = make_batch(cfg, data, step=0)
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_loss(arch):
    cfg, params, batch = _setup(arch)
    loss, metrics = T.loss_fn(params, cfg, batch, n_chunks=2)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    h, aux = T.forward(params, cfg, batch["tokens"],
                       prefix_embeds=batch.get("prefix_embeds"),
                       frames=batch.get("frames"))
    s_expect = batch["tokens"].shape[1] + (cfg.prefix_tokens or 0)
    assert h.shape == (BATCH, s_expect, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch):
    cfg, params, batch = _setup(arch)

    def loss(p):
        return T.loss_fn(p, cfg, batch, n_chunks=1)[0]

    grads = jax.grad(loss)(params)
    flat, _ = jax.tree.flatten(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32))))
               for g in flat), arch
    # at least one nonzero grad per major component
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in flat)
    assert total > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "internvl2-76b"])
def test_prefill_plus_decode_matches_forward(arch):
    """Cache correctness: logits(prefill(t[:-1]) -> decode(t[-1])) must
    equal last-position logits of forward(t)."""
    cfg, params, batch = _setup(arch)
    tokens = batch["tokens"]
    frames = batch.get("frames")

    # ground truth: full forward
    h, _ = T.forward(params, cfg, tokens, frames=frames, remat=False)
    want = h[:, -1] @ params["lm_head"]

    cache = T.init_cache(cfg, BATCH, max_len=SEQ + 8)
    _, cache = T.prefill(params, cfg, tokens[:, :-1], cache, frames=frames)
    got, cache = T.decode_step(params, cfg, tokens[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)
    # per-slot (b,) position vector: every slot sits at SEQ after prefill
    assert cache["pos"].shape == (BATCH,)
    assert np.all(np.asarray(cache["pos"]) == SEQ)


def test_vlm_prefix_loss_masks_prefix():
    cfg, params, batch = _setup("internvl2-76b")
    assert batch["prefix_embeds"].shape == (BATCH, cfg.prefix_tokens,
                                            cfg.d_model)
    loss, _ = T.loss_fn(params, cfg, batch, n_chunks=2)
    assert np.isfinite(float(loss))


def test_swa_ring_buffer_cache_is_bounded():
    """h2o-danube (SWA): decode caches hold `window` slots, not seq_len —
    the property that makes long_500k feasible."""
    cfg = get_smoke_config("h2o-danube-3-4b")
    cache = T.init_cache(cfg, batch=1, max_len=10_000)
    k = cache["layers"]["u0"]["k"]
    assert k.shape[2] == cfg.window  # (repeats, batch, window, ...)


def test_swa_ring_decode_matches_full_cache():
    """Windowed ring decode (cache = window slots) must equal decode with
    an unbounded cache once past the window boundary."""
    cfg = get_smoke_config("h2o-danube-3-4b")          # window = 32
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    total = 48                                          # crosses window
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, total), 0,
                              cfg.vocab)
    h, _ = T.forward(params, cfg, toks, remat=False)
    want = h[:, -1] @ params["lm_head"]

    cache = T.init_cache(cfg, 1, max_len=total)         # ring (win < total)
    _, cache = T.prefill(params, cfg, toks[:, :-1], cache)
    got, _ = T.decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_multi_step_decode_consistency():
    """Decode N tokens one-by-one == forward of the whole sequence
    (dense arch)."""
    cfg = get_smoke_config("minitron-8b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    total = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, total), 0,
                              cfg.vocab)
    h, _ = T.forward(params, cfg, toks, remat=False)
    want = jnp.einsum("bsd,dv->bsv", h, params["lm_head"])

    cache = T.init_cache(cfg, 1, max_len=total + 4)
    step = jax.jit(lambda tok, c: T.decode_step(params, cfg, tok, c))
    for t in range(total):
        got, cache = step(toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(want[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3, err_msg=f"t={t}")


def test_param_counts_match_published_sizes():
    """The config algebra must land near the published parameter counts
    (the 6*N*D roofline depends on it)."""
    expected = {
        "minitron-8b": (8.0e9, 0.3),
        "deepseek-67b": (67e9, 0.1),
        "smollm-360m": (360e6, 0.3),
        "h2o-danube-3-4b": (4.0e9, 0.3),
        "kimi-k2-1t-a32b": (1.0e12, 0.1),
        "qwen3-moe-235b-a22b": (235e9, 0.1),
        "mamba2-370m": (370e6, 0.3),
        "recurrentgemma-9b": (9.0e9, 0.3),
        "internvl2-76b": (70e9, 0.15),     # LLM backbone of the 76B VLM
    }
    for arch, (want, tol) in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < tol, (arch, got, want)


def test_active_params_moe():
    kimi = get_config("kimi-k2-1t-a32b")
    active = kimi.param_count(active_only=True)
    assert abs(active - 32e9) / 32e9 < 0.25, active
    assert active < kimi.param_count() / 10
