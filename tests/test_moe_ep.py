"""shard_map EP MoE vs the dense oracle on a real multi-device mesh.

Needs >1 device, so the mesh runs in a subprocess with
``--xla_force_host_platform_device_count`` (the parent process must keep
its single-device view for the rest of the suite).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.dist import sharding as shd
import repro.models.moe as M

mesh = shd.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
E, d, f, k = 8, 32, 64, 2
p = M.init_moe(key, d, f, E, jnp.float32)
x = jax.random.normal(key, (4, 8, d), jnp.float32)

dense = M.moe_ffn_dense_ref(p, x, top_k=k)
with shd.use_mesh(mesh):
    y, aux = jax.jit(
        lambda p, x: M.moe_ffn(p, x, top_k=k, capacity_factor=16.0))(p, x)
err = float(jnp.max(jnp.abs(y - dense)))
assert err < 1e-5, f"fwd err {err}"
assert float(aux) > 0

def loss_ep(p, x):
    y, aux = M.moe_ffn(p, x, top_k=k, capacity_factor=16.0)
    return jnp.sum(y ** 2)

def loss_dense(p, x):
    return jnp.sum(M.moe_ffn_dense_ref(p, x, top_k=k) ** 2)

with shd.use_mesh(mesh):
    g1 = jax.jit(jax.grad(loss_ep))(p, x)
g2 = jax.grad(loss_dense)(p, x)
for kk in g1:
    e = float(jnp.max(jnp.abs(g1[kk] - g2[kk])))
    assert e < 1e-4, (kk, e)
print("EP-OK")
"""

_SCRIPT_QUANT = r"""
import jax, jax.numpy as jnp
from repro.dist import sharding as shd
from repro import quant
import repro.models.moe as M

mesh = shd.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
E, d, f, k = 8, 32, 64, 2
p = M.init_moe(key, d, f, E, jnp.float32)
qp = dict(p)
for name in ("w_gate", "w_up", "w_down"):
    qp[name] = quant.quantize_weight(p[name])
x = jax.random.normal(key, (4, 8, d), jnp.float32)

# oracle dequantizes up front; the EP grouped path dequantizes each
# int8 expert panel in-register — same math, einsum-path tolerance
dense = M.moe_ffn_dense_ref(qp, x, top_k=k)
with shd.use_mesh(mesh):
    y, aux = jax.jit(
        lambda p, x: M.moe_ffn(p, x, top_k=k, capacity_factor=16.0))(qp, x)
err = float(jnp.max(jnp.abs(y - dense)))
assert err < 1e-4, f"fwd err {err}"
assert float(aux) > 0
print("EP-QUANT-OK")
"""


def _run_on_mesh(script: str, devices: str = "8"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, env=env,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))),
                          timeout=600)


@pytest.mark.parametrize("devices", ["8"])
def test_ep_matches_dense_oracle_on_mesh(devices):
    r = _run_on_mesh(_SCRIPT, devices)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP-OK" in r.stdout


def test_ep_quantized_expert_banks_on_mesh():
    """W8A16 expert banks through the EP grouped path: the stacked
    int8 {q, scale} structs shard and all_to_all like the bf16 banks,
    and the grouped kernel's in-register dequant matches the
    dequantize-up-front oracle."""
    r = _run_on_mesh(_SCRIPT_QUANT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP-QUANT-OK" in r.stdout
