"""Per-kernel correctness sweeps: Pallas (interpret=True) vs pure-jnp
oracles, across shapes / dtypes / strategies, plus gradient checks for the
custom-VJP gemm wrapper."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiling import TileConfig
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.gemm_aie import gemm_aie
from repro.kernels.gemm_tb import gemm_tb

# These suites exercise the deprecated legacy entrypoints on purpose
# (old-vs-new parity is the point); the -W error::DeprecationWarning
# CI invocation must not fail them.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")



def _rand(key, shape, dtype):
    if dtype == jnp.int8:
        return jax.random.randint(key, shape, -127, 128, jnp.int32) \
            .astype(jnp.int8)
    return jax.random.normal(key, shape, dtype)


GEMM_SHAPES = [
    (256, 256, 256),
    (384, 512, 640),        # multi-block every dim
    (128, 1024, 256),
    (8, 256, 128),          # skinny decode-like M
]
DTYPES = [jnp.float32, jnp.bfloat16, jnp.int8]
TILES = [TileConfig(128, 128, 128, "aie"), TileConfig(128, 256, 128, "aie"),
         TileConfig(128, 128, 128, "tb"), TileConfig(128, 256, 256, "tb")]


@pytest.mark.parametrize("shape", GEMM_SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
@pytest.mark.parametrize("tile", TILES,
                         ids=lambda t: f"{t.strategy}-{t.bm}x{t.bk}x{t.bn}")
def test_gemm_kernels_match_oracle(shape, dtype, tile):
    m, k, n = shape
    if m < tile.bm and tile.bm > 128:
        pytest.skip("tile larger than problem")
    key = jax.random.PRNGKey(0)
    a = _rand(key, (m, k), dtype)
    b = _rand(jax.random.PRNGKey(1), (k, n), dtype)

    # pad to tile multiples, run the kernel, slice back (what ops.py does)
    mp = -(-m // tile.bm) * tile.bm
    kp = -(-k // tile.bk) * tile.bk
    np_ = -(-n // tile.bn) * tile.bn
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    fn = gemm_aie if tile.strategy == "aie" else gemm_tb
    got = fn(ap, bp, tile=tile, interpret=True)[:m, :n]

    want = ref.gemm_ref(a, b)
    assert got.dtype == want.dtype
    if dtype == jnp.int8:
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        rtol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=rtol, atol=1e-3)


def test_ops_gemm_interpret_matches_ref(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    a = jax.random.normal(jax.random.PRNGKey(0), (4, 96, 192), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (192, 320), jnp.bfloat16)
    got = ops.gemm(a, w)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    want = ops.gemm(a, w)
    assert got.shape == (4, 96, 320)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=1e-2)


def test_ops_gemm_grad_matches_jnp():
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 48), jnp.float32)

    def loss_ops(a, w):
        return jnp.sum(ops.gemm(a, w) ** 2)

    def loss_jnp(a, w):
        return jnp.sum((a @ w) ** 2)

    ga, gw = jax.grad(loss_ops, (0, 1))(a, w)
    ga2, gw2 = jax.grad(loss_jnp, (0, 1))(a, w)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw2), rtol=1e-4)


def test_gemm_int8_quantized_path():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 128), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 96), jnp.float32)
    xq, xs = ops.quantize_int8(x, axis=-1)          # (64,1) scales
    wq, ws = ops.quantize_int8(w, axis=0)           # (1,96) scales
    got = ops.gemm_int8(xq, wq, xs, ws)
    want = x @ w
    # int8 W8A8 quantization error ~1% relative on random gaussians
    err = np.linalg.norm(np.asarray(got - want)) / np.linalg.norm(
        np.asarray(want))
    assert err < 0.03


ATTN_CASES = [
    # (b, sq, skv, hq, hkv, d, causal, window)
    (1, 256, 256, 4, 4, 64, True, 0),
    (2, 256, 256, 8, 2, 64, True, 0),          # GQA
    (1, 128, 384, 4, 2, 64, True, 0),          # cross-block kv, q_offset
    (1, 256, 256, 4, 1, 96, True, 128),        # SWA + non-128 head dim
    (1, 192, 192, 2, 2, 64, False, 0),         # non-causal (encoder)
    (1, 256, 256, 4, 4, 128, True, 64),        # tight window
]


@pytest.mark.parametrize("case", ATTN_CASES, ids=str)
def test_flash_attention_matches_ref(case):
    b, sq, skv, hq, hkv, d, causal, window = case
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(kk, (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(kv_, (b, skv, hkv, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          bq=128, bkv=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 4, 64),
                          jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64),
                          jnp.bfloat16)
    got = flash_attention(q, k, v, bq=128, bkv=128, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_attention_ref_window_equals_full_when_window_ge_seq():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 64, 2, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 32))
    full = ref.attention_ref(q, k, v, causal=True, window=0)
    wide = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(wide),
                               rtol=1e-6)
