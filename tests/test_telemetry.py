"""Telemetry tests: span nesting + attribute propagation, counter and
gauge registries, the disabled-mode no-op contract (shared singletons,
zero allocation), JSONL / Chrome-trace export round-trips with
schema-checked keys, modeled-traffic fields on plan/execute events, the
serve-engine request lifecycle over the shared acceptance trace, and the
repo-wide stray-print gate (telemetry is the sanctioned channel for
structured output from library code; ``print`` belongs to launch/)."""

import ast
import json
import pathlib
import time

import jax
import numpy as np
import pytest

from repro import ops, telemetry
from repro.telemetry import TRACK_TID_BASE, Recorder
from repro.telemetry import report as treport

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


@pytest.fixture
def rec():
    """Fresh recorder for the test; always uninstalled afterwards so
    the suite's default stays disabled-mode."""
    r = telemetry.enable(Recorder())
    yield r
    telemetry.disable()


@pytest.fixture(autouse=True)
def _always_disabled_after():
    yield
    telemetry.disable()


# ---------------------------------------------------------------- spans

def test_span_nesting_and_attrs(rec):
    with telemetry.span("outer", a=1) as outer:
        with telemetry.span("inner") as inner:
            inner.set(b=2)
        assert inner.parent == outer.sid
        assert inner.depth == outer.depth + 1
    spans = {e["name"]: e for e in rec.events if e["type"] == "span"}
    assert set(spans) == {"outer", "inner"}
    # children close (and are emitted) before their parents
    assert rec.events[0]["name"] == "inner"
    assert spans["outer"]["attrs"] == {"a": 1}
    assert spans["inner"]["attrs"] == {"b": 2}
    assert spans["inner"]["parent"] == spans["outer"]["sid"]
    assert spans["inner"]["depth"] == 1 and spans["outer"]["depth"] == 0
    # the inner interval nests inside the outer one
    assert spans["inner"]["ts"] >= spans["outer"]["ts"]
    assert spans["inner"]["dur"] <= spans["outer"]["dur"]


def test_span_sync_blocks_device_work(rec):
    x = jax.numpy.ones((128, 128))
    with telemetry.span("gemm") as sp:
        y = sp.sync(jax.jit(lambda a: a @ a)(x))
    assert float(y[0, 0]) == 128.0
    (ev,) = [e for e in rec.events if e["type"] == "span"]
    assert ev["dur"] > 0


def test_span_stack_survives_exception(rec):
    with pytest.raises(RuntimeError):
        with telemetry.span("outer"):
            with telemetry.span("inner"):
                raise RuntimeError("boom")
    with telemetry.span("after") as sp:
        pass
    assert sp.depth == 0 and sp.parent is None


def test_complete_span_gets_request_track(rec):
    t = time.perf_counter()
    telemetry.complete_span("serve.request", t, t + 0.5, tid=3, rid=3)
    (ev,) = rec.events
    assert ev["tid"] == TRACK_TID_BASE + 3
    assert abs(ev["dur"] - 0.5) < 1e-6


# ----------------------------------------------------- counters / gauges

def test_counters_and_gauges(rec):
    telemetry.counter("tok").add(3)
    telemetry.counter("tok").add()
    assert telemetry.counter("tok") is rec.counter("tok")
    assert rec.counter("tok").value == 4

    g = telemetry.gauge("slots")
    g.set(2)
    g.set(2)          # unchanged -> no new timeline sample
    g.set(1)
    samples = [e for e in rec.events if e["type"] == "gauge"]
    assert [s["value"] for s in samples] == [2.0, 1.0]

    snap = rec.snapshot()
    assert snap["counters"]["tok"] == 4
    assert snap["gauges"]["slots"] == 1.0
    assert "plan_cache" in snap and "entries" in snap["plan_cache"]


# ------------------------------------------------------- disabled mode

def test_disabled_mode_is_allocation_free_noop():
    assert telemetry.recorder() is None and not telemetry.enabled()
    # shared stateless singletons: every call returns the SAME object,
    # so the disabled hot path allocates nothing
    assert telemetry.span("a", x=1) is telemetry.span("b")
    assert telemetry.counter("a") is telemetry.counter("b")
    assert telemetry.gauge("a") is telemetry.gauge("b")
    with telemetry.span("a") as sp:
        v = sp.sync(42)            # passthrough
    assert v == 42 and sp.set(k=1) is sp
    telemetry.counter("a").add(5)
    telemetry.gauge("a").set(5)
    telemetry.event("a", x=1)
    telemetry.complete_span("a", 0.0, 1.0)
    assert telemetry.snapshot() is None
    assert telemetry.export("/nonexistent/should-not-write") is None


# -------------------------------------------------------------- exports

def test_jsonl_roundtrip_schema(rec, tmp_path):
    with telemetry.span("work", n=1):
        telemetry.event("mark", k="v")
    telemetry.gauge("g").set(7)
    path = rec.export_jsonl(str(tmp_path / "t.jsonl"))
    lines = [json.loads(l) for l in open(path)]
    meta, events = lines[0], lines[1:]
    assert meta["type"] == "meta"
    assert meta["schema_version"] == telemetry.SCHEMA_VERSION
    assert {"counters", "gauges", "plan_cache",
            "n_events"} <= set(meta["snapshot"])
    assert len(events) == len(rec.events)
    for ev in events:
        assert {"type", "name", "ts"} <= set(ev)
        if ev["type"] == "span":
            assert {"dur", "sid", "depth", "tid", "attrs"} <= set(ev)
        elif ev["type"] == "gauge":
            assert "value" in ev


def test_chrome_trace_roundtrip(rec, tmp_path):
    with telemetry.span("work"):
        telemetry.event("mark")
    telemetry.gauge("g").set(7)
    telemetry.complete_span("serve.request", 0.0, 0.1, tid=0)
    base = str(tmp_path / "t")
    jsonl_path, trace_path = rec.export(base)
    assert jsonl_path.endswith(".jsonl")
    trace = json.loads(open(trace_path).read())
    assert "traceEvents" in trace
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert {"M", "X", "i", "C"} <= phases
    for e in trace["traceEvents"]:
        assert {"ph", "name", "pid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0 and "ts" in e and "tid" in e
    # the explicit-tid request span got its own named track
    names = [e for e in trace["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"]
    assert any(e["args"]["name"] == "request 0" for e in names)


# ----------------------------------------------- kernel plan/execute

def test_plan_events_carry_modeled_traffic(rec):
    ops.plan_cache_clear()
    spec = ops.GemmSpec()
    ops.plan(spec, (64, 256, 128))
    ops.plan(spec, (64, 256, 128))          # cache hit
    plans = [e for e in rec.events if e["name"] == "gemm.plan"]
    assert [p["attrs"]["cache"] for p in plans] == ["miss", "hit"]
    for p in plans:
        a = p["attrs"]
        assert {"spec", "strategy", "tile", "hbm_bytes", "vmem_bytes",
                "flops", "t_model_us", "bound"} <= set(a)
        assert a["hbm_bytes"] > 0 and a["flops"] == 2 * 64 * 256 * 128
    assert rec.counter("gemm.plan_cache.miss").value == 1
    assert rec.counter("gemm.plan_cache.hit").value == 1


def test_execute_event_once_per_spec_shape(rec):
    ops.plan_cache_clear()
    x = jax.numpy.ones((16, 64), jax.numpy.bfloat16)
    w = jax.numpy.ones((64, 32), jax.numpy.bfloat16)
    for _ in range(3):
        ops.gemm(x, w)
    execs = [e for e in rec.events if e["name"] == "gemm.execute"]
    assert len(execs) == 1                   # deduped first-trace event
    a = execs[0]["attrs"]
    assert {"spec", "m", "k", "n", "strategy", "mode",
            "hbm_bytes", "flops"} <= set(a)
    assert (a["m"], a["k"], a["n"]) == (16, 64, 32)
    ops.plan_cache_clear()                   # clears the dedup set too
    ops.gemm(x, w)
    execs = [e for e in rec.events if e["name"] == "gemm.execute"]
    assert len(execs) == 2


def test_model_vs_measured_report(rec):
    ops.plan_cache_clear()
    pl = ops.plan(ops.GemmSpec(), (16, 128, 128))
    rows = treport.model_vs_measured([pl], iters=2)
    (r,) = rows
    assert r["t_measured_us"] > 0 and r["t_model_us"] > 0
    # achieved is rounded for display, so compare loosely
    assert r["achieved"] == pytest.approx(
        r["t_model_us"] / r["t_measured_us"], rel=5e-2)
    assert "measured" in treport.render(rows)
    s = treport.summarize(rows)
    assert s["n_measured"] == 1 and s["n_skipped"] == 0
    # over-budget plans are skipped EXPLICITLY, never silently
    rows = treport.model_vs_measured([pl], max_flops=1)
    assert rows[0]["t_measured_us"] is None
    assert "flops budget" in rows[0]["note"]


# ------------------------------------------------- serve lifecycle

def test_serve_lifecycle_events(rec):
    from repro.configs.base import get_smoke_config
    from repro.models import transformer as T
    from repro.serve.engine import (ACCEPTANCE_TRACE, DecodeEngine,
                                    acceptance_requests)

    cfg = get_smoke_config("smollm-360m")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    max_len = max(p + t for p, t in ACCEPTANCE_TRACE) + 1
    engine = DecodeEngine(params, cfg, batch=2, max_len=max_len)
    reqs = acceptance_requests(cfg.vocab)
    results = {r.rid: r for r in engine.run(reqs)}

    events = [e for e in rec.events if e["type"] == "event"]
    for req in reqs:
        order = [e["name"] for e in events
                 if e["attrs"].get("rid") == req.rid]
        assert order == ["serve.request.queued",
                         "serve.request.admitted",
                         "serve.request.finished"]
        fin = next(e for e in events
                   if e["name"] == "serve.request.finished"
                   and e["attrs"]["rid"] == req.rid)
        assert fin["attrs"]["ttft"] > 0
        assert fin["attrs"]["n_tokens"] == results[req.rid].n_tokens
        # each request got its own lifecycle track with phase spans
        track = [e for e in rec.events if e["type"] == "span"
                 and e["tid"] == TRACK_TID_BASE + req.rid]
        names = {e["name"] for e in track}
        assert {"serve.request", "serve.request.prefill",
                "serve.request.decode"} <= names
        life = next(e for e in track if e["name"] == "serve.request")
        assert life["attrs"]["ttft"] == pytest.approx(
            results[req.rid].ttft, abs=1e-6)
    # engine results surface the same latency split
    for r in results.values():
        assert r.ttft > 0 and r.queue_wait >= 0
    assert rec.counter("serve.completed").value == len(reqs)
    assert rec.counter("serve.generated_tokens").value == \
        sum(r.n_tokens for r in results.values())
    # slot-occupancy gauge recorded a timeline (and ended drained)
    occ = [e for e in rec.events if e["type"] == "gauge"
           and e["name"] == "serve.slots_active"]
    assert occ and occ[-1]["value"] == 0.0


# ---------------------------------------------------- repo-wide gate

def test_no_stray_prints_in_library_code():
    """``print`` is the launch/ drivers' UI; library code must report
    through telemetry (or return values).  AST-based so docstrings and
    comments mentioning print don't false-positive."""
    offenders = []
    for path in SRC.rglob("*.py"):
        if "launch" in path.relative_to(SRC).parts:
            continue
        tree = ast.parse(path.read_text())
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id == "print"):
                offenders.append(f"{path.relative_to(SRC)}:{node.lineno}")
    assert not offenders, f"print() outside launch/: {offenders}"
