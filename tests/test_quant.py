"""Weight-only int8 serving path: accuracy, size, end-to-end decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.configs.base import get_smoke_config
from repro.kernels import ops
from repro.models import transformer as T

# These suites exercise the deprecated legacy entrypoints on purpose
# (old-vs-new parity is the point); the -W error::DeprecationWarning
# CI invocation must not fail them.
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")



def test_quantize_weight_roundtrip_error():
    w = jax.random.normal(jax.random.PRNGKey(0), (128, 64), jnp.float32)
    wq = quant.quantize_weight(w)
    back = quant.dequantize_weight(wq, jnp.float32)
    # per-channel symmetric: elementwise error <= scale/2
    assert float(jnp.max(jnp.abs(back - w) / wq["scale"])) <= 0.5 + 1e-6


def test_gemm_accepts_quantized_struct():
    a = jax.random.normal(jax.random.PRNGKey(1), (16, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32), jnp.float32)
    want = ops.gemm(a, w)
    got = ops.gemm(a, quant.quantize_weight(w))
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.02


@pytest.mark.parametrize("arch", ["minitron-8b", "recurrentgemma-9b",
                                  "mamba2-370m"])
def test_quantized_decode_close_to_fp(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    qparams, n = quant.quantize_params(params)
    assert n > 0
    assert quant.param_bytes(qparams) < 0.75 * quant.param_bytes(params)

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab)
    cache_f = T.init_cache(cfg, 2, 24)
    cache_q = T.init_cache(cfg, 2, 24)
    lf, cache_f = T.prefill(params, cfg, toks, cache_f)
    lq, cache_q = T.prefill(qparams, cfg, toks, cache_q)
    # logits track closely; argmax agreement on most rows
    rel = float(jnp.linalg.norm(lq - lf) / jnp.linalg.norm(lf))
    assert rel < 0.1, (arch, rel)
    tok = jnp.argmax(lq, -1)[:, None].astype(jnp.int32)
    lq2, _ = T.decode_step(qparams, cfg, tok, cache_q)
    assert bool(jnp.all(jnp.isfinite(lq2)))


def test_layout_specs_survive_quantized_tree():
    from repro.dist import layout
    from tests.test_layout import MESH
    from jax.sharding import PartitionSpec as P
    cfg = get_smoke_config("minitron-8b")
    params = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    qparams, _ = quant.quantize_params(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params))
    specs = layout.param_specs(qparams, get_smoke_config("minitron-8b"),
                               MESH, "tp")
    flat_p = jax.tree.leaves(qparams)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape)


def test_bf16_reduce_flag_numerics(monkeypatch):
    """REPRO_BF16_REDUCE=1 (the cross-shard bf16-reduction experiment)
    must stay within bf16 tolerance of the fp32-accumulated path."""
    monkeypatch.setenv("REPRO_BF16_REDUCE", "1")
    a = jax.random.normal(jax.random.PRNGKey(3), (64, 256), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(4), (256, 32), jnp.bfloat16)
    got = ops.gemm(a, w)
    monkeypatch.delenv("REPRO_BF16_REDUCE")
    want = ops.gemm(a, w)
    rel = float(jnp.linalg.norm((got - want).astype(jnp.float32))
                / jnp.linalg.norm(want.astype(jnp.float32)))
    assert rel < 0.05
