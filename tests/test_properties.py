"""Property-based tests (hypothesis) on the system's invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dev dep (pip install "
                    "-e .[dev]); skip property tests without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dse, hlo_cost
from repro.core.hardware import TPU_V5E
from repro.core.memory_model import vmem_footprint
from repro.core.tiling import GemmProblem, TileConfig
from repro.kernels import ops, ref

SET = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------- tiling DSE

@given(m=st.integers(1, 8192), k=st.integers(1, 8192),
       n=st.integers(1, 8192),
       dt=st.sampled_from(["bfloat16", "int8", "float32"]))
@settings(**SET)
def test_dse_always_feasible_and_aligned(m, k, n, dt):
    p = GemmProblem(m, k, n, dt, dt)
    designs = dse.solve(p, top=3)
    assert designs
    for d in designs:
        assert d.tile.mxu_aligned(TPU_V5E)
        assert d.vmem_bytes <= 0.75 * TPU_V5E.vmem_bytes
        # traffic model sanity: at least compulsory traffic, and padded
        # flops at least the logical flops
        assert d.traffic.hbm_bytes >= p.out_bytes
        assert d.traffic.flops >= p.flops


@given(m=st.integers(1, 64), k=st.integers(1, 8192),
       n=st.integers(1, 8192),
       a_dt=st.sampled_from(["bfloat16", "float32", "int8"]),
       strategy=st.sampled_from(["aie", "tb"]))
@settings(**SET)
def test_dse_mixed_dtype_feasible_for_decode_shapes(m, k, n, a_dt,
                                                    strategy):
    """Mixed-precision solve (int8 B stream) always returns a feasible,
    aligned design for decode-shaped skinny-M problems, for both
    dataflow strategies, and never models MORE traffic than the same
    problem with B at A's width."""
    p = GemmProblem(m, k, n, a_dt, "bfloat16" if a_dt != "int8"
                    else "float32", "float32" if a_dt != "int8"
                    else "int32", "int8")
    # top must be deep enough that the weaker strategy still surfaces
    designs = [d for d in dse.solve(p, top=64)
               if d.tile.strategy == strategy]
    assert designs, (p, strategy)
    best = designs[0]
    assert best.tile.mxu_aligned(TPU_V5E)
    assert best.vmem_bytes <= 0.75 * TPU_V5E.vmem_bytes
    uniform = GemmProblem(m, k, n, p.a_dtype, p.out_dtype, p.acc_dtype)
    if p.a_dtype != "int8":                    # genuinely mixed
        u = [d for d in dse.solve(uniform, top=64)
             if d.tile.strategy == strategy]
        assert best.traffic.hbm_bytes <= u[0].traffic.hbm_bytes


@given(m=st.integers(1, 4096), k=st.integers(1, 4096),
       n=st.integers(1, 4096))
@settings(**SET)
def test_grid_covers_problem(m, k, n):
    p = GemmProblem(m, k, n)
    t = dse.best_tile(m, k, n)
    gm, gn, gk = t.grid(p)
    assert gm * t.bm >= m and gn * t.bn >= n and gk * t.bk >= k
    pm, pk, pn = t.padded_dims(p)
    assert 0 < t.tile_efficiency(p) <= 1.0
    assert pm * pk * pn * t.tile_efficiency(p) == pytest.approx(
        m * k * n, rel=1e-12)


@given(bm=st.sampled_from([8, 64, 256]), bk=st.sampled_from([128, 512]),
       bn=st.sampled_from([128, 512]),
       strategy=st.sampled_from(["aie", "tb"]))
@settings(**SET)
def test_vmem_footprint_monotone_in_block(bm, bk, bn, strategy):
    p = GemmProblem(4096, 4096, 4096)
    small = vmem_footprint(TileConfig(bm, bk, bn, strategy), p, TPU_V5E)
    big = vmem_footprint(TileConfig(2 * bm, bk, bn, strategy), p,
                         TPU_V5E)
    assert big.total > small.total


# ----------------------------------------------------------------- gemm

@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
       seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_gemm_matches_numpy(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    got = ops.gemm(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


@given(rows=st.integers(1, 32), cols=st.integers(1, 32),
       seed=st.integers(0, 2 ** 16))
@settings(**SET)
def test_quantize_roundtrip_bounded(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((rows, cols)), jnp.float32)
    q, scale = ops.quantize_int8(x)
    back = ops.dequantize(q, scale)
    # symmetric int8: error bounded by scale/2 elementwise
    assert float(jnp.max(jnp.abs(back - x))) <= float(
        jnp.max(scale)) / 2 + 1e-6


# ------------------------------------------------------------- attention

@given(sq=st.integers(1, 40), skv=st.integers(1, 48),
       hkv=st.sampled_from([1, 2, 3]), groups=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([0, 5, 16]), seed=st.integers(0, 999))
@settings(**SET)
def test_blocked_attention_matches_ref(sq, skv, hkv, groups, window,
                                       seed):
    if sq > skv:
        sq = skv
    rng = np.random.default_rng(seed)
    d = 16
    q = jnp.asarray(rng.standard_normal((2, sq, hkv * groups, d)),
                    jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, skv, hkv, d)), jnp.float32)
    from repro.kernels.blocked_attention import attention_blocked
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    got = attention_blocked(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(skv=st.integers(4, 64), pos_frac=st.floats(0.0, 1.0),
       window=st.sampled_from([0, 7]), seed=st.integers(0, 999))
@settings(**SET)
def test_decode_attention_xla_matches_ref(skv, pos_frac, window, seed):
    rng = np.random.default_rng(seed)
    d, hkv, g = 16, 2, 2
    q = jnp.asarray(rng.standard_normal((1, hkv * g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, skv, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, skv, hkv, d)), jnp.float32)
    pos = jnp.asarray(int(pos_frac * (skv - 1)), jnp.int32)
    want = ref.decode_attention_ref(q, k, v, pos, window=window)
    got = ops._decode_attention_xla(q, k, v, pos, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- hlo parsing

@given(dims=st.lists(st.integers(1, 64), min_size=0, max_size=4),
       dt=st.sampled_from(["f32", "bf16", "s8", "s32"]))
@settings(**SET)
def test_shape_parser(dims, dt):
    bytes_per = {"f32": 4, "bf16": 2, "s8": 1, "s32": 4}[dt]
    s = f"{dt}[{','.join(map(str, dims))}]{{{0}}}"
    numel, nbytes = hlo_cost._shape_numel_bytes(s)
    want = int(np.prod(dims)) if dims else 1
    assert numel == want
    assert nbytes == want * bytes_per


# ------------------------------------------------------------------ moe

@given(t=st.integers(2, 24), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 999))
@settings(**SET)
def test_moe_sort_dispatch_matches_dense(t, e, k, seed):
    """With ample capacity the sort-dispatch pjit path must equal the
    dense (every-expert) oracle for arbitrary token counts."""
    import repro.models.moe as M
    key = jax.random.PRNGKey(seed)
    d, f = 16, 32
    p = M.init_moe(key, d, f, e, jnp.float32)
    x = jax.random.normal(key, (1, t, d), jnp.float32)
    y, aux = M._moe_ffn_pjit(p, x, top_k=k, capacity_factor=float(e * 2))
    want = M.moe_ffn_dense_ref(p, x, top_k=k)
    np.testing.assert_allclose(y, want, rtol=2e-4, atol=2e-4)
    # aux = E*sum(f_e*p_e) is ~k at balance but can dip below 1 for tiny
    # token counts (empirical f_e is discrete); positivity is the invariant
    assert 0.0 < float(aux) < 10.0 * k


@given(t=st.integers(1, 48), e=st.sampled_from([2, 4, 8]),
       k=st.sampled_from([1, 2]), c=st.integers(1, 16),
       seed=st.integers(0, 999))
@settings(**SET)
def test_moe_sort_dispatch_invariants(t, e, k, c, seed):
    """The ragged sort-dispatch under arbitrary routing and capacity:
    tokens are conserved into unique ragged rows, drops are exactly the
    over-capacity tail of each expert, and the stable sort preserves
    source order within every expert."""
    import repro.models.moe as M
    if k > e:
        return
    rng = np.random.default_rng(seed)
    d = 8
    xe = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    # top-k routing: k distinct experts per token
    top_ids = jnp.asarray(np.stack(
        [rng.choice(e, size=k, replace=False) for _ in range(t)]),
        jnp.int32)
    dsp = M._sort_dispatch(xe, top_ids, k, e, c)
    sizes = np.asarray(dsp.sizes)
    counts = np.asarray(dsp.counts)
    dest, in_cap = np.asarray(dsp.dest), np.asarray(dsp.in_cap)
    tok, se = np.asarray(dsp.token_idx), np.asarray(dsp.sorted_e)
    xs = np.asarray(dsp.xs)

    # capacity semantics: kept rows are min(count, c), never more
    np.testing.assert_array_equal(sizes, np.minimum(counts, c))
    assert counts.sum() == t * k

    # no double-write: kept destinations are unique and exactly cover
    # the ragged row range [0, sum(sizes))
    kept = np.sort(dest[in_cap])
    np.testing.assert_array_equal(kept, np.arange(sizes.sum()))
    assert np.all(dest[~in_cap] == t * k)

    # token conservation: each kept assignment's packed row is its
    # source token, bit-for-bit; rows past the ragged total are zero
    np.testing.assert_array_equal(xs[dest[in_cap]],
                                  np.asarray(xe)[tok[in_cap]])
    assert not np.any(xs[sizes.sum():])

    # drops are exactly the over-capacity tail (stable order): within
    # every expert the first min(count, c) assignments are kept
    slot = np.asarray(dsp.slot)
    np.testing.assert_array_equal(in_cap, slot < c)
    for g in range(e):
        sel = se == g
        assert in_cap[sel].sum() == sizes[g]
        # permutation stability: source order preserved within a group
        assert np.all(np.diff(tok[sel]) > 0)


@given(t=st.integers(4, 32), seed=st.integers(0, 999))
@settings(**SET)
def test_moe_capacity_drops_zero_or_keep(t, seed):
    """GShard capacity semantics, top_k=1: under a tight capacity each
    token's output is either exactly its full-capacity output (kept) or
    exactly zero (dropped) — never a corrupted mixture."""
    import repro.models.moe as M
    key = jax.random.PRNGKey(seed)
    d, f, e = 16, 32, 4
    p = M.init_moe(key, d, f, e, jnp.float32)
    x = jax.random.normal(key, (1, t, d), jnp.float32)
    y_full, _ = M._moe_ffn_pjit(p, x, top_k=1, capacity_factor=8.0)
    y_tight, _ = M._moe_ffn_pjit(p, x, top_k=1, capacity_factor=0.5)
    yf, yt = np.asarray(y_full)[0], np.asarray(y_tight)[0]
    for i in range(t):
        kept = np.allclose(yt[i], yf[i], atol=1e-5)
        dropped = np.allclose(yt[i], 0.0, atol=1e-6)
        assert kept or dropped, i
