"""Measured autotuning: the robust timing harness, the top-K candidate
introspection, the persistent tuning cache (round-trip, cross-process
key stability, corrupt/stale fallback), and the deterministic
winner-selection loop through ``plan()`` with a monkeypatched timer —
a measured winner is selected and cached exactly once, and a "second
process" over the same file re-plans with zero re-measurement.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import ops
from repro.kernels import api
from repro.tune import autotune, cache, calibrate, measure

SHAPE = (16, 128, 128)


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Every test gets its own tuning-cache file and fresh plan/DSE
    state; autotune module switches are restored afterwards."""
    monkeypatch.setenv("REPRO_TUNE_CACHE",
                       str(tmp_path / "tune_cache.json"))
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    cache.tuning_cache_reset()
    api.plan_cache_clear()
    monkeypatch.setattr(autotune, "_enabled", None)  # unset, not off:
    monkeypatch.setattr(autotune, "_k", None)        # env/spec decide
    yield
    calibrate.clear()
    cache.tuning_cache_reset()
    api.plan_cache_clear()


# ---------------------------------------------------------------------------
# Measurement harness: median, MAD outlier rejection, spread
# ---------------------------------------------------------------------------

def test_reject_outliers_drops_gc_pause():
    times = (1.0, 1.02, 0.98, 1.01, 50.0)
    kept = measure.reject_outliers(times)
    assert 50.0 not in kept
    assert set(kept) == {1.0, 1.02, 0.98, 1.01}


def test_reject_outliers_keeps_identical_and_tiny_samples():
    assert measure.reject_outliers((2.0, 2.0, 2.0)) == (2.0, 2.0, 2.0)
    # <= 2 samples: nothing to reject against
    assert measure.reject_outliers((1.0, 9.0)) == (1.0, 9.0)


def test_reject_outliers_keeps_at_least_half():
    # bimodal: rejection may not throw away a whole mode
    times = (1.0, 1.0, 10.0, 10.0)
    assert len(measure.reject_outliers(times)) >= 2


def test_measurement_summary_properties():
    m = measure.Measurement(times_s=(1.0, 1.2, 0.8, 30.0),
                            kept_s=(1.0, 1.2, 0.8), warmup=2)
    assert m.iters == 4 and m.rejected == 1
    assert m.median_s == 1.0
    assert m.spread == pytest.approx(0.4)


def test_measure_plan_with_fake_timer_is_deterministic():
    ticks = iter(np.arange(0.0, 100.0, 0.5))
    pl = ops.plan(ops.GemmSpec(), SHAPE)
    meas = measure.measure_plan(pl, iters=3, warmup=1,
                                timer=lambda: float(next(ticks)))
    assert meas.times_s == (0.5, 0.5, 0.5)
    assert meas.median_s == 0.5 and meas.spread == 0.0
    assert meas.warmup == 1


# ---------------------------------------------------------------------------
# solve_topk introspection
# ---------------------------------------------------------------------------

def test_solve_topk_ranked_and_bounded():
    designs = api.solve_topk(ops.GemmSpec(), SHAPE, k=3)
    assert 1 <= len(designs) <= 3
    t_model = [d.traffic.t_model for d in designs]
    assert t_model == sorted(t_model)       # best first
    assert len({(d.tile.bm, d.tile.bk, d.tile.bn, d.tile.strategy)
                for d in designs}) == len(designs)


def test_solve_topk_respects_pinned_strategy():
    designs = api.solve_topk(ops.GemmSpec(strategy="tb"), SHAPE, k=4)
    assert designs and all(d.tile.strategy == "tb" for d in designs)


# ---------------------------------------------------------------------------
# Tuning cache: round-trip, key stability, corrupt/stale fallback
# ---------------------------------------------------------------------------

def test_cache_round_trip_persists_across_instances(tmp_path):
    path = str(tmp_path / "rt.json")
    c1 = cache.TuningCache(path)
    entry = {"tile": {"bm": 16, "bk": 128, "bn": 128, "strategy": "aie"},
             "t_us": 12.5, "mode": "ref"}
    c1.put("k1", entry)
    c2 = cache.TuningCache(path)            # fresh instance, same file
    got = c2.get("k1")
    assert got is not None and got["tile"] == entry["tile"]
    assert got["t_us"] == 12.5 and "created" in got
    assert c2.info() == cache.TuningCacheInfo(1, 1, 0, 0, 0)
    assert c1.info() == cache.TuningCacheInfo(1, 0, 0, 1, 0)


def test_cache_key_is_stable_across_processes():
    spec = ops.GemmSpec(b_quant=True,
                        epilogue=ops.Epilogue(activation="silu"))
    local = cache.cache_key(spec, SHAPE, "ref")
    prog = (
        "from repro import ops\n"
        "from repro.tune import cache\n"
        "spec = ops.GemmSpec(b_quant=True,"
        " epilogue=ops.Epilogue(activation='silu'))\n"
        f"print(cache.cache_key(spec, {SHAPE!r}, 'ref'))\n")
    out = subprocess.run([sys.executable, "-c", prog], text=True,
                         capture_output=True, check=True,
                         env=os.environ.copy())
    assert out.stdout.strip() == local
    assert "|16x128x128|ref" in local


def test_tune_field_never_changes_the_cache_key():
    # enabling via GemmSpec(tune=True) vs env vs module switch must all
    # join on the same persisted entry
    base = cache.cache_key(ops.GemmSpec(), SHAPE, "ref")
    assert cache.cache_key(ops.GemmSpec(tune=True), SHAPE, "ref") == base
    assert cache.cache_key(ops.GemmSpec(tune=False), SHAPE, "ref") == base


def test_corrupt_cache_warns_and_plan_survives(tmp_path, monkeypatch):
    path = str(tmp_path / "corrupt.json")
    with open(path, "w") as f:
        f.write("{not json at all")
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    cache.tuning_cache_reset()
    monkeypatch.setattr(measure, "measure_plan", _fake_measurer({}))
    with pytest.warns(UserWarning, match="unreadable"):
        pl = ops.plan(ops.GemmSpec(tune=True), SHAPE)
    assert pl.tile is not None              # analytic or measured — alive
    assert cache.tuning_cache_info().load_errors == 1


def test_stale_schema_warns_and_falls_back(tmp_path, monkeypatch):
    path = str(tmp_path / "stale.json")
    with open(path, "w") as f:
        json.dump({"schema": cache.SCHEMA_VERSION + 1,
                   "entries": {"k": {}}}, f)
    monkeypatch.setenv("REPRO_TUNE_CACHE", path)
    cache.tuning_cache_reset()
    with pytest.warns(UserWarning, match="stale"):
        assert cache.tuning_cache().get("k") is None


def test_malformed_entry_degrades_to_analytic(monkeypatch):
    c = cache.tuning_cache()
    c.put(cache.cache_key(ops.GemmSpec(), SHAPE, api._mode()),
          {"tile": "not-a-tile-dict"})
    boom = _fake_measurer({}, explode=True)
    monkeypatch.setattr(measure, "measure_plan", boom)
    pl = ops.plan(ops.GemmSpec(tune=True), SHAPE)
    # the malformed hit neither crashed nor triggered a re-measure of
    # the winner that "won" — every candidate errored, so analytic
    assert pl.source == "analytic"


# ---------------------------------------------------------------------------
# Deterministic winner selection through plan()
# ---------------------------------------------------------------------------

def _fake_measurer(times_by_tile: dict, default: float = 2e-3,
                   explode: bool = False):
    """measure_plan stand-in: wall-clock keyed by tile, call-counted."""
    def fake(pl, *, iters=3, warmup=1, rng=None, timer=None):
        fake.calls.append(pl.tile)
        if explode:
            raise RuntimeError("no measuring allowed")
        t = times_by_tile.get(
            (pl.tile.bm, pl.tile.bk, pl.tile.bn, pl.tile.strategy),
            default)
        return measure.Measurement(times_s=(t,) * iters,
                                   kept_s=(t,) * iters, warmup=warmup)
    fake.calls = []
    return fake


def test_measured_winner_selected_and_cached_exactly_once(monkeypatch):
    spec = ops.GemmSpec(tune=True)
    designs = api.solve_topk(spec, SHAPE, k=autotune.DEFAULT_K)
    assert len(designs) >= 2, "need >= 2 candidates to displace rank 0"
    # make the analytically-WORST candidate measure fastest
    target = designs[-1].tile
    fake = _fake_measurer({(target.bm, target.bk, target.bn,
                            target.strategy): 1e-3})
    monkeypatch.setattr(measure, "measure_plan", fake)

    pl = ops.plan(spec, SHAPE)
    assert pl.source == "tuned"
    assert pl.tile == target                # measured winner, not rank 0
    assert pl.tuned.from_cache is False
    assert pl.tuned.k_searched == len(designs)
    assert pl.tuned.t_measured_us == pytest.approx(1e3)
    assert pl.tuned.analytic_tile.startswith(designs[0].tile.strategy)
    assert len(fake.calls) == len(designs)  # each candidate timed once
    info = cache.tuning_cache_info()
    assert info.entries == 1 and info.measurements == 1
    assert "tuned" in pl.explain() and "measured" in pl.explain()

    # same process, same shape again: plan cache hit, no new search
    ops.plan(spec, SHAPE)
    assert len(fake.calls) == len(designs)


def test_second_process_reuses_winner_with_zero_measurements(monkeypatch):
    spec = ops.GemmSpec(tune=True)
    designs = api.solve_topk(spec, SHAPE, k=autotune.DEFAULT_K)
    target = designs[-1].tile
    fake = _fake_measurer({(target.bm, target.bk, target.bn,
                            target.strategy): 1e-3})
    monkeypatch.setattr(measure, "measure_plan", fake)
    first = ops.plan(spec, SHAPE)
    assert cache.tuning_cache_info().measurements == 1

    # "second process": in-memory caches dropped, file survives; any
    # measurement attempt now raises — persistence must make it moot
    cache.tuning_cache_reset()
    api.plan_cache_clear()
    monkeypatch.setattr(measure, "measure_plan",
                        _fake_measurer({}, explode=True))
    second = ops.plan(spec, SHAPE)
    assert second.tile == first.tile
    assert second.source == "tuned"
    assert second.tuned.from_cache is True
    info = cache.tuning_cache_info()
    assert info.hits == 1 and info.measurements == 0


def test_enablement_precedence_spec_module_env(monkeypatch):
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    assert not autotune.is_enabled(None)
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    assert autotune.is_enabled(None)
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    assert not autotune.is_enabled(None)
    monkeypatch.setenv("REPRO_AUTOTUNE", "6")
    assert autotune.is_enabled(None) and autotune.search_k() == 6
    autotune.disable()                      # module switch beats env
    assert not autotune.is_enabled(None)
    autotune.enable(k=3)
    assert autotune.is_enabled(None) and autotune.search_k() == 3
    assert autotune.is_enabled(False) is False   # spec beats everything
    autotune.disable()
    assert autotune.is_enabled(True) is True


def test_backward_pass_never_tunes(monkeypatch):
    import jax
    import jax.numpy as jnp
    fake = _fake_measurer({})
    monkeypatch.setattr(measure, "measure_plan", fake)
    a = jnp.ones((16, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    autotune.enable(k=2)
    loss = jax.grad(lambda a: ops.gemm(a, b).sum())(a)
    assert loss.shape == a.shape
    fwd_searches = cache.tuning_cache_info().measurements
    # only the forward spec searched; the VJP's _plain dA/dB GEMMs pin
    # tune=False (a nested search per backward shape would be quadratic)
    assert fwd_searches == 1


def test_flop_budget_skips_search(monkeypatch):
    fake = _fake_measurer({})
    monkeypatch.setattr(measure, "measure_plan", fake)
    autotune.enable(k=2)
    big = ops.plan(ops.GemmSpec(), (4096, 4096, 4096))   # 137 Gflop
    assert big.source == "analytic" and big.tuned is None
    assert fake.calls == []
    assert cache.tuning_cache_info().measurements == 0


# ---------------------------------------------------------------------------
# Calibration: exact synthetic recovery + apply/clear re-ranking
# ---------------------------------------------------------------------------

def _synthetic_entries(t0, bw, fl, n=8, mode="ref"):
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(n):
        by = float(rng.integers(1, 64) * 2**20)
        fp = float(rng.integers(1, 64) * 1e9)
        samples.append({"t_us": (t0 + by / bw + fp / fl) * 1e6,
                        "hbm_bytes": by, "flops": fp})
    return {"k": {"mode": mode, "samples": samples}}


def test_calibrate_recovers_exact_constants():
    fits = calibrate.fit(_synthetic_entries(t0=5e-4, bw=40e9, fl=2e12))
    c = fits["ref"]
    assert c.n_samples == 8
    assert c.t0_us == pytest.approx(500.0, rel=1e-3)
    assert c.hbm_bw == pytest.approx(40e9, rel=1e-3)
    assert c.peak_flops == pytest.approx(2e12, rel=1e-3)
    assert c.r2 == pytest.approx(1.0, abs=1e-4)
    assert "eff BW 40.00 GB/s" in calibrate.render(fits)


def test_calibrate_drops_non_identifiable_terms():
    # time *decreases* with flops (an absurd host): the fitted flops
    # coefficient is negative, so the term must be dropped and *said*,
    # not reported as a negative "effective compute rate"
    rng = np.random.default_rng(1)
    samples = []
    for _ in range(10):
        by = float(rng.integers(1, 64) * 2**20)
        fp = float(rng.integers(1, 64) * 1e6)
        t = 1e-3 + by / 10e9 - fp / 1e12
        samples.append({"t_us": t * 1e6, "hbm_bytes": by, "flops": fp})
    c = calibrate.fit({"k": {"mode": "ref", "samples": samples}})["ref"]
    assert c.peak_flops is None
    assert "flops" in c.note
    assert c.hbm_bw == pytest.approx(10e9, rel=5e-2)


def test_calibrate_insufficient_samples_is_explicit():
    c = calibrate.fit(_synthetic_entries(1e-4, 1e10, 1e12, n=2))["ref"]
    assert c.hbm_bw is None and "insufficient" in c.note


def test_calibrate_apply_changes_model_and_clear_restores(monkeypatch):
    monkeypatch.setattr(api, "_mode", lambda: "ref")
    before = ops.plan(ops.GemmSpec(), SHAPE).traffic.t_model
    applied = calibrate.apply(
        calibrate.fit(_synthetic_entries(t0=0.0, bw=1e9, fl=1e9)))
    assert applied is not None
    after = ops.plan(ops.GemmSpec(), SHAPE).traffic.t_model
    assert after > before * 10              # 1 GB/s host is much slower
    calibrate.clear()
    restored = ops.plan(ops.GemmSpec(), SHAPE).traffic.t_model
    assert restored == pytest.approx(before)
