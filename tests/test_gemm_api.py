"""The declarative GemmSpec operator API: spec validation, the plan
cache, explicit-tile honoring, the (quantized? x epilogue? x gated?) x
(pallas / interpret / ref) dispatch matrix, and bit-identical parity of
the deprecated legacy entrypoints against the planned path.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops, quant
from repro.core import dse
from repro.core.tiling import TileConfig
from repro.kernels import api, ref
from repro.kernels import ops as legacy


@pytest.fixture(autouse=True)
def _fresh_plan_cache():
    """The plan cache is global state; tests here monkeypatch DSE and
    kernel internals, so stale plans must not leak in either direction."""
    api.plan_cache_clear()
    yield
    api.plan_cache_clear()


def _rand(shape, dtype=jnp.bfloat16, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


A = _rand((32, 256), seed=0)
B = _rand((256, 128), seed=1)
B2 = _rand((256, 128), seed=2)
BQ = quant.quantize_weight(np.asarray(B, np.float32))
B2Q = quant.quantize_weight(np.asarray(B2, np.float32))
BIAS = _rand((128,), jnp.float32, seed=3)


# ---------------------------------------------------------------------------
# Spec validation — bad strategies/activations fail at construction
# ---------------------------------------------------------------------------

def test_unknown_strategy_raises_with_allowed_set():
    with pytest.raises(ValueError, match=r"aie.*tb"):
        ops.GemmSpec(strategy="aei")
    with pytest.raises(ValueError, match=r"aie.*tb"):
        ops.gemm(A, B, strategy="versal")


def test_unknown_activation_raises_with_allowed_set():
    with pytest.raises(ValueError, match="swish"):
        ops.GemmSpec(epilogue=ops.Epilogue(activation="swish"))
    with pytest.raises(ValueError, match="swish"):
        ops.gemm(A, B, activation="swish")
    with pytest.raises(ValueError, match="swish"):
        ops.gemm(A, B, b2=B2, activation="swish")


def test_gated_spec_constraints():
    with pytest.raises(ValueError, match="activation"):
        ops.gemm(A, B, b2=B2)                       # no gate activation
    with pytest.raises(ValueError, match="bias"):
        ops.gemm(A, B, b2=B2, activation="silu", bias=BIAS)
    with pytest.raises(ValueError, match="aie"):
        ops.GemmSpec(gated=True, epilogue="silu", strategy="tb")
    with pytest.raises(ValueError, match="neither"):
        ops.gemm(A, BQ, b2=B2, activation="silu")   # one quantized


def test_execute_rejects_operands_that_mismatch_the_plan():
    pl = ops.plan(ops.GemmSpec.for_operands(A, B), ops.gemm_shapes(A, B))
    with pytest.raises(ValueError, match="do not match the plan"):
        ops.execute(pl, A[:16], B)
    with pytest.raises(ValueError, match="requires"):
        pl_bias = ops.plan(
            ops.GemmSpec.for_operands(A, B, bias=BIAS),
            ops.gemm_shapes(A, B))
        ops.execute(pl_bias, A, B)                  # bias= missing
    with pytest.raises(ValueError, match="struct"):
        ops.execute(pl, A, BQ)                      # plan says plain B
    with pytest.raises(ValueError, match="zero-padded"):
        ops.gemm(A, B, b2=_rand((256, 64), seed=11),
                 activation="silu")                 # mismatched b2
    with pytest.raises(ValueError, match="residual"):
        ops.gemm(A, B, residual=_rand((16, 128), seed=12))


# ---------------------------------------------------------------------------
# Plan cache — DSE resolves once per unique (spec, shape)
# ---------------------------------------------------------------------------

def test_plan_cache_counters():
    info0 = ops.plan_cache_info()
    assert info0 == (0, 0, 0)
    ops.gemm(A, B)
    ops.gemm(A, B)
    info = ops.plan_cache_info()
    assert info.entries == 1 and info.misses == 1 and info.hits == 1
    ops.gemm(A[:16], B)                             # new shape -> miss
    info = ops.plan_cache_info()
    assert info.entries == 2 and info.misses == 2
    assert len(ops.plans()) == info.entries


def test_plan_is_cached_object_identity():
    spec = ops.GemmSpec.for_operands(A, B)
    assert ops.plan(spec, (32, 256, 128)) is ops.plan(spec, (32, 256, 128))


# ---------------------------------------------------------------------------
# Explicit tile honoring — uniformly, quantized B included
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strat", ["aie", "tb"])
@pytest.mark.parametrize("quantized", [False, True])
def test_explicit_tile_reaches_kernel_without_dse(monkeypatch, strat,
                                                  quantized):
    """The satellite fix: a user tile= must reach the kernel verbatim on
    every path (quant-struct B included) and must not consult the DSE."""
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    seen = []

    def spy(orig):
        def run(*args, **kw):
            seen.append(kw.get("tile"))
            return orig(*args, **kw)
        return run

    monkeypatch.setattr(api, "gemm_aie", spy(api.gemm_aie))
    monkeypatch.setattr(api, "gemm_tb", spy(api.gemm_tb))
    monkeypatch.setattr(dse, "solve",
                        lambda *a, **kw: pytest.fail("DSE consulted "
                                                     "despite tile="))
    tile = TileConfig(32, 128, 128, strat)
    b = BQ if quantized else B
    got = ops.gemm(A, b, tile=tile, out_dtype=jnp.float32)
    assert seen == [tile]
    want = ref.gemm_fused_ref(A, BQ["q"], BQ["scale"],
                              out_dtype=jnp.float32) if quantized \
        else ref.gemm_ref(A, B, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-2, atol=5e-2)


def test_infeasible_explicit_aie_tile_raises(monkeypatch):
    monkeypatch.setattr(api, "fits_vmem", lambda *a, **kw: False)
    with pytest.raises(ValueError, match="infeasible"):
        ops.gemm(A, BQ, tile=TileConfig(32, 128, 128, "aie"))


# ---------------------------------------------------------------------------
# The dispatch matrix: (quantized?, epilogue?, gated?) x mode -> kernel
# ---------------------------------------------------------------------------

# pre-bound so the dummies keep working while the ref module attrs are
# monkeypatched with call counters
_ORIG_EP_REF = ref.gemm_epilogue_ref
_ORIG_GATED_REF = ref.gemm_gated_ref


def _ref_dummy(*args, **kw):
    """Stand-in for a Pallas kernel under REPRO_KERNELS=pallas on a CPU
    host: computes the same math with the jnp oracle so the dispatch
    (which kernel was chosen) can be asserted without a TPU."""
    a, b = args[0], args[1]
    return _ORIG_EP_REF(
        a, b, b_scale=kw.get("b_scale"), bias=kw.get("bias"),
        activation=kw.get("activation"), residual=kw.get("residual"),
        out_scale=kw.get("out_scale"), out_dtype=kw.get("out_dtype"))


def _gated_dummy(a, bg, bu, **kw):
    return _ORIG_GATED_REF(a, bg, bu, activation=kw["activation"],
                           bg_scale=kw.get("bg_scale"),
                           bu_scale=kw.get("bu_scale"),
                           out_dtype=kw.get("out_dtype"))


MATRIX = [(q, e, g) for q in (False, True) for e in (False, True)
          for g in (False, True) if not (g and e)]


@pytest.mark.parametrize("mode", ["ref", "interpret", "pallas"])
@pytest.mark.parametrize("quantized,epilogue,gated", MATRIX)
def test_dispatch_matrix(monkeypatch, quantized, epilogue, gated, mode):
    """Every (quantized?, epilogue?, gated?) combination must route to
    the intended kernel in every REPRO_KERNELS mode (call counters via
    monkeypatch), through the ONE planned dispatch path."""
    monkeypatch.setenv("REPRO_KERNELS", mode)
    calls = {}

    def count(name, fn):
        def run(*args, **kw):
            calls[name] = calls.get(name, 0) + 1
            return fn(*args, **kw)
        return run

    pallas_impl = {"interpret": (api.gemm_aie, api._gemm_gated_kernel),
                   "pallas": (_ref_dummy, _gated_dummy),
                   "ref": (api.gemm_aie, api._gemm_gated_kernel)}[mode]
    monkeypatch.setattr(api, "gemm_aie", count("aie", pallas_impl[0]))
    monkeypatch.setattr(api, "_gemm_gated_kernel",
                        count("gated", pallas_impl[1]))
    monkeypatch.setattr(api._ref, "gemm_ref",
                        count("ref", ref.gemm_ref))
    monkeypatch.setattr(api._ref, "gemm_fused_ref",
                        count("fused_ref", ref.gemm_fused_ref))
    monkeypatch.setattr(api._ref, "gemm_epilogue_ref",
                        count("ep_ref", ref.gemm_epilogue_ref))
    monkeypatch.setattr(api._ref, "gemm_gated_ref",
                        count("gated_ref", ref.gemm_gated_ref))

    kwargs = {"out_dtype": jnp.float32}
    if not gated:
        kwargs["tile"] = TileConfig(32, 128, 128, "aie")
    b = BQ if quantized else B
    if gated:
        got = ops.gemm(A, b, b2=B2Q if quantized else B2,
                       activation="silu", **kwargs)
    elif epilogue:
        got = ops.gemm(A, b, bias=BIAS, activation="gelu", **kwargs)
    else:
        got = ops.gemm(A, b, **kwargs)

    if mode == "ref":
        want = ("gated_ref" if gated else "ep_ref" if epilogue
                else "fused_ref" if quantized else "ref")
    else:
        want = "gated" if gated else "aie"
    assert calls.get(want) == 1, (calls, want)
    others = {k: v for k, v in calls.items() if k != want}
    assert not others, (calls, want)

    # and the math is right whatever the route
    bq, bs = (BQ["q"], BQ["scale"]) if quantized else (B, None)
    if gated:
        want_val = ref.gemm_gated_ref(
            A, bq, B2Q["q"] if quantized else B2, activation="silu",
            bg_scale=bs, bu_scale=B2Q["scale"] if quantized else None,
            out_dtype=jnp.float32)
    else:
        want_val = ref.gemm_epilogue_ref(
            A, bq, b_scale=bs, bias=BIAS.reshape(1, -1) if epilogue
            else None, activation="gelu" if epilogue else None,
            out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_val),
                               rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# Legacy entrypoints: deprecated shims, bit-identical to the new API
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings("ignore::DeprecationWarning")
@pytest.mark.parametrize("mode", ["ref", "interpret"])
@pytest.mark.parametrize("quantized", [False, True])
def test_legacy_entrypoints_bit_identical(monkeypatch, mode, quantized):
    monkeypatch.setenv("REPRO_KERNELS", mode)
    b = BQ if quantized else B
    res = _rand((32, 128), seed=7)
    pairs = [
        (legacy.gemm(A, b), ops.gemm(A, b)),
        (legacy.gemm_fused(A, b, bias=BIAS, activation="gelu",
                           residual=res),
         ops.gemm(A, b, bias=BIAS, activation="gelu", residual=res)),
        (legacy.gemm_gated(A, b, B2Q if quantized else B2),
         ops.gemm(A, b, b2=B2Q if quantized else B2,
                  activation="silu")),
    ]
    if not quantized:
        aq, asc = ops.quantize_int8(A)
        bq8, bsc = ops.quantize_int8(B, axis=0)
        acc = ops.gemm(jnp.asarray(aq), jnp.asarray(bq8),
                       out_dtype=jnp.int32)
        pairs.append((
            legacy.gemm_int8(jnp.asarray(aq), jnp.asarray(bq8), asc, bsc),
            (acc.astype(jnp.float32) * asc * bsc).astype(jnp.float32)))
    for old, new in pairs:
        assert old.dtype == new.dtype
        assert (np.asarray(old) == np.asarray(new)).all()


def test_legacy_entrypoints_emit_deprecation_warning():
    for call in (lambda: legacy.gemm(A, B),
                 lambda: legacy.gemm_fused(A, B, bias=BIAS),
                 lambda: legacy.gemm_gated(A, B, B2),
                 lambda: legacy.gemm_int8(
                     jnp.ones((8, 128), jnp.int8),
                     jnp.ones((128, 128), jnp.int8), 1.0, 1.0)):
        with pytest.warns(DeprecationWarning, match="repro.ops"):
            call()


def test_internal_model_layers_use_no_deprecated_entrypoints():
    """The -W error::DeprecationWarning CI invocation in miniature: a
    forward+backward through the migrated layers must not touch the
    legacy shims."""
    from repro.models import layers as L
    key = jax.random.PRNGKey(0)
    params = L.init_swiglu(key, 64, 128, jnp.float32)
    attn = L.init_attention(
        key, L.AttnLayerSpec(64, 4, 2, 16, rope_theta=1e4), jnp.float32)
    x = _rand((2, 8, 64), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        def loss(p, a, x):
            h = L.swiglu(p, x, residual=x)
            h = L.attention_block(a, h, L.AttnLayerSpec(64, 4, 2, 16),
                                  residual=h)
            return jnp.sum(h.astype(jnp.float32))
        val, grads = jax.value_and_grad(loss)(params, attn, x)
    assert np.isfinite(float(val))


# ---------------------------------------------------------------------------
# Grads through the single VJP match the unfused jnp composition
# ---------------------------------------------------------------------------

def test_grad_epilogue_matches_unfused_composition():
    a = _rand((16, 128), jnp.float32, seed=4)
    b = _rand((128, 128), jnp.float32, seed=5)
    res = _rand((16, 128), jnp.float32, seed=6)

    def fused(a, b, bias, res):
        return jnp.sum(ops.gemm(a, b, bias=bias, activation="gelu",
                                residual=res, out_dtype=jnp.float32))

    def unfused(a, b, bias, res):
        z = a @ b + bias
        return jnp.sum(jax.nn.gelu(z) + res)

    gf = jax.grad(fused, argnums=(0, 1, 2, 3))(a, b, BIAS, res)
    gu = jax.grad(unfused, argnums=(0, 1, 2, 3))(a, b, BIAS, res)
    for f, u in zip(gf, gu):
        np.testing.assert_allclose(np.asarray(f), np.asarray(u),
                                   rtol=1e-4, atol=1e-4)


def test_grad_quantized_weight_is_serving_artifact():
    a = _rand((16, 256), jnp.float32, seed=8)

    def f(a):
        return jnp.sum(ops.gemm(a, BQ, out_dtype=jnp.float32))

    da = jax.grad(f)(a)
    w = np.asarray(BQ["q"], np.float32) * np.asarray(BQ["scale"])
    np.testing.assert_allclose(np.asarray(da),
                               np.tile(w.sum(axis=1), (16, 1)),
                               rtol=1e-3, atol=1e-3)


def test_only_one_custom_vjp_per_gemm_family_core():
    """Acceptance criterion, executable form of the grep: the kernels
    dispatch layer defines exactly ONE jax.custom_vjp per family core —
    ``_gemm_core`` (plain/fused/gated, every epilogue) and
    ``_grouped_core`` (the ragged ``(E, k, n)`` bank + ``group_sizes``
    operand structure that cannot share the dense signature).  Any new
    epilogue or dtype combination must ride one of these two backwards,
    not add a third."""
    import pathlib
    root = pathlib.Path(api.__file__).parent
    count = sum(
        (root / f).read_text().count("functools.partial(jax.custom_vjp")
        for f in ("api.py", "ops.py"))
    assert count == 2, count


def test_w8a8_reroute_through_planned_path(monkeypatch):
    monkeypatch.setenv("REPRO_W8A8", "1")
    a = _rand((16, 256), jnp.float32, seed=9)
    got = ops.gemm(a, BQ, out_dtype=jnp.float32)
    aq, asc = quant.quantize_activations(a)
    want = ref.gemm_fused_ref(aq, BQ["q"], BQ["scale"],
                              out_dtype=jnp.float32) * asc
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # linear epilogue keeps the int8 path, applied outside
    res = _rand((16, 128), jnp.float32, seed=10)
    got2 = ops.gemm(a, BQ, residual=res, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got2),
                               np.asarray(want + res),
                               rtol=1e-4, atol=1e-4)
