"""repro.dist.sharding mechanism + choose_layout DSE policy tests
(beyond the spec-level coverage in tests/test_layout.py)."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import layout, sharding as shd
from tests.test_layout import MESH, MESH_POD


# ---------------------------------------------------------------- mesh stack

def test_use_mesh_nesting_and_restore():
    assert shd.current_mesh() is None
    with shd.use_mesh(MESH) as outer:
        assert shd.current_mesh() is outer is MESH
        with shd.use_mesh(MESH_POD):
            assert shd.current_mesh() is MESH_POD
        assert shd.current_mesh() is MESH
    assert shd.current_mesh() is None


def test_use_mesh_restores_on_exception():
    with pytest.raises(RuntimeError):
        with shd.use_mesh(MESH):
            raise RuntimeError("boom")
    assert shd.current_mesh() is None


def test_axis_sizes_duck_typed():
    assert shd.axis_sizes(MESH_POD) == {"pod": 2, "data": 16, "model": 16}
    assert shd.axis_sizes(None) == {}


# ---------------------------------------------------------------------- act

def test_act_is_noop_without_mesh():
    x = jnp.ones((4, 8, 16))
    assert shd.act(x, ("batch", "seq", None)) is x


def test_act_is_noop_on_duck_typed_mesh():
    # spec-level FakeMesh must never reach with_sharding_constraint
    x = jnp.ones((4, 8, 16))
    with shd.use_mesh(MESH):
        assert shd.act(x, ("batch", None, "model")) is x


def test_act_is_noop_on_trivial_real_mesh():
    mesh = shd.make_mesh((1, 1), ("data", "model"))
    x = jnp.ones((4, 8))
    with shd.use_mesh(mesh):
        assert shd.act(x, ("batch", None)) is x


def test_act_rank_mismatch_is_noop():
    x = jnp.ones((4, 8))
    with shd.use_mesh(MESH):
        assert shd.act(x, ("batch", "seq", None)) is x


# ------------------------------------------------------ logical resolution

def test_logical_spec_resolution_and_relaxation():
    sizes = shd.axis_sizes(MESH_POD)
    # batch -> widest dividing combo; seq -> model; non-dividing relaxes
    assert shd.logical_spec((64, 32, 10), ("batch", "seq", None), sizes) \
        == P(("pod", "data"), "model", None)
    # rows=16: 'pod'*'data'=32 doesn't divide, suffix ('data',) does
    assert shd.logical_spec((16, 32), ("batch", "seq"), sizes) \
        == P("data", "model")
    # nothing divides -> fully replicated
    assert shd.logical_spec((3, 5), ("batch", "seq"), sizes) == P(None, None)


def test_logical_spec_never_reuses_a_mesh_axis():
    sizes = shd.axis_sizes(MESH)
    # both 'expert' and 'seq' resolve to 'model'; second claim drops
    s = shd.logical_spec((16, 16, 8), ("expert", "seq", None), sizes)
    assert s == P("model", None, None)


def test_seq_shard_toggle(monkeypatch):
    sizes = shd.axis_sizes(MESH)
    monkeypatch.setenv("REPRO_SEQ_SHARD", "0")
    assert shd.resolve_axis("seq", 32, sizes) is None
    monkeypatch.delenv("REPRO_SEQ_SHARD")
    assert shd.resolve_axis("seq", 32, sizes) == "model"


# ----------------------------------------------------------- choose_layout

def test_choose_layout_tp_over_dp_when_param_bytes_dominate():
    cfg = get_config("smollm-360m")
    scored = layout.score_layouts(cfg)
    assert scored["dp"]["feasible"] and scored["tp"]["feasible"]
    # per-device bytes dominate dp's score; tp shards them 16x
    assert scored["tp"]["score"] < scored["dp"]["score"]
    assert layout.choose_layout(cfg) == "tp"


def test_choose_layout_infeasible_tiers_fall_to_max_sharding():
    cfg = get_config("kimi-k2-1t-a32b")
    scored = layout.score_layouts(cfg)
    assert not any(v["feasible"] for v in scored.values())
    assert layout.choose_layout(cfg) == "fsdp_tp"


def test_score_layouts_memory_ordering():
    scored = layout.score_layouts(get_config("deepseek-67b"))
    mem = {s: v["mem_bytes_per_device"] for s, v in scored.items()}
    assert mem["fsdp_tp"] < mem["tp"] <= mem["dp"]
    assert mem["fsdp_tp"] < mem["fsdp"] <= mem["dp"]


def test_spec_for_rejects_unknown_strategy():
    with pytest.raises(ValueError):
        layout.spec_for("lm_head", (8, 8), "zz_not_a_strategy",
                        {"data": 2, "model": 2})


# ------------------------------------------------- end-to-end on a real mesh

_ACT_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.dist import sharding as shd

mesh = shd.make_mesh((2, 4), ("data", "model"))
x = jnp.ones((4, 8, 16))

def f(x):
    return shd.act(x, ("batch", None, "model")) * 2.0

with shd.use_mesh(mesh):
    y = jax.jit(f)(x)
assert y.shape == x.shape and float(y[0, 0, 0]) == 2.0
# the constraint must actually land: last dim sharded 4-way over 'model'
shard_shapes = {s.data.shape for s in y.addressable_shards}
assert shard_shapes == {(2, 8, 4)}, shard_shapes
print("ACT-OK", sorted(shard_shapes))
"""


def test_act_applies_constraint_under_jit_multidevice():
    """act() must emit a real sharding constraint — run on a forced
    8-device CPU mesh in a subprocess (parent stays single-device)."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _ACT_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ACT-OK" in r.stdout
