"""The declarative AttnSpec operator API: spec validation, the counted
dispatch-mode-scoped plan cache, the (prefill / decode / paged) x
(causal / window) x (MHA / GQA / MQA) x (pallas / interpret / ref)
dispatch matrix with call counters, recorded fallback reasons, grads
through the ONE generic VJP, plan-explain-vs-cost-model agreement on the
decode-32k shape, measured block autotuning through the persistent
``attn|`` cache namespace, and bit-identical parity of the deprecated
legacy entrypoints against the planned path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import ops
from repro.core import bandwidth
from repro.kernels import attn_api
from repro.kernels import ops as legacy
from repro.kernels import ref as _ref


@pytest.fixture(autouse=True)
def _fresh_attn_plan_cache():
    """Attention plans are global, dispatch-mode-scoped state; tests
    here flip REPRO_KERNELS and monkeypatch kernels, so stale plans must
    not leak in either direction."""
    attn_api.attn_plan_cache_clear()
    yield
    attn_api.attn_plan_cache_clear()


def _rand(shape, dtype=jnp.bfloat16, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape,
                             jnp.float32).astype(dtype)


def _qkv(b=1, sq=128, skv=128, hq=2, hkv=2, d=64, dtype=jnp.bfloat16):
    return (_rand((b, sq, hq, d), dtype, 0),
            _rand((b, skv, hkv, d), dtype, 1),
            _rand((b, skv, hkv, d), dtype, 2))


def _decode_ops(b=2, skv=256, hq=4, hkv=2, d=64, dtype=jnp.bfloat16):
    q = _rand((b, hq, d), dtype, 0)
    kc = _rand((b, skv, hkv, d), dtype, 1)
    vc = _rand((b, skv, hkv, d), dtype, 2)
    pos = jnp.asarray([skv // 2, skv - 1][:b], jnp.int32)
    return q, kc, vc, pos


# ---------------------------------------------------------------------------
# Spec validation — invalid combos raise at construction
# ---------------------------------------------------------------------------

def test_spec_rejects_bad_mode_window_group():
    with pytest.raises(ValueError, match="mode"):
        ops.AttnSpec(mode="chunked")
    with pytest.raises(ValueError, match="window"):
        ops.AttnSpec(window=-1)
    with pytest.raises(ValueError, match="group"):
        ops.AttnSpec(group=0)


def test_spec_rejects_noncausal_decode_and_windowed_noncausal():
    with pytest.raises(ValueError, match="causal"):
        ops.AttnSpec(mode="decode", causal=False)
    with pytest.raises(ValueError, match="causal"):
        ops.AttnSpec(mode="decode_paged", causal=False)
    with pytest.raises(ValueError, match="window"):
        ops.AttnSpec(causal=False, window=128)


def test_spec_rejects_nonfloat_dtypes_and_kv_quant_hook():
    with pytest.raises(ValueError, match="q_dtype"):
        ops.AttnSpec(q_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        ops.AttnSpec(kv_dtype="int32")
    # the forward-compat hook must never be silently ignored
    with pytest.raises(ValueError, match="kv_quant"):
        ops.AttnSpec(kv_quant=True)


def test_spec_block_override_constraints():
    with pytest.raises(ValueError, match="bq"):
        ops.AttnSpec(bq=100)            # not a multiple of 8
    with pytest.raises(ValueError, match="bkv"):
        ops.AttnSpec(bkv=64)            # not a multiple of 128
    with pytest.raises(ValueError, match="page"):
        ops.AttnSpec(mode="decode_paged", bkv=256)
    # a valid override is honored verbatim
    spec = ops.AttnSpec(bq=256, bkv=128)
    pl = ops.attn_plan(spec, (1, 2048, 2048, 2, 2, 64))
    assert (pl.bq, pl.bkv) == (256, 128)
    assert "!256x128" in spec.key


def test_spec_key_namespace_and_plan_shapes_validation():
    assert ops.AttnSpec().key.startswith("attn|")
    with pytest.raises(ValueError, match="5 ints"):
        ops.attn_plan(ops.AttnSpec(mode="decode"), (1, 2, 3, 4, 5, 6))
    with pytest.raises(ValueError, match="group"):
        # hq != hkv * group
        ops.attn_plan(ops.AttnSpec(group=2), (1, 128, 128, 2, 2, 64))


# ---------------------------------------------------------------------------
# The dispatch matrix: call counters prove which kernel family ran
# ---------------------------------------------------------------------------

_ORIG_ATTENTION_REF = _ref.attention_ref
_ORIG_XLA_DECODE = attn_api._decode_attention_xla


def _flash_dummy(q, k, v, *, causal=True, window=0, scale=None,
                 q_offset=None, **kw):
    """Stand-in for the Pallas flash kernel under REPRO_KERNELS=pallas
    on a CPU host — same math via the jnp oracle, so the dispatch can
    be asserted without a TPU."""
    return _ORIG_ATTENTION_REF(q, k, v, causal=causal, window=window,
                               scale=scale, q_offset=q_offset)


def _flash_decode_dummy(q, kc, vc, pos, *, window=0, **kw):
    return _ORIG_XLA_DECODE(q, kc, vc, pos, window=window)


def _flash_paged_dummy(q, kp, vp, tbl, pos, *, window=0, **kw):
    n, ps, hkv, d = kp.shape
    b, mp = tbl.shape
    k = kp[tbl].reshape(b, mp * ps, hkv, d)
    v = vp[tbl].reshape(b, mp * ps, hkv, d)
    return _ORIG_XLA_DECODE(q, k, v, pos, window=window)


CASES = {
    # name: (mode_kind, heads, causal, window)
    "prefill_mha": ("prefill", (2, 2), True, 0),
    "prefill_gqa_window": ("prefill", (4, 2), True, 64),
    "prefill_mqa_full": ("prefill", (4, 1), False, 0),
    "decode_gqa": ("decode", (4, 2), True, 0),
    "decode_mqa_window": ("decode", (4, 1), True, 64),
    "paged_gqa": ("decode_paged", (4, 2), True, 0),
}


@pytest.mark.parametrize("mode", ["ref", "interpret", "pallas"])
@pytest.mark.parametrize("case", sorted(CASES))
def test_dispatch_matrix(monkeypatch, mode, case):
    """Every (prefill/decode/paged x mask x head-grouping) combination
    must route to the intended kernel family in every REPRO_KERNELS
    mode, through the ONE planned dispatch path."""
    monkeypatch.setenv("REPRO_KERNELS", mode)
    kind, (hq, hkv), causal, window = CASES[case]
    calls = {}

    def count(name, fn):
        def run(*args, **kw):
            calls[name] = calls.get(name, 0) + 1
            return fn(*args, **kw)
        return run

    pallas_impl = {
        "interpret": (attn_api.flash_attention, attn_api.flash_decode,
                      attn_api.flash_decode_paged),
        "pallas": (_flash_dummy, _flash_decode_dummy, _flash_paged_dummy),
        "ref": (attn_api.flash_attention, attn_api.flash_decode,
                attn_api.flash_decode_paged),
    }[mode]
    monkeypatch.setattr(attn_api, "flash_attention",
                        count("flash", pallas_impl[0]))
    monkeypatch.setattr(attn_api, "flash_decode",
                        count("flash_decode", pallas_impl[1]))
    monkeypatch.setattr(attn_api, "flash_decode_paged",
                        count("flash_paged", pallas_impl[2]))
    monkeypatch.setattr(attn_api, "attention_blocked",
                        count("blocked", attn_api.attention_blocked))
    monkeypatch.setattr(attn_api._ref, "attention_ref",
                        count("xla_ref", _ORIG_ATTENTION_REF))
    monkeypatch.setattr(attn_api, "_decode_attention_xla",
                        count("xla_decode", _ORIG_XLA_DECODE))

    if kind == "prefill":
        q, k, v = _qkv(hq=hq, hkv=hkv)
        got = ops.attention(q, k, v, causal=causal, window=window)
        want_ref = _ORIG_ATTENTION_REF(q, k, v, causal=causal,
                                       window=window)
        want_call = "flash" if mode != "ref" else "xla_ref"
    elif kind == "decode":
        q, kc, vc, pos = _decode_ops(hq=hq, hkv=hkv)
        got = ops.decode_attention(q, kc, vc, pos, window=window)
        want_ref = _ORIG_XLA_DECODE(q, kc, vc, pos, window=window)
        want_call = "flash_decode" if mode != "ref" else "xla_decode"
    else:
        q, kc, vc, pos = _decode_ops(hq=hq, hkv=hkv, skv=256)
        kp = kc.reshape(4, 128, hkv, 64)
        vp = vc.reshape(4, 128, hkv, 64)
        tbl = jnp.arange(4, dtype=jnp.int32).reshape(2, 2)
        got = ops.decode_attention_paged(q, kp, vp, tbl, pos,
                                         window=window)
        want_ref = _ORIG_XLA_DECODE(q, kc, vc, pos, window=window)
        want_call = "flash_paged" if mode != "ref" else "xla_decode"

    assert calls.get(want_call) == 1, (calls, want_call)
    wrong = {"flash", "flash_decode", "flash_paged", "blocked",
             "xla_ref", "xla_decode"} - {want_call}
    if kind == "decode_paged" and mode == "ref":
        wrong -= {"xla_decode"}     # the gather path reuses the dense one
    assert not (wrong & calls.keys()), (calls, want_call)

    # the plan cache saw exactly this resolution
    (pl,) = ops.attn_plans()
    assert pl.dispatch == mode
    assert pl.spec.mode == kind
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want_ref, np.float32),
        rtol=3e-2, atol=3e-2)


def test_long_prefill_routes_to_blocked_in_ref_mode(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    q, k, v = _qkv(sq=128, skv=2048)
    got = ops.attention(q, k, v)
    (pl,) = ops.attn_plans()
    assert pl.kernel == "attention_blocked"
    assert pl.fallback_reason is None       # ref mode never wanted flash
    assert pl.bq is not None and pl.bkv is not None
    want = _ORIG_ATTENTION_REF(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# Satellite: the silent pallas fallback is now loud
# ---------------------------------------------------------------------------

def test_short_prefill_fallback_reason_recorded(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "pallas")
    pl = ops.attn_plan(ops.AttnSpec(), (1, 64, 128, 2, 2, 64))
    assert pl.kernel == "xla_ref"
    assert "sq >= 128" in pl.fallback_reason
    assert "sq=64" in pl.fallback_reason
    assert "fallback" in pl.explain()


def test_no_fallback_reason_when_flash_applies(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    pl = ops.attn_plan(ops.AttnSpec(), (1, 128, 128, 2, 2, 64))
    assert pl.kernel == "flash_attention"
    assert pl.fallback_reason is None
    assert "fallback" not in pl.explain()


# ---------------------------------------------------------------------------
# Legacy entrypoints: deprecated shims, bit-identical to the new API
# ---------------------------------------------------------------------------

@pytest.mark.filterwarnings("ignore::DeprecationWarning")
@pytest.mark.parametrize("mode", ["ref", "interpret"])
def test_legacy_entrypoints_bit_identical(monkeypatch, mode):
    monkeypatch.setenv("REPRO_KERNELS", mode)
    q, k, v = _qkv(hq=4, hkv=2)
    qd, kc, vc, pos = _decode_ops()
    kp = kc.reshape(4, 128, 2, 64)
    vp = vc.reshape(4, 128, 2, 64)
    tbl = jnp.arange(4, dtype=jnp.int32).reshape(2, 2)
    pairs = [
        (legacy.attention(q, k, v, window=64),
         ops.attention(q, k, v, window=64)),
        (legacy.decode_attention(qd, kc, vc, pos),
         ops.decode_attention(qd, kc, vc, pos)),
        (legacy.decode_attention_paged(qd, kp, vp, tbl, pos),
         ops.decode_attention_paged(qd, kp, vp, tbl, pos)),
    ]
    for old, new in pairs:
        assert old.dtype == new.dtype
        assert (np.asarray(old) == np.asarray(new)).all()


def test_legacy_attention_entrypoints_warn():
    q, k, v = _qkv()
    qd, kc, vc, pos = _decode_ops()
    kp = kc.reshape(4, 128, 2, 64)
    vp = vc.reshape(4, 128, 2, 64)
    tbl = jnp.arange(4, dtype=jnp.int32).reshape(2, 2)
    with pytest.warns(DeprecationWarning, match="repro.ops"):
        legacy.attention(q, k, v)
    with pytest.warns(DeprecationWarning, match="repro.ops"):
        legacy.decode_attention(qd, kc, vc, pos)
    with pytest.warns(DeprecationWarning, match="repro.ops"):
        legacy.decode_attention_paged(qd, kp, vp, tbl, pos)


# ---------------------------------------------------------------------------
# Grads through the ONE generic VJP, vs the ref composition
# ---------------------------------------------------------------------------

def test_prefill_grads_match_ref_composition():
    q, k, v = _qkv(sq=256, skv=256, hq=4, hkv=2, dtype=jnp.float32)
    got = jax.grad(lambda *a: ops.attention(*a, window=64).sum(),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(
        lambda *a: _ref.attention_ref(*a, window=64).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_long_prefill_grads_match_ref_composition():
    # forward = attention_blocked, backward recomputes through the
    # checkpointed blocked composition — still the ref math
    q, k, v = _qkv(sq=128, skv=2048, dtype=jnp.float32)
    got = jax.grad(lambda *a: ops.attention(*a).sum(),
                   argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(lambda *a: _ref.attention_ref(*a).sum(),
                    argnums=(0, 1, 2))(q, k, v)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)


def test_decode_grads_with_int_pos_operand():
    # pos is an int data operand riding the VJP — float0 cotangent
    q, kc, vc, pos = _decode_ops(dtype=jnp.float32)
    got = jax.grad(
        lambda q, kc, vc: ops.decode_attention(q, kc, vc, pos).sum(),
        argnums=(0, 1, 2))(q, kc, vc)
    want = jax.grad(
        lambda q, kc, vc: attn_api._decode_attention_xla(
            q, kc, vc, pos, window=0).sum(),
        argnums=(0, 1, 2))(q, kc, vc)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)


def test_exactly_one_custom_vjp_in_attn_api():
    import inspect
    src = inspect.getsource(attn_api)
    assert src.count("functools.partial(jax.custom_vjp") == 1


# ---------------------------------------------------------------------------
# Plan cache: counted, dispatch-mode scoped
# ---------------------------------------------------------------------------

def test_plan_cache_counters_and_mode_scoping(monkeypatch):
    spec = ops.AttnSpec(mode="decode", group=2)
    shapes = (2, 256, 4, 2, 64)
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    p1 = ops.attn_plan(spec, shapes)
    p2 = ops.attn_plan(spec, shapes)
    assert p1 is p2
    info = ops.attn_plan_cache_info()
    assert (info.entries, info.hits, info.misses) == (1, 1, 1)
    # a different dispatch mode is a different plan, not a stale hit
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    p3 = ops.attn_plan(spec, shapes)
    assert p3.kernel == "flash_decode" and p1.kernel == "xla_decode"
    assert ops.attn_plan_cache_info().entries == 2
    ops.attn_plan_cache_clear()
    assert ops.attn_plan_cache_info() == (0, 0, 0)


def test_execute_rejects_operands_that_mismatch_the_plan():
    q, kc, vc, pos = _decode_ops()
    spec = ops.AttnSpec(mode="decode", group=2)
    pl = ops.attn_plan(spec, (2, 256, 4, 2, 64))
    with pytest.raises(ValueError, match="pos"):
        ops.attn_execute(pl, q, kc, vc)             # decode needs pos
    with pytest.raises(ValueError, match="q shape"):
        ops.attn_execute(pl, q[:1], kc, vc, pos=pos)
    with pytest.raises(ValueError, match="k shape"):
        ops.attn_execute(pl, q, kc[:, :128], vc, pos=pos)
    with pytest.raises(ValueError, match="dtype"):
        ops.attn_execute(pl, q.astype(jnp.float32), kc, vc, pos=pos)
    with pytest.raises(ValueError, match="prefill-only"):
        ops.attn_execute(pl, q, kc, vc, pos=pos, scale=0.5)


# ---------------------------------------------------------------------------
# Cost model: plan/explain vs bandwidth billing on the decode-32k shape
# ---------------------------------------------------------------------------

def test_decode_32k_plan_agrees_with_decode_kv_billing(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    b, skv, hq, hkv, d = 4, 32768, 15, 5, 64
    pl = ops.attn_plan(ops.AttnSpec(mode="decode", group=3),
                       (b, skv, hq, hkv, d))
    assert pl.kernel == "flash_decode"
    kv = bandwidth.decode_kv_bytes([skv - 1] * b, n_kv_heads=hkv,
                                   head_dim=d, dtype="bfloat16")
    q_o = 2 * b * hq * d * 2                # q read + o write, bf16
    assert pl.hbm_bytes == pytest.approx(kv + q_o)
    # roofline verdict is max(compute, memory) under effective rates
    from repro.core.hardware import TPU_V5E
    peak, bw = bandwidth.effective_rates(TPU_V5E, False)
    assert pl.traffic.t_model == pytest.approx(
        max(pl.flops / peak, pl.hbm_bytes / bw))
    assert pl.traffic.bound == "memory"     # decode at 32k always is
    assert "true positions" in pl.explain()


def test_paged_decode_plan_bills_page_rounded_kv(monkeypatch):
    monkeypatch.setenv("REPRO_KERNELS", "interpret")
    b, mp, ps, hq, hkv, d = 4, 256, 128, 15, 5, 64
    pl = ops.attn_plan(ops.AttnSpec(mode="decode_paged", group=3),
                       (b, mp, ps, hq, hkv, d))
    assert pl.kernel == "flash_decode_paged"
    kv = bandwidth.decode_kv_bytes([mp * ps - 1] * b, n_kv_heads=hkv,
                                   head_dim=d, dtype="bfloat16",
                                   page_size=ps)
    q_o = 2 * b * hq * d * 2
    assert pl.hbm_bytes == pytest.approx(kv + q_o)
    assert "page-rounded" in pl.explain()


def test_prefill_traffic_rewards_larger_q_blocks():
    # bigger bq -> fewer kv re-streams: the gradient the block DSE uses
    p = attn_api.AttnProblem(mode="prefill", b=1, sq=4096, skv=4096,
                             hq=8, hkv=8, d=64)
    small = attn_api.attn_traffic(p, "flash_attention", 128, 512)
    big = attn_api.attn_traffic(p, "flash_attention", 1024, 512)
    assert big.hbm_bytes < small.hbm_bytes
    assert big.flops == small.flops         # mask math is block-free


def test_solve_topk_is_vmem_feasible_and_ranked():
    spec = ops.AttnSpec()
    designs = ops.attn_solve_topk(spec, (1, 4096, 4096, 8, 8, 128), k=5)
    assert designs
    ts = [d.traffic.t_model for d in designs]
    assert ts == sorted(ts)
    for d in designs:
        assert d.vmem.total <= (attn_api.VMEM_BUDGET_FRACTION
                                * attn_api.TPU_V5E.vmem_bytes)


# ---------------------------------------------------------------------------
# Autotune: measured block winners through the persistent attn| namespace
# ---------------------------------------------------------------------------

def test_attn_autotune_roundtrip_persistent_cache(tmp_path, monkeypatch):
    from repro import tune
    monkeypatch.setenv("REPRO_KERNELS", "ref")
    monkeypatch.setenv("REPRO_TUNE_CACHE",
                       str(tmp_path / "tune_cache.json"))
    tune.tuning_cache_reset()
    q, k, v = _qkv(sq=128, skv=2048)        # blocked path: tunable
    ops.attention(q, k, v, tune=True)
    (pl,) = ops.attn_plans()
    assert pl.source == "tuned" and not pl.tuned.from_cache
    assert pl.tuned.k_searched >= 1
    info = tune.tuning_cache_info()
    assert info.measurements == 1
    (key,) = tune.tuning_cache().entries().keys()
    assert key.startswith("attn|") and key.endswith("|ref")

    # second process over the same file: zero re-measurement
    tune.tuning_cache_reset()
    ops.attn_plan_cache_clear()
    ops.attention(q, k, v, tune=True)
    (pl2,) = ops.attn_plans()
    assert pl2.source == "tuned" and pl2.tuned.from_cache
    assert tune.tuning_cache_info().measurements == 0
    assert (pl2.bq, pl2.bkv) == (pl.bq, pl.bkv)
    assert f"{pl2.tuned.t_measured_us:.1f} us measured" in pl2.explain()
    tune.tuning_cache_reset()


def test_attn_autotune_batch_proxy_scales_down_not_out():
    from repro.tune import autotune
    p = attn_api.AttnProblem(mode="prefill", b=256, sq=4096, skv=4096,
                             hq=15, hkv=5, d=64)
    spec = ops.AttnSpec(group=3)
    shapes = (256, 4096, 4096, 15, 5, 64)
    got = autotune._attn_proxy_shapes(spec, shapes, p, 5e10)
    assert got is not None
    proxy_shapes, measured_b = got
    assert measured_b < 256 and proxy_shapes[0] == measured_b
    assert proxy_shapes[1:] == shapes[1:]
    # per-b flops above the budget: nothing measurable at all
    assert autotune._attn_proxy_shapes(spec, shapes, p, 1e7) is None


# ---------------------------------------------------------------------------
# The public surface rides repro.ops
# ---------------------------------------------------------------------------

def test_ops_exports_the_attention_api():
    for name in ("AttnSpec", "AttnPlan", "AttnProblem", "attn_plan",
                 "attn_execute", "attn_plans", "attn_plan_cache_info",
                 "attn_plan_cache_clear", "attn_solve_topk", "attention",
                 "decode_attention", "decode_attention_paged"):
        assert hasattr(ops, name), name
