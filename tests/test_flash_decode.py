"""flash_decode Pallas kernel vs the pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.ops import (_decode_attention_paged_xla,
                               _decode_attention_xla)
from repro.kernels.ref import decode_attention_ref


def _mk(b, skv, hq, hkv, d, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, skv, hkv, d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,skv,hq,hkv,d", [
    (1, 256, 4, 4, 64),       # MHA
    (2, 512, 8, 2, 64),       # GQA groups=4
    (1, 384, 16, 1, 128),     # MQA groups=16 (recurrentgemma shape)
    (2, 1024, 8, 8, 96),      # non-128 head_dim (padded lanes)
    (1, 200, 6, 2, 80),       # non-multiple skv (padded kv blocks)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(b, skv, hq, hkv, d, dtype):
    q, k, v = _mk(b, skv, hq, hkv, d, dtype)
    pos = jnp.asarray(skv - 1, jnp.int32)
    want = decode_attention_ref(q, k, v, pos)
    got = flash_decode(q, k, v, pos, bkv=128, interpret=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("pos", [0, 5, 130, 255])
def test_position_masking(pos):
    q, k, v = _mk(1, 256, 4, 2, 64, jnp.float32)
    want = decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32))
    got = flash_decode(q, k, v, jnp.asarray(pos, jnp.int32), bkv=128,
                       interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # sanity: masked positions must not leak — perturbing them is a no-op
    k2 = k.at[:, pos + 1:].set(99.0)
    v2 = v.at[:, pos + 1:].set(-99.0)
    got2 = flash_decode(q, k2, v2, jnp.asarray(pos, jnp.int32), bkv=128,
                        interpret=True)
    np.testing.assert_allclose(got2, got, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_sliding_window(window):
    q, k, v = _mk(1, 512, 8, 4, 64, jnp.float32, seed=3)
    pos = jnp.asarray(400, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, window=window)
    got = flash_decode(q, k, v, pos, window=window, bkv=128,
                       interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_xla_path_matches_oracle():
    q, k, v = _mk(2, 512, 8, 2, 64, jnp.bfloat16)
    pos = jnp.asarray(300, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, window=64)
    got = _decode_attention_xla(q, k, v, pos, window=64)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32),
        atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# Ragged per-slot positions (the continuous-batching contract): every
# batch row masks at its own length, one compiled kernel for all.
# ---------------------------------------------------------------------------

RAGGED_POS = [3, 17, 0, 31]


@pytest.mark.parametrize("hq,hkv,d", [
    (4, 4, 64),        # MHA
    (8, 2, 64),        # GQA groups=4
    (16, 1, 128),      # MQA groups=16
])
def test_ragged_positions_match_oracle(hq, hkv, d):
    """flash_decode (interpret) with per-slot positions [3, 17, 0, 31]
    == XLA reference == per-row scalar-pos decode."""
    q, k, v = _mk(4, 64, hq, hkv, d, jnp.float32, seed=7)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    want = decode_attention_ref(q, k, v, pos)
    got = flash_decode(q, k, v, pos, bkv=128, interpret=True)
    got_xla = _decode_attention_xla(q, k, v, pos, window=0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(got_xla, want, atol=2e-5, rtol=2e-5)
    # row i of the ragged batch == the same row decoded alone at a
    # scalar position (slot independence)
    for i, p in enumerate(RAGGED_POS):
        solo = flash_decode(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                            jnp.asarray(p, jnp.int32), bkv=128,
                            interpret=True)
        np.testing.assert_allclose(got[i:i + 1], solo, atol=2e-5,
                                   rtol=2e-5, err_msg=f"slot {i}")


@pytest.mark.parametrize("window", [8, 16])
def test_ragged_positions_sliding_window(window):
    """Per-slot positions compose with the sliding window: each row
    excludes its own slots <= pos[i] - window."""
    q, k, v = _mk(4, 64, 8, 4, 64, jnp.float32, seed=9)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, window=window)
    got = flash_decode(q, k, v, pos, window=window, bkv=128,
                       interpret=True)
    got_xla = _decode_attention_xla(q, k, v, pos, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(got_xla, want, atol=2e-5, rtol=2e-5)


def test_ragged_masked_slots_do_not_leak():
    """Perturbing any row's cache beyond its own position is a no-op for
    that row — the per-row mask is actually per-row."""
    q, k, v = _mk(4, 64, 8, 2, 64, jnp.float32, seed=11)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    base = flash_decode(q, k, v, pos, bkv=128, interpret=True)
    k2, v2 = k, v
    for i, p in enumerate(RAGGED_POS):
        k2 = k2.at[i, p + 1:].set(99.0)
        v2 = v2.at[i, p + 1:].set(-99.0)
    got = flash_decode(q, k2, v2, pos, bkv=128, interpret=True)
    np.testing.assert_allclose(got, base, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Block-paged decode: the page table steers the kv BlockSpec index_map;
# any table permutation of the same logical cache must reproduce the
# dense result.
# ---------------------------------------------------------------------------

def _paginate(k, v, ps, seed=0):
    """Scatter a dense (b, skv, hkv, d) cache into a randomly permuted
    page pool + per-row tables (page 0 = reserved sink, left zero)."""
    b, skv, hkv, d = k.shape
    mp = skv // ps
    rng = np.random.default_rng(seed)
    table = (rng.permutation(b * mp) + 1).reshape(b, mp).astype(np.int32)
    kp = np.zeros((1 + b * mp, ps, hkv, d), np.asarray(k).dtype)
    vp = np.zeros_like(kp)
    kn, vn = np.asarray(k), np.asarray(v)
    for i in range(b):
        for j in range(mp):
            kp[table[i, j]] = kn[i, j * ps:(j + 1) * ps]
            vp[table[i, j]] = vn[i, j * ps:(j + 1) * ps]
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(table)


PAGED_POS = [3, 130, 0, 255]


@pytest.mark.parametrize("hq,hkv,d", [
    (4, 4, 64),        # MHA
    (8, 2, 64),        # GQA groups=4
    (16, 1, 128),      # MQA groups=16
])
@pytest.mark.parametrize("window", [0, 32])
def test_paged_kernel_bitwise_at_page_eq_block(hq, hkv, d, window):
    """page_size == the dense kernel's kv block size -> identical block
    accumulation order -> BIT-identical output under any table
    permutation (the serve acceptance contract)."""
    q, k, v = _mk(4, 256, hq, hkv, d, jnp.float32, seed=13)
    pos = jnp.asarray(PAGED_POS, jnp.int32)
    kp, vp, tbl = _paginate(k, v, 128, seed=1)
    dense = flash_decode(q, k, v, pos, window=window, bkv=128,
                         interpret=True)
    got = flash_decode_paged(q, kp, vp, tbl, pos, window=window,
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


@pytest.mark.parametrize("ps", [8, 32])
def test_paged_kernel_matches_oracle_small_pages(ps):
    q, k, v = _mk(4, 64, 8, 2, 64, jnp.float32, seed=17)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    kp, vp, tbl = _paginate(k, v, ps, seed=2)
    want = decode_attention_ref(q, k, v, pos)
    got = flash_decode_paged(q, kp, vp, tbl, pos, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_paged_kernel_sliding_window(window):
    q, k, v = _mk(2, 256, 8, 4, 64, jnp.float32, seed=19)
    pos = jnp.asarray([200, 255], jnp.int32)
    kp, vp, tbl = _paginate(k, v, 16, seed=3)
    want = decode_attention_ref(q, k, v, pos, window=window)
    got = flash_decode_paged(q, kp, vp, tbl, pos, window=window,
                             interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_paged_xla_gather_bitwise_vs_dense():
    """The XLA paged path gathers the table back into the dense layout,
    so equal gathered length -> bit-identical to the dense XLA path (the
    property the engine's max_len page-rounding relies on)."""
    q, k, v = _mk(4, 64, 8, 2, 64, jnp.bfloat16, seed=21)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    kp, vp, tbl = _paginate(k, v, 16, seed=4)
    dense = _decode_attention_xla(q, k, v, pos, window=0)
    got = _decode_attention_paged_xla(q, kp, vp, tbl, pos, window=0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dense))


def test_paged_masked_pages_do_not_leak():
    """Sink-page garbage and per-row positions past ``pos`` never reach
    a row's output: point every wholly-masked table entry at a poisoned
    sink and poison the masked tail of each row's live pages."""
    ps = 16
    q, k, v = _mk(4, 64, 8, 2, 64, jnp.float32, seed=23)
    pos = jnp.asarray(RAGGED_POS, jnp.int32)
    kp, vp, tbl = _paginate(k, v, ps, seed=5)
    base = flash_decode_paged(q, kp, vp, tbl, pos, interpret=True)
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    tbl2 = np.asarray(tbl).copy()
    kp2[0], vp2[0] = 99.0, -99.0             # poisoned sink
    for i, p in enumerate(RAGGED_POS):
        for j in range(tbl2.shape[1]):
            if j * ps > p:                   # page wholly past pos
                tbl2[i, j] = 0
            else:                            # poison the masked tail
                page = tbl2[i, j]
                for t in range(ps):
                    if j * ps + t > p:
                        kp2[page, t] = 99.0
                        vp2[page, t] = -99.0
    got = flash_decode_paged(q, jnp.asarray(kp2), jnp.asarray(vp2),
                             jnp.asarray(tbl2), pos, interpret=True)
    np.testing.assert_allclose(got, base, atol=2e-5, rtol=2e-5)
