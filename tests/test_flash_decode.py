"""flash_decode Pallas kernel vs the pure-jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode
from repro.kernels.ops import _decode_attention_xla
from repro.kernels.ref import decode_attention_ref


def _mk(b, skv, hq, hkv, d, dtype, seed=0):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, hq, d), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (b, skv, hkv, d), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,skv,hq,hkv,d", [
    (1, 256, 4, 4, 64),       # MHA
    (2, 512, 8, 2, 64),       # GQA groups=4
    (1, 384, 16, 1, 128),     # MQA groups=16 (recurrentgemma shape)
    (2, 1024, 8, 8, 96),      # non-128 head_dim (padded lanes)
    (1, 200, 6, 2, 80),       # non-multiple skv (padded kv blocks)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matches_oracle(b, skv, hq, hkv, d, dtype):
    q, k, v = _mk(b, skv, hq, hkv, d, dtype)
    pos = jnp.asarray(skv - 1, jnp.int32)
    want = decode_attention_ref(q, k, v, pos)
    got = flash_decode(q, k, v, pos, bkv=128, interpret=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("pos", [0, 5, 130, 255])
def test_position_masking(pos):
    q, k, v = _mk(1, 256, 4, 2, 64, jnp.float32)
    want = decode_attention_ref(q, k, v, jnp.asarray(pos, jnp.int32))
    got = flash_decode(q, k, v, jnp.asarray(pos, jnp.int32), bkv=128,
                       interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # sanity: masked positions must not leak — perturbing them is a no-op
    k2 = k.at[:, pos + 1:].set(99.0)
    v2 = v.at[:, pos + 1:].set(-99.0)
    got2 = flash_decode(q, k2, v2, jnp.asarray(pos, jnp.int32), bkv=128,
                        interpret=True)
    np.testing.assert_allclose(got2, got, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [32, 128])
def test_sliding_window(window):
    q, k, v = _mk(1, 512, 8, 4, 64, jnp.float32, seed=3)
    pos = jnp.asarray(400, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, window=window)
    got = flash_decode(q, k, v, pos, window=window, bkv=128,
                       interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_xla_path_matches_oracle():
    q, k, v = _mk(2, 512, 8, 2, 64, jnp.bfloat16)
    pos = jnp.asarray(300, jnp.int32)
    want = decode_attention_ref(q, k, v, pos, window=64)
    got = _decode_attention_xla(q, k, v, pos, window=64)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32),
        atol=2e-2, rtol=2e-2)
