"""Validate the faithful analytical models against the paper's tables.

This is the paper-fidelity gate: every published row of Tables II-IV must
be reproduced by :mod:`repro.core.paper_model` within the documented
tolerances (exact for Table II; <=1% throughput, <=0.1 GiB/s BW, <=1.5%
RAM-efficiency elsewhere).
"""

import math

import pytest

from repro.core import paper_model as pm
from repro.core import paper_tables as pt
from repro.core.hardware import STRATIX_NX2100, VERSAL_VC1902


def _sol(pattern: str) -> pm.AIESolution:
    return pm.MAXEVA_P1 if pattern == "P1" else pm.MAXEVA_P2


# ---------------------------------------------------------------------------
# Table II: memory-model estimates and the HLS-AUTO failure mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", pt.VERSAL_TABLE2,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE2])
def test_table2_model_estimate_exact(row):
    geom = pm.versal_buffer_geometry(_sol(row.pattern), row.u, row.v, row.w)
    found = pm.versal_best_mapping(geom)
    assert found is not None
    mapping, brams, urams = found
    assert mapping == row.mapping
    assert brams == row.model_brams
    assert urams == row.model_urams


@pytest.mark.parametrize("row", pt.VERSAL_TABLE2,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE2])
def test_table2_hls_auto_exact(row):
    geom = pm.versal_buffer_geometry(_sol(row.pattern), row.u, row.v, row.w)
    _, brams, urams, fails = pm.versal_hls_auto_mapping(geom)
    assert brams == row.auto_brams
    assert urams == row.auto_urams
    assert fails == row.auto_fails
    if fails:  # the paper's over-utilization numbers: 133% / 138% URAM
        assert urams / VERSAL_VC1902.uram_288k > 1.3


# ---------------------------------------------------------------------------
# Table III: Versal top-10 designs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", pt.VERSAL_TABLE3,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE3])
def test_table3_geometry_and_resources(row):
    sol = _sol(row.pattern)
    assert sol.compute_gemm == row.compute_gemm
    assert sol.native_buffer(row.u, row.v, row.w) == row.native_buffer
    assert sol.aie_cores == row.aie_cores

    geom = pm.versal_buffer_geometry(sol, row.u, row.v, row.w)
    found = pm.versal_best_mapping(geom)
    assert found is not None
    mapping, brams, urams = found
    # Table III counts are post-implementation; they exceed the buffer
    # model by a small constant number of system FIFO BRAMs.
    assert urams == row.urams
    assert 0 <= row.brams - brams <= pm.BRAM_IMPL_OVERHEAD_TOL
    if row.mapping is not None:
        assert mapping == row.mapping


@pytest.mark.parametrize("row", pt.VERSAL_TABLE3,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE3])
def test_table3_throughput_within_1pct(row):
    thr = pm.versal_throughput_ops(_sol(row.pattern), row.pl_freq_mhz * 1e6)
    assert abs(thr / 1e12 - row.throughput_tops) / row.throughput_tops < 0.01


@pytest.mark.parametrize("row", pt.VERSAL_TABLE3,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE3])
def test_table3_bandwidth_column(row):
    """The BW column is bytes/2**30; reproduce to 0.1 'GB/s' printed."""
    sol = _sol(row.pattern)
    thr = pm.versal_throughput_ops(sol, row.pl_freq_mhz * 1e6)
    # Use the paper's measured throughput for the time base so the BW check
    # is independent of the (calibrated) throughput model's <=1% error.
    bw = pm.bytes_to_gibps(pm.versal_bw_bytes(
        sol, row.u, row.v, row.w, row.throughput_tops * 1e12))
    if (row.u, row.v, row.w, row.pattern) == (4, 2, 4, "P1"):
        # Model: 102.88 vs printed 101.9 (1.0%) — the single deviating row;
        # notably the model value falls just above the 102.4 DDR gate while
        # the printed one falls just below.  Documented in EXPERIMENTS.md.
        assert bw == pytest.approx(row.bw_gibps, rel=0.011)
    else:
        assert bw == pytest.approx(row.bw_gibps, abs=0.1)
    # And with the modeled throughput it stays within 1.5% (the 0.4-0.9%
    # throughput-model error compounds with the BW row tolerance).
    bw_model = pm.bytes_to_gibps(
        pm.versal_bw_bytes(sol, row.u, row.v, row.w, thr))
    assert bw_model == pytest.approx(row.bw_gibps, rel=0.015)


@pytest.mark.parametrize("row", pt.VERSAL_TABLE3,
                         ids=[f"{r.u}x{r.v}x{r.w}-{r.pattern}"
                              for r in pt.VERSAL_TABLE3])
def test_table3_ram_efficiency(row):
    sol = _sol(row.pattern)
    geom = pm.versal_buffer_geometry(sol, row.u, row.v, row.w)
    found = pm.versal_best_mapping(geom)
    assert found is not None
    eff = pm.versal_ram_efficiency(geom, found[0])
    assert eff == pytest.approx(row.ram_eff, abs=0.002)


def test_versal_dse_contains_paper_designs():
    """Every Table III (U,V,W) must appear among the DSE's top-8 ranked
    designs for its pattern, and the DSE must not find more reuse than the
    paper's best (=32)."""
    for pattern in ("P1", "P2"):
        designs = pm.versal_dse(_sol(pattern))
        rows = [r for r in pt.VERSAL_TABLE3 if r.pattern == pattern]
        top_reuse = designs[0].reuse
        top8 = {(d.u, d.v, d.w) for d in designs[:8]}
        for r in rows:
            assert (r.u, r.v, r.w) in top8, (pattern, r.u, r.v, r.w)
            assert r.u * r.v * r.w <= top_reuse
        # Paper's best designs achieve the DSE's maximum reuse (=32).
        assert top_reuse == 32


def test_versal_ddr_gate_selects_paper_valid_set():
    """SS V-A2: designs within the printed 102.4 BW gate are exactly the
    four the paper calls valid (75.40-76.93 TOPs, 0.911-0.938 TOPs/W)."""
    valid = [r for r in pt.VERSAL_TABLE3
             if r.bw_gibps <= pt.VERSAL_DDR_LIMIT_GIBPS]
    assert len(valid) == 4
    assert min(r.throughput_tops for r in valid) == 75.40
    assert max(r.throughput_tops for r in valid) == 76.93
    assert min(r.energy_eff for r in valid) == 0.911
    assert max(r.energy_eff for r in valid) == 0.938
    # our BW model must agree with the gate decision row by row, except the
    # single deviating 4x2x4 (P1) row (model 102.9 vs printed 101.9, which
    # straddles the 102.4 gate — documented in EXPERIMENTS.md).
    for r in pt.VERSAL_TABLE3:
        if (r.u, r.v, r.w, r.pattern) == (4, 2, 4, "P1"):
            continue
        bw = pm.bytes_to_gibps(pm.versal_bw_bytes(
            _sol(r.pattern), r.u, r.v, r.w, r.throughput_tops * 1e12))
        assert (bw <= pt.VERSAL_DDR_LIMIT_GIBPS) == (r in valid)


def test_fig7a_frequency_sweep():
    """Fig. 7a: <1.5% throughput drop from 290 to 250 MHz; ~16% from 250
    to 200 MHz (PL streaming becomes the bound)."""
    sol = pm.MAXEVA_P1
    t290 = pm.versal_throughput_ops(sol, 290e6)
    t250 = pm.versal_throughput_ops(sol, 250e6)
    t200 = pm.versal_throughput_ops(sol, 200e6)
    assert (t290 - t250) / t290 < 0.015
    drop = (t250 - t200) / t250
    assert 0.10 < drop < 0.20


def test_versal_peak_fraction_claim():
    """SS V-C3: ~60% of the 128-TOPs AIE theoretical peak."""
    frac = pm.versal_throughput_ops(pm.MAXEVA_P1, 300e6) / 128e12
    lo, hi = pt.VERSAL_PEAK_FRACTION_CLAIM
    assert lo <= frac <= hi + 0.005


# ---------------------------------------------------------------------------
# Table IV: Stratix top-10 designs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("row", pt.STRATIX_TABLE4,
                         ids=[f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}-{r.nprime}"
                              if False else
                              f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}"
                              f"@{r.native_buffer[2]}"
                              for r in pt.STRATIX_TABLE4])
def test_table4_layout_algebra(row):
    lay = pm.TBLayout(row.tb_len, row.kp, row.np_, row.mp)
    assert lay.compute_gemm == row.compute_gemm
    assert lay.tbs == row.tbs
    assert lay.tbs / STRATIX_NX2100.compute_units <= 0.91 + 1e-9
    # native buffer respects the latency-hiding + capacity constraints
    # (two rows have non-multiple native dims; the paper zero-pads)
    geom = pm.stratix_check_design(lay, row.native_buffer)
    assert geom.m20ks <= STRATIX_NX2100.bram_36k


@pytest.mark.parametrize("row", pt.STRATIX_TABLE4,
                         ids=[f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}"
                              f"@{r.native_buffer[2]}"
                              for r in pt.STRATIX_TABLE4])
def test_table4_throughput_within_0p3pct(row):
    lay = pm.TBLayout(row.tb_len, row.kp, row.np_, row.mp)
    thr = pm.stratix_throughput_ops(lay, row.freq_mhz * 1e6)
    assert abs(thr / 1e12 - row.throughput_tops) / row.throughput_tops \
        < 0.003


@pytest.mark.parametrize("row", pt.STRATIX_TABLE4,
                         ids=[f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}"
                              f"@{r.native_buffer[2]}"
                              for r in pt.STRATIX_TABLE4])
def test_table4_m20k_count(row):
    """Eq. 12/14 reproduce the M20K column exactly on 7/10 rows; three rows
    (18x16x4x3, 18x16x3x4, 9x16x6x4) are printed 2.7-4.2% above the buffer
    model — implementation blocks beyond the modeled buffers, mirroring the
    +6..12 BRAM overhead on Versal Table III.  Model never exceeds print."""
    lay = pm.TBLayout(row.tb_len, row.kp, row.np_, row.mp)
    geom = pm.stratix_geometry(lay, *row.native_buffer)
    assert geom.m20ks <= row.brams
    assert (row.brams - geom.m20ks) / row.brams <= 0.045
    overhead_rows = {(18, 16, 4, 3), (18, 16, 3, 4), (9, 16, 6, 4)}
    if (row.tb_len, row.kp, row.np_, row.mp) not in overhead_rows:
        assert geom.m20ks == row.brams, (geom.m20ks, row.brams)


@pytest.mark.parametrize("row", pt.STRATIX_TABLE4,
                         ids=[f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}"
                              f"@{r.native_buffer[2]}"
                              for r in pt.STRATIX_TABLE4])
def test_table4_bandwidth_column(row):
    bw = pm.bytes_to_gibps(pm.stratix_bw_bytes(
        *row.native_buffer, row.throughput_tops * 1e12))
    assert bw == pytest.approx(row.bw_gibps, abs=0.15)


@pytest.mark.parametrize("row", pt.STRATIX_TABLE4,
                         ids=[f"{r.tb_len}x{r.kp}x{r.np_}x{r.mp}"
                              f"@{r.native_buffer[2]}"
                              for r in pt.STRATIX_TABLE4])
def test_table4_ram_efficiency(row):
    """Printed efficiencies divide by the *implemented* M20K count, so we
    evaluate the model's logical-bit numerator against the printed block
    count (within 1%)."""
    lay = pm.TBLayout(row.tb_len, row.kp, row.np_, row.mp)
    geom = pm.stratix_geometry(lay, *row.native_buffer)
    eff = pm.stratix_ram_efficiency(geom, m20ks=row.brams)
    assert eff == pytest.approx(row.ram_eff, abs=0.01)


def test_stratix_ip_reuse_at_least_paper():
    """Our IP solver must find native buffers with reuse >= the paper's
    published choice for every Table IV layout."""
    for row in pt.STRATIX_TABLE4:
        lay = pm.TBLayout(row.tb_len, row.kp, row.np_, row.mp)
        ours = pm.stratix_ip_solve(lay)
        paper_reuse = math.prod(row.native_buffer)
        assert ours.reuse >= paper_reuse, (row, ours.native_buffer)


def test_stratix_dse_covers_paper_layouts():
    designs = pm.stratix_dse()
    keys = {(d.layout.tb_len, d.layout.kp, d.layout.np_, d.layout.mp)
            for d in designs}
    for row in pt.STRATIX_TABLE4:
        assert (row.tb_len, row.kp, row.np_, row.mp) in keys


def test_headline_claims():
    """Abstract: up to 77 / 68 TOPs and 0.94 / 1.35 TOPs/W."""
    v = pm.versal_throughput_ops(pm.MAXEVA_P1, 300e6) / 1e12
    assert v == pytest.approx(pt.VERSAL_PEAK_TOPS_CLAIM, rel=0.01)
    lay = pm.TBLayout(18, 16, 4, 3)
    s = pm.stratix_throughput_ops(lay, 349e6) / 1e12
    assert s == pytest.approx(pt.STRATIX_PEAK_TOPS_CLAIM, rel=0.005)
    assert s / STRATIX_NX2100.peak_tops_int8 * 1e12 == pytest.approx(
        pt.STRATIX_PEAK_FRACTION_CLAIM, abs=0.01)
