"""Substrate-layer tests: optimizers, schedules, compression, data
pipeline, checkpointing, fault tolerance, elastic re-mesh."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import get_smoke_config
from repro.data import pipeline
from repro.optim import adafactor, adamw, compression, schedule
from repro.runtime import fault_tolerance as ft


# ---------------------------------------------------------------- optim

def _quadratic_params():
    return {"w": jnp.asarray([3.0, -2.0, 5.0]),
            "b": jnp.asarray([[1.0, -1.0], [2.0, 0.5]])}


def _converges(opt_init, opt_update, lr=0.1, steps=300):
    params = _quadratic_params()
    state = opt_init(params)
    loss = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
    for _ in range(steps):
        grads = jax.grad(loss)(params)
        params, state = opt_update(grads, state, params, lr=lr,
                                   weight_decay=0.0)
    return float(loss(params))


def test_adamw_converges():
    assert _converges(adamw.init, adamw.update) < 1e-3


def test_adafactor_converges():
    assert _converges(adafactor.init, adafactor.update) < 1e-2


def test_adafactor_state_is_factored():
    p = {"w": jnp.zeros((64, 32))}
    st = adafactor.init(p)
    assert st.vr["w"].shape == (64,)
    assert st.vc["w"].shape == (32,)


def test_optimizer_state_specs_rank_match():
    from jax.sharding import PartitionSpec as P
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    specs = {"w": P("data", "model"), "b": P(None)}
    st = adafactor.init(params)
    ss = adafactor.state_specs(specs, params)
    assert tuple(ss.vr["w"]) == ("data",)
    assert tuple(ss.vc["w"]) == ("model",)
    assert len(ss.vr["b"]) == 1
    sa = adamw.state_specs(specs, params)
    assert tuple(sa.mu["w"]) == ("data", "model")


def test_warmup_cosine_schedule():
    lr0 = schedule.warmup_cosine(0, peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)
    lr_peak = schedule.warmup_cosine(10, peak_lr=1.0, warmup_steps=10,
                                     total_steps=100)
    lr_end = schedule.warmup_cosine(100, peak_lr=1.0, warmup_steps=10,
                                    total_steps=100)
    assert float(lr0) == 0.0
    assert float(lr_peak) == pytest.approx(1.0)
    assert float(lr_end) == pytest.approx(0.1, abs=1e-6)


# ---------------------------------------------------------- compression

def test_int8_error_feedback_reduces_error():
    """Error feedback: quantization residual carried into the next step
    keeps the cumulative compressed sum tracking the true sum."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    err = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    acc_comp = jnp.zeros_like(g)
    for step in range(20):
        gs = g * (0.9 ** step)
        q, scale = compression._quantize(gs + err)
        deq = q.astype(jnp.float32) * scale
        err = gs + err - deq
        acc_true += gs
        acc_comp += deq
    rel = float(jnp.linalg.norm(acc_comp - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01


# ------------------------------------------------------------- pipeline

def test_pipeline_deterministic():
    cfg = get_smoke_config("minitron-8b")
    d = pipeline.DataConfig(seq_len=32, global_batch=4, seed=7)
    b1 = pipeline.make_batch(cfg, d, step=3)
    b2 = pipeline.make_batch(cfg, d, step=3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    cfg = get_smoke_config("minitron-8b")
    full = pipeline.make_batch(
        cfg, pipeline.DataConfig(seq_len=16, global_batch=4), 0)
    sh0 = pipeline.make_batch(
        cfg, pipeline.DataConfig(seq_len=16, global_batch=4,
                                 row_start=0, rows=2), 0)
    # shards are deterministic per (step, row_start) but independent
    # streams; shapes partition the global batch
    assert sh0["tokens"].shape == (2, 16)
    assert full["tokens"].shape == (4, 16)


def test_pipeline_labels_are_shifted_tokens():
    cfg = get_smoke_config("minitron-8b")
    b = pipeline.make_batch(
        cfg, pipeline.DataConfig(seq_len=32, global_batch=2), 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip_and_gc():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.asarray(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep_last=2)
        for step in (1, 2, 3, 4):
            ck.save(step, jax.tree.map(lambda x: x * step, tree))
        assert ck.all_steps() == [3, 4]          # gc keeps last 2
        got = ck.restore(tree, step=4)
        np.testing.assert_allclose(got["a"], tree["a"] * 4)
        assert int(got["n"]["b"]) == 12


def test_checkpoint_async_then_blocking_same_step():
    tree = {"a": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, tree, blocking=False)
        ck.save(5, tree, blocking=True)          # must not race
        assert ck.latest_step() == 5


def test_checkpoint_uncommitted_ignored():
    tree = {"a": jnp.ones((4,))}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, tree)
        os.remove(os.path.join(d, "step_00000001", "COMMITTED"))
        assert ck.all_steps() == []


# ------------------------------------------------------ fault tolerance

def test_watchdog_flags_stragglers():
    wd = ft.StepWatchdog(threshold=2.0)
    for i in range(10):
        assert wd.observe(i, 1.0) is None
    ev = wd.observe(10, 5.0)
    assert ev is not None and ev.step == 10


def test_run_resumable_restarts():
    inj = ft.FailureInjector(fail_at_steps=(3, 7))
    done = []
    state = {"step": 0}

    def restore():
        return state["step"]

    def run_step(step):
        inj.maybe_fail(step)
        done.append(step)
        state["step"] = step + 1

    restarts = ft.run_resumable(10, run_step, restore)
    assert restarts == 2
    assert state["step"] == 10
    assert sorted(set(done)) == list(range(10))


def test_checkpoint_bfloat16_roundtrip():
    """ml_dtypes arrays (bf16) must survive the npz round-trip — the
    ~100M example trains in bf16 and restarts from checkpoint."""
    tree = {"w": jnp.arange(8, dtype=jnp.float32)
            .astype(jnp.bfloat16).reshape(2, 4),
            "s": jnp.asarray(2.5, jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, tree)
        got = ck.restore(tree)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(got["w"], np.float32), np.asarray(tree["w"],
                                                     np.float32))
    assert float(got["s"]) == 2.5
